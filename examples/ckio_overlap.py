"""Compute/input overlap demo (paper Figs. 8–9 mechanism, minimal form).

Background chares keep executing on every PE while a read session ingests a
file on helper I/O threads; the printed fraction is the share of the input
window spent doing useful background compute.

    PYTHONPATH=src python examples/ckio_overlap.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import CkIO, BackgroundWorker, CkFuture, FileOptions

path = "/tmp/ckio_overlap.bin"
with open(path, "wb") as f:
    f.write(np.random.default_rng(0).integers(0, 256, 96 << 20,
                                              dtype=np.uint8).tobytes())

ck = CkIO(num_pes=4)
workers = [BackgroundWorker(ck.sched, pe, grain_us=10) for pe in range(4)]
fh = ck.open_sync(path, FileOptions(num_readers=4))

t0 = time.perf_counter()
sess = ck.start_read_session_sync(fh, fh.size, 0)
for w in workers:
    w.start()

done = CkFuture()
buf = bytearray(fh.size)
ck.read(sess, fh.size, 0, buf, done)
done.wait(ck.sched, timeout=120)
wall = time.perf_counter() - t0
for w in workers:
    w.stop()

busy = sum(w.busy_s for w in workers)
iters = sum(w.iterations for w in workers)
print(f"input window: {wall*1e3:.1f} ms for {fh.size >> 20} MB "
      f"({fh.size/wall/1e6:.0f} MB/s)")
print(f"background work done during input: {iters} iterations, "
      f"{busy*1e3:.1f} ms busy -> overlap fraction {100*busy/wall:.1f}%")
ck.close_read_session_sync(sess)
ck.close_sync(fh)
