"""Serving example: greedy decoding of CkIO-loaded prompts on a reduced
recurrentgemma (hybrid RG-LRU + local attention).

Static batching is the default. Extra flags pass straight through to
``repro.launch.serve``, so the continuous-batching engine is one flag away:

    PYTHONPATH=src python examples/serve_lm.py
    PYTHONPATH=src python examples/serve_lm.py --continuous --arrival-rate 50
    PYTHONPATH=src python examples/serve_lm.py --continuous --service \
        --pool-workers 2 --max-inflight-mb 16
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

if __name__ == "__main__":
    sys.argv = [
        "serve",
        "--arch", "recurrentgemma-2b",
        "--smoke",
        "--requests", "12",
        "--batch", "4",
        "--prompt-len", "24",
        "--max-new", "8",
    ] + sys.argv[1:]
    from repro.launch.serve import main

    main()
