"""CkIO quickstart: the paper's five-call API in one file.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import CkIO, CkCallback, FileOptions

# 1. a "large shared input file" (64 MB of bytes)
path = "/tmp/ckio_quickstart.bin"
rng = np.random.default_rng(0)
data = rng.integers(0, 256, size=64 << 20, dtype=np.uint8).tobytes()
with open(path, "wb") as f:
    f.write(data)

# 2. a CkIO instance: 8 logical PEs on 2 "nodes"
ck = CkIO(num_pes=8, pes_per_node=4)

# 3. open -> startReadSession -> read -> closeReadSession -> close,
#    every completion delivered as a scheduled task (split-phase).
fh = ck.open_sync(path, FileOptions(num_readers=4, splinter_bytes=4 << 20))
print(f"opened {fh.path} ({fh.size >> 20} MB), 4 buffer readers")

sess = ck.start_read_session_sync(fh, nbytes=32 << 20, offset=8 << 20)
print(f"session #{sess.id}: greedy prefetch started "
      f"({len(sess.plan.splinters)} splinters)")

# split-phase read from a migratable client
client = ck.make_client(pe=1)
done = []


def after_read(msg):
    ok = bytes(msg.data) == data[msg.offset : msg.offset + msg.nbytes]
    print(f"  read [{msg.offset}, +{msg.nbytes}) on PE {client.pe}: "
          f"{'OK' if ok else 'CORRUPT'} ({msg.latency_s*1e3:.2f} ms)")
    done.append(ok)


buf = bytearray(1 << 20)
ck.read(sess, 1 << 20, 10 << 20, buf, client.callback(after_read), client=client)
ck.run_until(lambda: len(done) == 1)

# 4. migrate the client mid-session; reads keep working at the new location
client.migrate(6)
buf2 = bytearray(1 << 20)
ck.read(sess, 1 << 20, 24 << 20, buf2, client.callback(after_read), client=client)
ck.run_until(lambda: len(done) == 2)

print("metrics:", {k: round(v, 2) for k, v in sess.metrics.summary().items()
                   if k in ("throughput_MBps", "read_calls", "steals",
                            "requests", "bytes_read")})
ck.close_read_session_sync(sess)
ck.close_sync(fh)
assert all(done)
print("quickstart OK")
