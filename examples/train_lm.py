"""End-to-end driver: train a reduced qwen2-moe through the CkIO pipeline
for a few hundred steps with checkpoints + fault-tolerant supervisor.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

(This is a thin preset over ``python -m repro.launch.train``.)
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

if __name__ == "__main__":
    args = sys.argv[1:]
    sys.argv = [
        "train",
        "--arch", "qwen2-moe-a2.7b",
        "--smoke",
        "--steps", "200",
        "--global-batch", "8",
        "--seq", "128",
        "--microbatches", "2",
        "--num-readers", "4",
        "--num-consumers", "32",
        "--ckpt-every", "50",
        "--device-ingest",   # one device_put/step + on-device reassembly
    ] + args
    from repro.launch.train import main

    main()
