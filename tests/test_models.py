"""Per-architecture smoke tests (reduced same-family configs) + decode
consistency against the teacher-forced forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES_BY_NAME
from repro.configs.registry import get_config, list_archs, smoke_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg, with_labels=True):
    ks = jax.random.split(KEY, 2)
    if cfg.is_encdec:
        b = {
            "embeds": jax.random.normal(ks[0], (B, cfg.encoder_seq, cfg.d_model),
                                        jnp.bfloat16) * 0.02,
            "tokens": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
        }
    elif cfg.input_mode == "embeddings":
        b = {"embeds": jax.random.normal(ks[0], (B, S, cfg.d_model),
                                         jnp.bfloat16) * 0.02}
        if cfg.mrope_sections:
            b["positions"] = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, 3)
            )
    else:
        b = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if with_labels:
        b["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", list_archs())
def test_arch_train_step_smoke(arch):
    cfg = smoke_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    loss, metrics = jax.jit(model.loss)(params, _batch(cfg))
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # ~ln(vocab) at init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 3 * np.log(cfg.vocab_size)
    # gradients flow and are finite
    grads = jax.grad(lambda p: model.loss(p, _batch(cfg))[0])(params)
    gleaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in gleaves)
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0 for g in gleaves)


@pytest.mark.parametrize("arch", list_archs())
def test_arch_prefill_and_decode_smoke(arch):
    cfg = smoke_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg, with_labels=False)
    logits = jax.jit(model.prefill_logits)(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    frames = batch.get("embeds") if cfg.is_encdec else None
    state = model.init_decode_state(params, B, 16, frames=frames)
    lg, state2 = jax.jit(model.decode)(params, state,
                                       {"tokens": jnp.ones((B, 1), jnp.int32)})
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    assert int(state2.pos) == 1


@pytest.mark.parametrize(
    "arch",
    ["codeqwen1.5-7b", "falcon-mamba-7b", "recurrentgemma-2b", "gemma3-27b"],
)
def test_decode_matches_teacher_forcing(arch):
    """Token-by-token decode must reproduce the full-sequence forward —
    validates ring KV caches (global + windowed), mamba and RG-LRU decode
    states against their train-time scans."""
    cfg = smoke_config(get_config(arch)).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    T = 12
    tokens = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    full = model._m.forward_logits(params, cfg, {"tokens": tokens},
                                   last_only=False)
    state = model.init_decode_state(params, B, 2 * T)
    dec_logits = []
    decode = jax.jit(model.decode)
    for t in range(T):
        lg, state = decode(params, state, {"tokens": tokens[:, t:t + 1]})
        dec_logits.append(lg[:, 0])
    dec = jnp.stack(dec_logits, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full, np.float32),
        atol=2e-3, rtol=2e-3,
    )


def test_moe_capacity_and_padding():
    """Padded experts must receive no routing weight."""
    from repro.models.moe import moe_apply, moe_init

    d, E_real, pad, ff = 16, 6, 2, 8
    params = moe_init(KEY, d, E_real, ff, 0, jnp.float32, expert_pad=pad)
    x = jax.random.normal(KEY, (2, 8, d))
    out, aux = moe_apply(params, x, top_k=2, capacity_factor=2.0,
                         dtype=jnp.float32, num_real_experts=E_real)
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
    # zero out real experts' weights -> output must be exactly zero even
    # though padded experts have nonzero weights (proves they're masked)
    z = dict(params)
    for k in ("gate", "up", "down"):
        z[k] = params[k].at[:E_real].set(0.0)
    out_z, _ = moe_apply(z, x, top_k=2, capacity_factor=2.0,
                         dtype=jnp.float32, num_real_experts=E_real)
    np.testing.assert_allclose(np.asarray(out_z), 0.0, atol=1e-6)


def test_sliding_window_attention_masks_past():
    """A token beyond the window must not influence attention output."""
    from repro.models.attention import attention_train, attn_init
    from repro.models.layers import rope_angles

    d, H, hd, S, W = 16, 2, 8, 16, 4
    params = attn_init(KEY, d, H, H, hd, jnp.float32)
    x = jax.random.normal(KEY, (1, S, d))
    pos = jnp.arange(S)[None]
    cos, sin = rope_angles(pos, hd, 1e4)
    y1 = attention_train(params, x, cos, sin, dtype=jnp.float32, eps=1e-6,
                         window=W)
    x2 = x.at[0, 0].set(99.0)      # outside the window of position >= W
    y2 = attention_train(params, x2, cos, sin, dtype=jnp.float32, eps=1e-6,
                         window=W)
    np.testing.assert_allclose(np.asarray(y1[0, W:]), np.asarray(y2[0, W:]),
                               atol=1e-5)
    assert not np.allclose(np.asarray(y1[0, 0]), np.asarray(y2[0, 0]))


def test_input_specs_cover_all_cells():
    from repro.configs.registry import cells

    n = 0
    for arch, shape in cells():
        model = build_model(get_config(arch))
        specs = model.input_specs(shape)
        assert specs, (arch, shape.name)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
        if shape.kind == "decode":
            st = model.decode_state_specs(shape)
            assert jax.tree.leaves(st)
        n += 1
    assert n == 32   # 10 archs x 4 shapes - 8 long_500k skips
