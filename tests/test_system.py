"""End-to-end behaviour tests: training through the CkIO pipeline converges,
restart resumes bit-exact, serving completes, dry-run lowers a real cell."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, smoke_config
from repro.core import FileOptions
from repro.data import CkIOPipeline, make_token_file
from repro.models import build_model
from repro.train import (
    AsyncCheckpointer,
    OptConfig,
    StepSupervisor,
    init_opt_state,
    make_train_step,
    restore_tree,
)

KEY = jax.random.PRNGKey(0)


def test_train_e2e_through_ckio_pipeline(tmp_path):
    """The ChaNGa-analog: over-decomposed consumers feed a real train loop;
    loss must drop on a repeating corpus."""
    cfg = smoke_config(get_config("qwen2-moe-a2.7b"))
    model = build_model(cfg)
    path = str(tmp_path / "corpus.bin")
    steps, gb, seq = 12, 4, 32
    make_token_file(path, steps * gb * (seq + 1) + 64, cfg.vocab_size, seed=1)
    pipe = CkIOPipeline(path, gb, seq, num_pes=2, num_consumers=8,
                        file_opts=FileOptions(num_readers=2))
    params = model.init(KEY)
    opt = init_opt_state(params)
    step_jit = jax.jit(make_train_step(
        model, OptConfig(peak_lr=3e-3, warmup_steps=2, decay_steps=steps * 4),
        num_microbatches=2))
    losses = []
    for s in range(steps):
        x, y = pipe.get_batch(s % 4)   # cycle a small window -> memorizable
        params, opt, m = step_jit(params, opt,
                                  {"tokens": jnp.asarray(x),
                                   "labels": jnp.asarray(y)})
        losses.append(float(m["loss"]))
    pipe.close()
    assert losses[-1] < losses[0] - 0.1, losses


def test_restart_resumes_deterministically(tmp_path):
    """Kill-and-restart mid-run == uninterrupted run (checkpoint/replay)."""
    cfg = smoke_config(get_config("phi4-mini-3.8b")).replace(dtype="float32")
    model = build_model(cfg)
    opt_cfg = OptConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=100)
    step_jit = jax.jit(make_train_step(model, opt_cfg))

    def batch_for(s):
        k = jax.random.PRNGKey(1000 + s)
        t = jax.random.randint(k, (2, 17), 0, cfg.vocab_size)
        return {"tokens": t[:, :-1], "labels": t[:, 1:]}

    def run(n_steps, state):
        for s in range(int(jax.device_get(state["opt"]["step"])), n_steps):
            p, o, _ = step_jit(state["params"], state["opt"], batch_for(s))
            state = {"params": p, "opt": o}
        return state

    params = model.init(KEY)
    ref_state = run(6, {"params": params, "opt": init_opt_state(params)})

    # interrupted run: 3 steps, checkpoint, "crash", restore, continue
    st = run(3, {"params": params, "opt": init_opt_state(params)})
    ck_path = str(tmp_path / "mid.ckpt")
    from repro.train import save_checkpoint

    save_checkpoint(ck_path, st, step=3)
    restored, step = restore_tree(ck_path, st)
    assert step == 3
    final = run(6, restored)

    for a, b in zip(jax.tree.leaves(ref_state["params"]),
                    jax.tree.leaves(final["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serving_batched_requests():
    cfg = smoke_config(get_config("recurrentgemma-2b"))
    model = build_model(cfg)
    params = model.init(KEY)
    from repro.serve import BatchServer, Request

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=8,
                                        dtype=np.int32),
                    max_new_tokens=4)
            for i in range(5)]
    out = BatchServer(model, params, batch_size=2).serve(reqs)
    assert all(r.result is not None and len(r.result) == 4 for r in out)


def test_greedy_generate_deterministic():
    cfg = smoke_config(get_config("codeqwen1.5-7b")).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    from repro.serve import greedy_generate

    prompt = jax.random.randint(KEY, (1, 6), 0, cfg.vocab_size)
    a = np.asarray(greedy_generate(model, params, prompt, 5))
    b = np.asarray(greedy_generate(model, params, prompt, 5))
    np.testing.assert_array_equal(a, b)


def test_dryrun_subprocess_lowers_real_cell(tmp_path):
    """The dry-run must boot with 512 placeholder devices and lower a real
    (arch × shape) cell in a fresh process."""
    out = str(tmp_path / "dry.jsonl")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "olmoe-1b-7b", "--shape", "decode_32k",
         "--mesh", "pod", "--no-compile", "--no-analyze", "--out", out],
        env=env, capture_output=True, text=True, timeout=300,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads(open(out).read().strip().splitlines()[-1])
    assert "error" not in rec, rec
    assert rec["chips"] == 256


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes_from_hlo

    hlo = """
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={}
  %ag = (bf16[2,512]{1,0}, bf16[2,512]{1,0}) all-gather(bf16[1,512] %a, bf16[1,512] %b), dimensions={0}
  %rs = f32[64]{0} reduce-scatter(f32[1024]{0} %y), dimensions={0}
  %cp = u32[16]{0} collective-permute(u32[16]{0} %z), source_target_pairs={{0,1}}
  %nope = f32[8]{0} add(f32[8]{0} %p, f32[8]{0} %q)
"""
    got = collective_bytes_from_hlo(hlo)
    assert got["all-reduce"] == 4096
    assert got["all-gather"] == 2 * 2 * 512 * 2
    assert got["reduce-scatter"] == 256
    assert got["collective-permute"] == 64
    assert got["count"] == 4
