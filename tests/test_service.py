"""Persistent reader service: re-arm protocol, recycling, admission, faults.

Covers ``ipc/service.py`` end to end:

* ``ArenaPool`` unit behavior: power-of-two size classes, recycle hits
  keep the segment (generation bumped), ``check_generation`` fails stale
  views fast, quarantined releases unlink instead of recycling, the free
  list is bounded;
* the re-arm protocol matrix on BOTH pool substrates (``backend="thread"``
  and ``"process"``): K back-to-back sessions through one pool are
  bit-identical and zero-copy, epochs strictly increase, sessions 2..K
  recycle the arena, the service counters (admitted / checkout / rearms /
  completed) reconcile;
* FileSet shards through the pool: a sharded session drains bit-identically
  with per-shard read accounting intact;
* faults on the pooled path (process substrate — the crash hooks call
  ``os._exit`` and must NEVER run inside the pytest process): a seeded
  ``FaultPlan`` crash mid-re-arm recovers per the session's own
  ``recovery`` option (supervisor re-issue, or a supplementary re-arm wave
  for ``"respawn"``) and the service keeps serving afterwards;
* sibling containment (the shutdown-vs-recovery fix): a pooled worker
  crash under ``recovery="none"`` fails ITS session alone — the concurrent
  sibling session completes bit-identically, exactly the dead worker is
  evicted, and the pool lazily replaces it for the next session;
* MPSC hygiene: a ring event carrying an epoch that matches no live
  session is dropped + counted (``ServiceMetrics.stale_events``), never
  delivered;
* admission: with the inflight cap and queue both full, ``submit`` raises
  a descriptive ``ServiceBusy`` (counted as rejected); with
  ``use_service`` left at auto the Director falls back to legacy
  per-session spawn and the session completes un-pooled.

Thread-substrate tests keep the matrix fast; the process substrate pays
one real spawn per service and is used where process death semantics are
the subject.
"""
from __future__ import annotations

import os
import time

import numpy as np
import pytest

from repro.core import CkIO, FileOptions, WorkerCrashed
from repro.core.faults import CrashReader, FaultPlan
from repro.data import FileSet, write_token_shards
from repro.io.posix import write_file
from repro.ipc.ring import RingEvent
from repro.ipc.service import (
    ArenaPool,
    ReaderService,
    ServiceBusy,
    ServiceOptions,
    _size_class,
)
from repro.ipc.shm import StaleArenaView

SEED = int(os.environ.get("CKIO_FAULT_SEED", "20260809"))


def _shm_leftovers():
    d = "/dev/shm"
    if not os.path.isdir(d):
        return []
    return [n for n in os.listdir(d) if n.startswith("ckio-")]


@pytest.fixture(autouse=True)
def _clean_shm():
    # Leftover-free /dev/shm is asserted per test; scrub debris a PRIOR
    # (failed) test left behind so the assertion stays self-contained.
    for n in _shm_leftovers():
        try:
            os.unlink(os.path.join("/dev/shm", n))
        except OSError:
            pass
    yield


@pytest.fixture
def data_file(tmp_path):
    rng = np.random.default_rng(SEED)
    data = rng.integers(0, 256, 1 << 20, dtype=np.uint8).tobytes()
    path = str(tmp_path / "service_blob.bin")
    write_file(path, data)
    return path, data


def _opts(**kw):
    base = dict(num_readers=2, splinter_bytes=128 * 1024,
                backend="process", max_workers=2)
    base.update(kw)
    return FileOptions(**base)


def _service(ck, **kw):
    base = dict(pool_workers=2, backend="thread")
    base.update(kw)
    svc = ReaderService(ServiceOptions(**base))
    ck.director.attach_service(svc)
    return svc


# -- ArenaPool ----------------------------------------------------------------
def test_size_class_pow2_buckets():
    q = 1 << 20
    assert _size_class(1, q) == q
    assert _size_class(q, q) == q
    assert _size_class(q + 1, q) == 2 * q
    assert _size_class(3 * q, q) == 4 * q


def test_arena_pool_recycles_and_bumps_generation():
    pool = ArenaPool(max_segments=4, quantum=1 << 16)
    try:
        a1, recycled = pool.acquire(10_000)
        assert not recycled and a1.generation == 1
        assert a1.nbytes == 1 << 16               # size-class, not request
        name = a1.path
        pool.release(a1)
        assert pool.free_segments() == 1
        a2, recycled = pool.acquire(50_000)       # fits the same class
        assert recycled and a2 is a1 and a2.generation == 2
        # a view captured under generation 1 fails fast, never aliases
        with pytest.raises(StaleArenaView):
            a2.check_generation(1)
        a2.check_generation(2)
        assert a2.path == name                    # same prefaulted segment
        pool.release(a2)
    finally:
        pool.shutdown()
    assert _shm_leftovers() == []


def test_arena_pool_quarantine_unlinks_instead_of_recycling():
    pool = ArenaPool(max_segments=4, quantum=1 << 16)
    try:
        a, _ = pool.acquire(1 << 16)
        pool.release(a, quarantine=True)          # pinned export: never reuse
        assert pool.free_segments() == 0
        assert a.closed
    finally:
        pool.shutdown()
    assert _shm_leftovers() == []


def test_arena_pool_free_list_is_bounded():
    pool = ArenaPool(max_segments=1, quantum=1 << 16)
    try:
        a, _ = pool.acquire(1 << 16)
        b, _ = pool.acquire(1 << 16)
        pool.release(a)
        pool.release(b)                           # over capacity: unlinked
        assert pool.free_segments() == 1
        assert b.closed and not a.closed
    finally:
        pool.shutdown()
    assert _shm_leftovers() == []


# -- re-arm protocol matrix ---------------------------------------------------
@pytest.mark.parametrize("substrate", ["thread", "process"])
def test_back_to_back_sessions_rearm_one_pool(data_file, substrate):
    """Three sessions through one pool: bit-identical, zero-copy, strictly
    increasing epochs, arena recycled from session 2 on, and the service
    counters reconcile with what ran."""
    path, data = data_file
    ck = CkIO(num_pes=4)
    svc = _service(ck, backend=substrate)
    try:
        fh = ck.open_sync(path, _opts())
        epochs = []
        for i in range(3):
            sess = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
            view = ck.read_view_sync(sess, len(data), 0, timeout=120)
            assert bytes(view) == data
            del view
            m = sess.metrics.summary()
            assert m["pooled"] == 1.0
            assert sess.metrics.bytes_copied == 0
            assert bool(m["arena_recycled"]) == (i > 0)
            epochs.append(sess.metrics.service_epoch)
            ck.close_read_session_sync(sess)
        ck.close_sync(fh)
        assert epochs == sorted(epochs) and len(set(epochs)) == 3
        sm = svc.metrics
        assert sm.admitted == 3 and sm.checkout_count == 3
        assert sm.rearms == 6                     # 3 sessions x 2 workers
        assert sm.completed == 3                  # Director observer path
        assert sm.arena_hits == 2 and sm.arena_misses == 1
        assert sm.workers_spawned == 2 and sm.workers_evicted == 0
        assert svc.pool_size() == 2 and svc.idle_workers() == 2
    finally:
        svc.shutdown()
    assert _shm_leftovers() == []


def test_concurrent_sessions_share_one_pool(data_file):
    """Four concurrent sessions over disjoint windows, one 2-worker pool:
    the MPSC poller keeps per-session fan-out separate (bit-identity per
    window, per-session zero-copy)."""
    path, data = data_file
    ck = CkIO(num_pes=4)
    svc = _service(ck, pool_workers=2, max_sessions=4)
    try:
        fh = ck.open_sync(path, _opts(num_readers=1, max_workers=1))
        win = len(data) // 4
        sessions = [ck.start_read_session_sync(fh, win, i * win, timeout=120)
                    for i in range(4)]
        for i, sess in enumerate(sessions):
            view = ck.read_view_sync(sess, win, i * win, timeout=120)
            assert bytes(view) == data[i * win:(i + 1) * win]
            del view
            assert sess.metrics.pooled
            assert sess.metrics.bytes_copied == 0
        for sess in sessions:
            ck.close_read_session_sync(sess)
        ck.close_sync(fh)
        assert svc.metrics.stale_events == 0
        assert svc.metrics.occupancy_hwm <= 2     # never more than the pool
    finally:
        svc.shutdown()
    assert _shm_leftovers() == []


def test_fileset_shards_through_service(tmp_path):
    """A sharded FileSet session on the pool: splinters route to the right
    backing files (bit-identity + per-shard read accounting) and a second
    session re-arms over the same shards."""
    rows = 32 * 1024                              # 128 KiB per shard (uint32)
    rng = np.random.default_rng(SEED)
    arr = rng.integers(0, 2**31, size=2 * rows, dtype=np.uint32)
    fs = FileSet.build(write_token_shards(str(tmp_path), arr, [rows, rows]))
    ck = CkIO(num_pes=4)
    svc = _service(ck)
    try:
        fh = ck.open_fileset_sync(fs, _opts(splinter_bytes=64 * 1024))
        for _ in range(2):
            sess = ck.start_read_session_sync(fh, fs.data_bytes, 0,
                                              timeout=120)
            view = ck.read_view_sync(sess, fs.data_bytes, 0, timeout=120)
            assert bytes(view) == arr.tobytes()
            del view
            assert sess.metrics.pooled
            assert sess.metrics.bytes_copied == 0
            assert sess.metrics.shard_bytes[0] == rows * 4
            assert sess.metrics.shard_bytes[1] == rows * 4
            ck.close_read_session_sync(sess)
        ck.close_sync(fh)
    finally:
        svc.shutdown()
    assert _shm_leftovers() == []


# -- faults on the pooled path (process substrate: crash hooks os._exit) ------
def test_crash_mid_rearm_respawn_keeps_service_alive(data_file):
    """Session 2 of 3 loses a pooled worker mid-drain: the unfinished tail
    re-arms on a supplementary wave (session-level ``recovery="respawn"``),
    completion is bit-identical, exactly one worker is evicted, and
    session 3 runs on the lazily replenished pool."""
    path, data = data_file
    ck = CkIO(num_pes=4)
    svc = _service(ck, backend="process")
    try:
        fh_ok = ck.open_sync(path, _opts(splinter_bytes=256 * 1024))
        fh_bad = ck.open_sync(path, _opts(
            splinter_bytes=256 * 1024, recovery="respawn", max_respawns=2,
            worker_fault=CrashReader(reader=1, after=1, code=66)))

        def drain(fh):
            sess = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
            view = ck.read_view_sync(sess, len(data), 0, timeout=120)
            assert bytes(view) == data
            del view
            assert sess.metrics.pooled
            assert sess.metrics.bytes_copied == 0
            m = sess.metrics
            ck.close_read_session_sync(sess)
            return m

        drain(fh_ok)                              # session 1: clean re-arm
        m2 = drain(fh_bad)                        # session 2: crash + respawn
        assert m2.recovery.respawns == 1
        assert m2.recovery.reissued_splinters >= 1
        m3 = drain(fh_ok)                         # session 3: pool healed
        assert m3.recovery.respawns == 0
        ck.close_sync(fh_ok)
        ck.close_sync(fh_bad)
        assert svc.metrics.workers_evicted == 1
        assert svc.metrics.sessions_failed == 0
        assert svc.pool_size() == 2               # lazy replacement landed
    finally:
        svc.shutdown()
    assert _shm_leftovers() == []


def test_fault_plan_crash_reissue_on_pool(data_file):
    """Seeded FaultPlan crash against the pooled backend with
    ``recovery="reissue"``: the supervisor re-reads the dead worker's tail,
    the session completes bit-identically, the service keeps serving."""
    path, data = data_file
    plan = FaultPlan(seed=SEED, crash=True, num_readers=2, num_splinters=8)
    ck = CkIO(num_pes=4)
    svc = _service(ck, backend="process")
    try:
        fh = ck.open_sync(path, _opts(recovery="reissue", fault_plan=plan))
        sess = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
        view = ck.read_view_sync(sess, len(data), 0, timeout=300)
        assert bytes(view) == data
        del view
        m = sess.metrics.recovery
        assert m.reissues >= 1 and m.reissued_splinters >= 1
        ck.close_read_session_sync(sess)
        ck.close_sync(fh)
        assert svc.metrics.workers_evicted >= 1
        # the pool still serves: a clean session after the crash
        fh2 = ck.open_sync(path, _opts())
        sess2 = ck.start_read_session_sync(fh2, len(data), 0, timeout=120)
        assert bytes(ck.read_view_sync(sess2, len(data), 0,
                                       timeout=120)) == data
        ck.close_read_session_sync(sess2)
        ck.close_sync(fh2)
    finally:
        svc.shutdown()
    assert _shm_leftovers() == []


def test_worker_crash_never_tears_down_sibling_session(data_file):
    """The containment fix: session A (``recovery="none"``) loses its
    pooled worker and fails ALONE with a WorkerCrashed; concurrent sibling
    session B on the same pool completes bit-identically, and only the
    dead worker was evicted."""
    path, data = data_file
    ck = CkIO(num_pes=4)
    svc = _service(ck, backend="process", pool_workers=4, max_sessions=2)
    try:
        fh_bad = ck.open_sync(path, _opts(
            recovery="none",
            worker_fault=CrashReader(reader=0, after=0, code=67)))
        fh_ok = ck.open_sync(path, _opts())
        sess_a = ck.start_read_session_sync(fh_bad, len(data), 0, timeout=120)
        sess_b = ck.start_read_session_sync(fh_ok, len(data), 0, timeout=120)
        with pytest.raises(WorkerCrashed):
            ck.read_sync(sess_a, len(data), 0, timeout=120)
        view = ck.read_view_sync(sess_b, len(data), 0, timeout=120)
        assert bytes(view) == data                # sibling unharmed
        del view
        assert sess_b.metrics.bytes_copied == 0
        ck.close_read_session_sync(sess_a)
        ck.close_read_session_sync(sess_b)
        assert svc.metrics.sessions_failed == 1
        assert svc.metrics.workers_evicted == 1   # only the dead one
        # lazy replacement: the next session still gets a full grant
        sess_c = ck.start_read_session_sync(fh_ok, len(data), 0, timeout=120)
        assert bytes(ck.read_view_sync(sess_c, len(data), 0,
                                       timeout=120)) == data
        ck.close_read_session_sync(sess_c)
        assert svc.pool_size() == 4
        ck.close_sync(fh_bad)
        ck.close_sync(fh_ok)
    finally:
        svc.shutdown()
    assert _shm_leftovers() == []


# -- MPSC hygiene -------------------------------------------------------------
def test_stale_epoch_event_dropped_and_counted(data_file):
    """An event published under an epoch no live session owns (late worker
    of a torn-down generation, or corruption) is dropped + counted — and
    the pool keeps serving normally afterwards."""
    path, data = data_file
    ck = CkIO(num_pes=4)
    svc = _service(ck)
    try:
        fh = ck.open_sync(path, _opts())
        sess = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
        assert bytes(ck.read_view_sync(sess, len(data), 0,
                                       timeout=120)) == data
        ck.close_read_session_sync(sess)
        # Inject into a parked worker's ring: epoch 9999 matches nothing.
        with svc._lock:
            ring = svc._idle[0].ring
        assert ring.publish(RingEvent(
            index=0, reader=0, offset=0, nbytes=4096, arena_off=0,
            t_arrival=0.0, read_dt=0.0, epoch=9999), timeout=5.0)
        deadline = time.monotonic() + 10.0
        while (svc.metrics.stale_events < 1
               and time.monotonic() < deadline):
            time.sleep(0.01)
        assert svc.metrics.stale_events == 1
        # undamaged: the same pool serves the next session
        sess2 = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
        assert bytes(ck.read_view_sync(sess2, len(data), 0,
                                       timeout=120)) == data
        ck.close_read_session_sync(sess2)
        ck.close_sync(fh)
        assert svc.metrics.stale_events == 1      # counted once, not leaked
    finally:
        svc.shutdown()
    assert _shm_leftovers() == []


# -- admission ----------------------------------------------------------------
def test_admission_rejects_with_descriptive_servicebusy(data_file):
    """Inflight cap + queue both full and ``use_service=True`` pins the
    session to the pool: submit raises a ServiceBusy naming the caps, and
    the rejection is counted."""
    path, data = data_file
    ck = CkIO(num_pes=2)
    svc = _service(ck, pool_workers=1, max_sessions=1, max_queue=0)
    try:
        fh = ck.open_sync(path, _opts(
            num_readers=1, max_workers=1, use_service=True))
        sess = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
        with pytest.raises(ServiceBusy, match="saturated"):
            ck.start_read_session_sync(fh, len(data), 0, timeout=120)
        assert svc.metrics.rejected == 1
        assert bytes(ck.read_view_sync(sess, len(data), 0,
                                       timeout=120)) == data
        ck.close_read_session_sync(sess)
        # capacity freed: the pool admits again
        sess2 = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
        ck.close_read_session_sync(sess2)
        ck.close_sync(fh)
    finally:
        svc.shutdown()
    assert _shm_leftovers() == []


def test_saturated_service_falls_back_to_spawn(data_file):
    """With ``use_service`` left at auto, a saturated pool degrades to the
    legacy per-session spawn path: the session completes un-pooled and
    nothing in the service is disturbed."""
    path, data = data_file
    ck = CkIO(num_pes=2)
    svc = _service(ck, pool_workers=1, max_sessions=1, max_queue=0)
    try:
        fh = ck.open_sync(path, _opts(num_readers=1, max_workers=1))
        sess_a = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
        assert sess_a.readers.wait_attached(120.0)
        assert sess_a.metrics.pooled
        sess_b = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
        assert not sess_b.metrics.pooled          # legacy spawn fallback
        assert bytes(ck.read_view_sync(sess_b, len(data), 0,
                                       timeout=120)) == data
        assert sess_b.metrics.bytes_copied == 0
        assert bytes(ck.read_view_sync(sess_a, len(data), 0,
                                       timeout=120)) == data
        ck.close_read_session_sync(sess_b)
        ck.close_read_session_sync(sess_a)
        ck.close_sync(fh)
        assert svc.metrics.rejected == 1
        assert svc.metrics.sessions_failed == 0
        # non-sticky: with capacity back, the next session pools again
        fh2 = ck.open_sync(path, _opts(num_readers=1, max_workers=1))
        sess_c = ck.start_read_session_sync(fh2, len(data), 0, timeout=120)
        assert sess_c.readers.wait_attached(120.0)
        assert sess_c.metrics.pooled
        ck.close_read_session_sync(sess_c)
        ck.close_sync(fh2)
    finally:
        svc.shutdown()
    assert _shm_leftovers() == []


def test_use_service_false_always_spawns(data_file):
    """``use_service=False`` pins to legacy spawn even with a healthy
    service attached."""
    path, data = data_file
    ck = CkIO(num_pes=2)
    svc = _service(ck)
    try:
        fh = ck.open_sync(path, _opts(
            num_readers=1, max_workers=1, use_service=False))
        sess = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
        assert not sess.metrics.pooled
        assert bytes(ck.read_view_sync(sess, len(data), 0,
                                       timeout=120)) == data
        ck.close_read_session_sync(sess)
        ck.close_sync(fh)
        assert svc.metrics.admitted == 0          # never touched the pool
    finally:
        svc.shutdown()
    assert _shm_leftovers() == []
