"""Scheduler semantics: message-driven execution, overlap, quiescence."""
import threading
import time

import pytest

from repro.core.scheduler import BackgroundWorker, QuiescenceTimeout, TaskScheduler


def test_fifo_per_pe_and_round_robin():
    s = TaskScheduler(num_pes=2)
    order = []
    for i in range(3):
        s.enqueue(0, order.append, f"a{i}")
        s.enqueue(1, order.append, f"b{i}")
    s.pump()
    # per-PE FIFO preserved
    assert [x for x in order if x.startswith("a")] == ["a0", "a1", "a2"]
    assert [x for x in order if x.startswith("b")] == ["b0", "b1", "b2"]


def test_run_until_wakes_from_io_thread():
    s = TaskScheduler(num_pes=1)
    done = []

    def io_thread():
        time.sleep(0.05)
        s.enqueue(0, done.append, 1)

    threading.Thread(target=io_thread, daemon=True).start()
    s.run_until(lambda: bool(done), timeout=5)
    assert done == [1]


def test_run_until_timeout():
    s = TaskScheduler(num_pes=1)
    with pytest.raises(QuiescenceTimeout):
        s.run_until(lambda: False, timeout=0.2)


def test_background_worker_yields():
    """Background chares interleave with other tasks (paper Fig. 8 loop)."""
    s = TaskScheduler(num_pes=1)
    w = BackgroundWorker(s, pe=0, grain_us=20)
    w.start()
    seen = []
    s.enqueue(0, seen.append, "task")
    # pump a bounded number of tasks: worker must not starve the queue
    s.pump(max_tasks=10)
    assert seen == ["task"]
    assert w.iterations >= 1
    w.stop()
    s.pump(max_tasks=5)


def test_topology_mapping():
    s = TaskScheduler(num_pes=8, pes_per_node=4)
    assert s.num_nodes == 2
    assert s.node_of(0) == 0 and s.node_of(3) == 0
    assert s.node_of(4) == 1 and s.node_of(7) == 1
