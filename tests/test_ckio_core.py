"""CkIO core behaviour: correctness under arbitrary decomposition, split-phase
semantics, migration, straggler mitigation, concurrent sessions, autotuning."""
import os
import random
import threading

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (
    CkIO,
    CkFuture,
    FileOptions,
    NetworkModel,
    suggest_num_readers,
    AutoTuner,
)
from repro.core.placement import place_readers
from repro.core.scheduler import TaskScheduler


@pytest.fixture(scope="module")
def data_file(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("ckio") / "data.bin")
    rng = np.random.default_rng(42)
    data = rng.integers(0, 256, size=2_000_000, dtype=np.uint8).tobytes()
    with open(path, "wb") as f:
        f.write(data)
    return path, data


def _mk(num_pes=4, **opts):
    return CkIO(num_pes=num_pes, pes_per_node=2), FileOptions(**opts)


def test_whole_file_roundtrip(data_file):
    path, data = data_file
    ck, opts = _mk(num_readers=3, splinter_bytes=128 * 1024)
    fh = ck.open_sync(path, opts)
    assert fh.size == len(data)
    sess = ck.start_read_session_sync(fh, fh.size, 0)
    out = ck.read_sync(sess, fh.size, 0)
    assert bytes(out) == data
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


@settings(max_examples=10, deadline=None)
@given(
    readers=st.integers(1, 9),
    clients=st.integers(1, 40),
    splinter_kib=st.sampled_from([4, 64, 512]),
    seed=st.integers(0, 10**6),
)
def test_any_decomposition_reads_correctly(data_file, readers, clients,
                                           splinter_kib, seed):
    """The paper's core decoupling claim: ANY (readers × consumers) pair
    returns byte-identical data."""
    path, data = data_file
    ck, opts = _mk(num_readers=readers, splinter_bytes=splinter_kib * 1024)
    fh = ck.open_sync(path, opts)
    sess = ck.start_read_session_sync(fh, len(data) // 2, 1000)
    rng = random.Random(seed)
    futs, spans = [], []
    for i in range(clients):
        off = rng.randrange(1000, 1000 + len(data) // 2 - 2)
        n = rng.randrange(1, min(100_000, 1000 + len(data) // 2 - off))
        c = ck.make_client(pe=i % ck.sched.num_pes)
        futs.append(ck.read_future(sess, n, off, client=c))
        spans.append((off, n))
    for f, (off, n) in zip(futs, spans):
        msg = f.wait(ck.sched, timeout=60)
        assert bytes(msg.data) == data[off:off + n]
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


def test_session_offsets_are_absolute(data_file):
    path, data = data_file
    ck, opts = _mk(num_readers=2)
    fh = ck.open_sync(path, opts)
    sess = ck.start_read_session_sync(fh, 100_000, 500_000)
    out = ck.read_sync(sess, 1234, 512_345)
    assert bytes(out) == data[512_345:512_345 + 1234]
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


def test_read_outside_session_rejected(data_file):
    path, _ = data_file
    ck, opts = _mk(num_readers=2)
    fh = ck.open_sync(path, opts)
    sess = ck.start_read_session_sync(fh, 1000, 0)
    with pytest.raises(ValueError):
        ck.read_sync(sess, 10, 995)
    ck.close_read_session_sync(sess)
    with pytest.raises(RuntimeError):
        ck.read_sync(sess, 10, 0)
    ck.close_sync(fh)


def test_greedy_prefetch_before_any_request(data_file):
    """Buffer readers start on session instantiation (paper Fig. 5)."""
    path, data = data_file
    ck, opts = _mk(num_readers=4, splinter_bytes=64 * 1024)
    fh = ck.open_sync(path, opts)
    sess = ck.start_read_session_sync(fh, 1_000_000, 0)
    assert sess.readers.join(timeout=30)       # completes with zero reads issued
    done, total = sess.readers.progress()
    assert done == total > 0
    # a request served from resident data completes without any disk wait
    out = ck.read_sync(sess, 100, 50)
    assert bytes(out) == data[50:150]
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


def test_migration_mid_session(data_file):
    """Paper §IV-A.3: migrate a client between two reads of one session."""
    path, data = data_file
    ck, opts = _mk(num_pes=4, num_readers=2)
    fh = ck.open_sync(path, opts)
    sess = ck.start_read_session_sync(fh, 1_000_000, 0)
    c = ck.make_client(pe=0)
    m1 = ck.read_future(sess, 5000, 100, client=c).wait(ck.sched)
    assert bytes(m1.data) == data[100:5100]
    c.migrate(3)
    assert c.pe == 3
    m2 = ck.read_future(sess, 5000, 600_000, client=c).wait(ck.sched)
    assert bytes(m2.data) == data[600_000:605_000]
    assert ck.locations.migrations == 1
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


def test_straggler_work_stealing(data_file):
    """A delayed reader's splinters get stolen; session finishes fast."""
    path, data = data_file
    delays = {"n": 0}

    def slow_reader_0(reader, splinter):
        if reader == 0:
            delays["n"] += 1
            return 0.05           # 50 ms per splinter for reader 0
        return 0.0

    ck = CkIO(num_pes=2)
    opts = FileOptions(num_readers=4, splinter_bytes=64 * 1024,
                       work_stealing=True, delay_model=slow_reader_0)
    fh = ck.open_sync(path, opts)
    sess = ck.start_read_session_sync(fh, 2_000_000, 0)
    assert sess.readers.join(timeout=30)
    assert sess.metrics.steals > 0, "no splinters were stolen from the straggler"
    out = ck.read_sync(sess, 100_000, 0)
    assert bytes(out) == data[:100_000]
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


def test_concurrent_sessions(data_file):
    path, data = data_file
    ck, opts = _mk(num_readers=2, splinter_bytes=64 * 1024)
    fh = ck.open_sync(path, opts)
    f1, f2 = CkFuture(), CkFuture()
    ck.start_read_session(fh, 500_000, 0, f1)
    ck.start_read_session(fh, 500_000, 1_000_000, f2)
    s1 = f1.wait(ck.sched)
    s2 = f2.wait(ck.sched)
    r1 = ck.read_future(s1, 1000, 100)
    r2 = ck.read_future(s2, 1000, 1_400_000)
    assert bytes(r1.wait(ck.sched).data) == data[100:1100]
    assert bytes(r2.wait(ck.sched).data) == data[1_400_000:1_401_000]
    ck.close_read_session_sync(s1)
    ck.close_read_session_sync(s2)
    ck.close_sync(fh)


def test_callbacks_are_split_phase(data_file):
    """read() must return before the callback runs (no inline completion)."""
    path, _ = data_file
    ck, opts = _mk(num_readers=1)
    fh = ck.open_sync(path, opts)
    sess = ck.start_read_session_sync(fh, 10_000, 0)
    sess.readers.join(timeout=10)   # make data resident -> tempting to inline
    fired = []
    from repro.core import CkCallback

    buf = bytearray(100)
    ck.read(sess, 100, 0, buf, CkCallback(lambda m: fired.append(m), pe=0))
    assert fired == [], "callback ran inline inside read()"
    ck.sched.run_until(lambda: bool(fired), timeout=10)
    assert len(fired) == 1
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


def test_network_model_cross_node_latency():
    net = NetworkModel(bw_bytes_per_s=1e9, latency_s=0.01)
    fired = threading.Event()
    import time

    t0 = time.perf_counter()
    net.deliver(1_000_000, same_node=False, fn=fired.set)
    assert fired.wait(5)
    dt = time.perf_counter() - t0
    assert dt >= 0.01, f"cross-node delivery too fast ({dt})"
    got = []
    net.deliver(100, same_node=True, fn=lambda: got.append(1))
    assert got == [1]            # same-node is immediate
    net.shutdown()


def test_placement_policies():
    sched = TaskScheduler(num_pes=8, pes_per_node=2)  # 4 nodes
    rr = place_readers("round_robin", 6, sched)
    assert rr == [0, 1, 2, 3, 4, 5]
    ns = place_readers("node_spread", 4, sched)
    assert sorted({sched.node_of(p) for p in ns}) == [0, 1, 2, 3]
    nc = place_readers("near_consumers", 4, sched, consumer_pes=[5, 6])
    assert set(nc) <= {5, 6}
    with pytest.raises(ValueError):
        place_readers("nope", 2, sched)


def test_autotune_heuristic_and_online():
    # U-curve bounds: at least 1/node, at most 2/PE, ~1 per 64 MB
    assert suggest_num_readers(1 << 30, num_pes=32, num_nodes=4) == 16
    assert suggest_num_readers(1 << 20, num_pes=32, num_nodes=4) == 4
    assert suggest_num_readers(1 << 40, num_pes=32, num_nodes=4) == 64
    tuner = AutoTuner(num_pes=8, num_nodes=2)
    first = tuner.suggest(1 << 30)
    tuner.record(first, 100.0)
    nxt = tuner.suggest(1 << 30)
    assert nxt != first                     # explores the neighbourhood
    tuner.record(nxt, 500.0)
    assert tuner.best() == nxt
