"""Multi-process reader backend: shm arena, event rings, worker lifecycle.

Covers the ``src/repro/ipc`` subsystem and its ``backend="process"``
integration (``core/buffers.py`` ``ProcessReaderSet``):

* SharedArena create/attach/unlink semantics (zero-copy across mappings);
* EventRing protocol edges: ordering, wraparound under a slow consumer
  (producer throttled, nothing lost/overwritten), stop-vs-publish race;
* worker_main protocol run inline (attach → barrier → drain → DONE, and
  the ERROR reporting path);
* process-backend sessions end-to-end: correctness, consumer-side
  zero-copy (``bytes_copied == 0``), event stream replay, crash fail-fast
  (descriptive error within a bounded timeout — no hang), close racing
  in-flight publishes, and bit-identity with ``backend="thread"`` across
  the host, device and streamed pipeline paths;
* the NetworkModel borrowed-view accounting regression (a view delivery
  is never double-counted as a modeled transfer);
* the streamed per-call ``sharding`` explicit-fallback warning.
"""
from __future__ import annotations

import os
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import (
    CkIO,
    FileOptions,
    NetworkModel,
    ProcessReaderSet,
    WorkerCrashed,
)
from repro.data import CkIOPipeline, make_token_file
from repro.io.layout import plan_session
from repro.io.posix import write_file
from repro.ipc.ring import (
    ST_ATTACHED,
    ST_DONE,
    ST_ERROR,
    ST_INIT,
    EventRing,
    RingEvent,
    ring_bytes,
)
from repro.ipc.shm import SharedArena
from repro.ipc.worker import (
    ExitAfter,
    RaiseAfter,
    StallReader,
    WorkerSpec,
    worker_main,
)

SEED = 20260728


def _shm_leftovers():
    d = "/dev/shm"
    if not os.path.isdir(d):
        return []
    return [n for n in os.listdir(d) if n.startswith("ckio-")]


@pytest.fixture
def data_file(tmp_path):
    rng = np.random.default_rng(SEED)
    data = rng.integers(0, 256, 1_000_000, dtype=np.uint8).tobytes()
    path = str(tmp_path / "ipc_blob.bin")
    write_file(path, data)
    return path, data


@pytest.fixture(scope="module")
def token_file(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("ipc_tokens") / "tokens.bin")
    make_token_file(path, 16 * 128 * 8 + 64, vocab_size=32000, seed=SEED)
    return path


# -- SharedArena --------------------------------------------------------------
def test_shared_arena_create_attach_zero_copy():
    a = SharedArena.create(8192, tag="t")
    try:
        arr = a.ndarray()
        arr[:] = np.arange(8192, dtype=np.uint8) % 251
        b = SharedArena.attach(a.path, 8192)     # second mapping, own fd
        assert bytes(b.buf) == arr.tobytes()
        b.ndarray()[100] = 77                    # writes are shared
        assert arr[100] == 77
        b.close()
    finally:
        a.close()
    assert a.closed
    a.close()                                    # idempotent


def test_shared_arena_unlink_keeps_mapping_alive():
    a = SharedArena.create(4096)
    path = a.path
    b = SharedArena.attach(path, 4096)
    a.unlink()
    assert not os.path.exists(path)
    b.ndarray()[0] = 9                           # mapping survives the name
    assert a.ndarray()[0] == 9
    b.close()
    a.close()


def test_shared_arena_close_tolerates_live_export():
    a = SharedArena.create(4096)
    arr = a.ndarray()
    arr[:4] = [1, 2, 3, 4]
    a.close()                                    # arr pins the mapping
    assert list(arr[:4]) == [1, 2, 3, 4]         # still readable (pinned)


# -- EventRing ----------------------------------------------------------------
def _ev(i: int, nbytes: int = 64) -> RingEvent:
    return RingEvent(index=i, reader=i % 3, offset=i * nbytes, nbytes=nbytes,
                     arena_off=i * nbytes, t_arrival=float(i), read_dt=0.25)


def test_ring_publish_consume_roundtrip():
    buf = memoryview(bytearray(ring_bytes(8)))
    prod = EventRing(buf, 8, create=True)
    cons = EventRing(buf, 8)                     # attach view of same bytes
    for i in range(5):
        assert prod.publish(_ev(i))
    assert cons.pending() == 5
    got = cons.consume()
    assert [e.index for e in got] == list(range(5))
    assert got[2].offset == 2 * 64 and got[2].read_dt == 0.25
    assert cons.pending() == 0
    # sequence continues across the consume
    assert prod.publish(_ev(5))
    assert [e.index for e in cons.consume()] == [5]


def test_ring_header_handshake_fields():
    buf = memoryview(bytearray(ring_bytes(4)))
    ring = EventRing(buf, 4, create=True)
    assert ring.state() == 0
    ring.set_pid(4242)
    ring.set_touch(123, 1)
    ring.set_state(ST_ATTACHED)
    assert ring.pid() == 4242
    assert ring.touch_report() == (123, 1)
    assert ring.state() == ST_ATTACHED
    ring.set_error("boom: " + "x" * 500)         # truncated, NUL-terminated
    assert ring.state() == ST_ERROR
    assert ring.error_message().startswith("boom: xxx")
    buf8 = memoryview(bytearray(ring_bytes(8)))
    EventRing(buf8, 8, create=True)
    with pytest.raises(ValueError, match="capacity mismatch"):
        EventRing(buf8, 6)                       # header disagrees with caller


def test_ring_wraparound_slow_consumer_loses_nothing():
    """A full ring throttles the producer (backoff) — a slow consumer can
    never be lapped; every record arrives exactly once, in order."""
    slots, total = 4, 64
    buf = memoryview(bytearray(ring_bytes(slots)))
    prod = EventRing(buf, slots, create=True)
    cons = EventRing(buf, slots)
    published = []

    def produce():
        for i in range(total):
            assert prod.publish(_ev(i), timeout=30.0)
            published.append(i)

    th = threading.Thread(target=produce)
    th.start()
    got = []
    while len(got) < total:
        time.sleep(0.002)                        # deliberately slow consumer
        batch = cons.consume(limit=2)
        assert cons.pending() <= slots           # never overfilled
        got.extend(e.index for e in batch)
    th.join(10)
    assert not th.is_alive()
    assert got == list(range(total))
    # the producer genuinely had to wait on the consumer at least once
    assert len(published) == total


def test_ring_torn_publication_never_consumed():
    """Weak-memory-ordering guard: a slot whose stamp is visible but whose
    payload bytes are not (simulated by corrupting one byte) fails the
    seq-keyed CRC and is left unconsumed until the payload is coherent."""
    from repro.ipc.ring import HDR_BYTES, MSG_BYTES

    buf = memoryview(bytearray(ring_bytes(4)))
    prod = EventRing(buf, 4, create=True)
    cons = EventRing(buf, 4)
    assert prod.publish(_ev(7))
    payload_off = HDR_BYTES + MSG_BYTES + 8      # slot 0, past the stamp
    original = buf[payload_off]
    buf[payload_off] = original ^ 0xFF           # payload "not visible yet"
    assert cons.consume() == []                  # stamp alone is not enough
    buf[payload_off] = original                  # stores land
    assert [e.index for e in cons.consume()] == [7]


def test_ring_publish_respects_stop_when_full():
    buf = memoryview(bytearray(ring_bytes(2)))
    prod = EventRing(buf, 2, create=True)
    cons = EventRing(buf, 2)
    assert prod.publish(_ev(0)) and prod.publish(_ev(1))
    cons.request_stop()
    assert prod.publish(_ev(2)) is False         # full + stop → drop, no hang
    assert prod.publish(_ev(3), timeout=0.01) is False
    assert [e.index for e in cons.consume()] == [0, 1]


def test_ring_wait_go_gate():
    buf = memoryview(bytearray(ring_bytes(2)))
    prod = EventRing(buf, 2, create=True)
    cons = EventRing(buf, 2)
    released = threading.Event()

    def waiter():
        assert prod.wait_go()
        released.set()

    th = threading.Thread(target=waiter)
    th.start()
    time.sleep(0.01)
    assert not released.is_set()
    cons.open_gate()
    assert released.wait(5)
    th.join(5)
    # stop beats go: a parked worker is released with False
    buf2 = memoryview(bytearray(ring_bytes(2)))
    ring2 = EventRing(buf2, 2, create=True)
    ring2.request_stop()
    assert ring2.wait_go() is False


# -- worker_main protocol (run inline for determinism + coverage) -------------
def _make_spec(path: str, nbytes: int, *, splinter=64 * 1024, fault=None,
               delay=None, prefault=True):
    plan = plan_session(0, nbytes, 2, splinter_bytes=splinter)
    arena = SharedArena.create(plan.nbytes, tag="t-arena")
    rings = SharedArena.create(ring_bytes(64), tag="t-ring")
    ring = EventRing(rings.buf[: ring_bytes(64)], 64, create=True)
    spec = WorkerSpec(
        worker_id=0, file_path=path,
        arena_path=arena.path, arena_bytes=plan.nbytes, base_offset=0,
        ring_path=rings.path, ring_region_bytes=ring_bytes(64),
        ring_offset=0, ring_slots=64,
        splinters=plan.splinters,
        stripe_bounds=plan.stripe_bounds,
        prefault=prefault, pin_cpus=None, delay_model=delay, fault=fault,
    )
    return spec, plan, arena, rings, ring


def test_worker_main_inline_protocol(data_file):
    path, data = data_file
    spec, plan, arena, rings, ring = _make_spec(path, len(data))
    ring.open_gate()                              # supervisor's role
    worker_main(spec)
    assert ring.state() == ST_DONE
    assert ring.pid() == os.getpid()
    pages, pin = ring.touch_report()
    assert pages > 0                              # prefault reported
    events = ring.consume()
    assert len(events) == len(plan.splinters)
    assert sorted(e.index for e in events) == list(range(len(plan.splinters)))
    assert all(e.read_dt >= 0 for e in events)
    assert bytes(arena.ndarray()) == data         # preadv'd into the mapping
    arena.close()
    rings.close()


def test_worker_main_inline_error_path(data_file):
    path, data = data_file
    spec, plan, arena, rings, ring = _make_spec(
        path, len(data), fault=RaiseAfter(1, "synthetic-fault"))
    ring.open_gate()
    with pytest.raises(SystemExit):
        worker_main(spec)
    assert ring.state() == ST_ERROR
    assert "synthetic-fault" in ring.error_message()
    assert len(ring.consume()) == 1               # one splinter made it
    arena.close()
    rings.close()


def test_worker_main_stop_before_go_exits_clean(data_file):
    path, data = data_file
    spec, plan, arena, rings, ring = _make_spec(path, len(data))
    ring.request_stop()                           # cancelled during spawn
    worker_main(spec)
    assert ring.state() == ST_DONE
    assert ring.consume() == []
    arena.close()
    rings.close()


# -- process backend end-to-end ----------------------------------------------
def test_process_backend_end_to_end(data_file):
    path, data = data_file
    ck = CkIO(num_pes=4, pes_per_node=2)
    fh = ck.open_sync(path, FileOptions(
        num_readers=2, splinter_bytes=128 * 1024, backend="process",
        max_workers=2))
    sess = ck.start_read_session_sync(fh, len(data), 0)
    assert isinstance(sess.readers, ProcessReaderSet)

    # event stream: replay sees everything workers published so far
    seen = []
    sess.readers.join(120)
    tok = sess.subscribe_splinters(seen.append)
    assert sorted(e.index for e in seen) == list(
        range(len(sess.plan.splinters)))
    sess.unsubscribe_splinters(tok)
    assert len(sess.arrival_order) == len(sess.plan.splinters)

    # zero-copy in the consumer process: the view aliases the mapped arena
    view = ck.read_view_sync(sess, 300_000, 4096)
    assert bytes(view) == data[4096: 304_096]
    assert sess.metrics.bytes_copied == 0
    # copy path still works cross-process
    out = ck.read_sync(sess, 100_000, 50_000)
    assert bytes(out) == data[50_000:150_000]
    assert sess.metrics.bytes_copied == 100_000
    ck.close_read_session_sync(sess)
    with pytest.raises(ValueError):
        view.tobytes()                            # borrow died with session
    ck.close_sync(fh)
    assert _shm_leftovers() == []


def test_process_backend_bad_backend_rejected():
    with pytest.raises(ValueError, match="unknown reader backend"):
        FileOptions(backend="fiber").reader_options()


def test_process_backend_delay_model_and_metrics(data_file):
    """Picklable delay hook reaches the worker; per-reader metrics flow
    back over the ring (read counts/bytes per planned owner)."""
    path, data = data_file
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(
        num_readers=2, splinter_bytes=256 * 1024, backend="process",
        delay_model=StallReader(reader=0, seconds=0.01)))
    sess = ck.start_read_session_sync(fh, len(data), 0)
    assert sess.readers.join(120)
    m = sess.metrics
    assert m.bytes_read == len(data)
    assert set(m.bytes_per_reader) == {0, 1}
    assert m.read_calls == len(sess.plan.splinters)
    # the stall runs before each of reader 0's reads (2 splinters of its
    # stripe), so it shows up in session wall time, not read_dt — same
    # contract as the thread backend's delay_model
    assert m.ingest_seconds() >= 0.02
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


def test_worker_crash_fails_fast_no_hang(data_file):
    """Acceptance: a worker crash mid-session surfaces a descriptive error
    within a bounded timeout — blocked reads raise instead of hanging."""
    path, data = data_file
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(
        num_readers=2, splinter_bytes=64 * 1024, backend="process",
        max_workers=2, worker_fault=ExitAfter(1, code=43)))
    sess = ck.start_read_session_sync(fh, len(data), 0)
    t0 = time.monotonic()
    with pytest.raises(WorkerCrashed, match="exited with code 43"):
        ck.read_sync(sess, len(data), 0, timeout=60)
    assert time.monotonic() - t0 < 60             # bounded, not a timeout
    with pytest.raises(WorkerCrashed):
        sess.readers.join(10)
    with pytest.raises(WorkerCrashed):
        sess.readers.when_available(0, 1024, lambda: None)
    ck.close_read_session_sync(sess)              # teardown still clean
    ck.close_sync(fh)
    assert _shm_leftovers() == []


def test_worker_crash_fails_every_blocked_future(data_file):
    """EVERY request blocked at crash time gets the error — not only the
    first pump to notice (each request's error channel is fed once)."""
    path, data = data_file
    ck = CkIO(num_pes=2, pes_per_node=1)      # 2 nodes → multi-piece reqs
    fh = ck.open_sync(path, FileOptions(
        num_readers=2, splinter_bytes=64 * 1024, backend="process",
        max_workers=2,
        delay_model=StallReader(reader=1, seconds=0.05),
        worker_fault=ExitAfter(2, code=44)))
    sess = ck.start_read_session_sync(fh, len(data), 0)
    futures = [ck.read_future(sess, len(data), 0),
               ck.read_future(sess, len(data) // 2, 0),
               ck.read_view_future(sess, 1024, len(data) - 2048)]
    for f in futures:
        with pytest.raises(WorkerCrashed, match="exited with code 44"):
            f.wait(ck.sched, timeout=30)
    ck.close_read_session_sync(sess)          # no stale raising tasks left
    ck.close_sync(fh)
    assert _shm_leftovers() == []


def test_worker_orphan_guard_inline(data_file):
    """A worker whose supervisor pid no longer matches exits cleanly
    before reading (the SIGKILLed-parent backstop), and the ring
    publish/wait_go loops honor their abort hooks."""
    path, data = data_file
    spec, plan, arena, rings, ring = _make_spec(path, len(data))
    spec.parent_pid = 2 ** 22 + 17            # nobody's parent
    worker_main(spec)                         # exits before attaching
    assert ring.state() == ST_INIT
    assert ring.consume() == []
    arena.close()
    rings.close()
    # abort hooks: a full ring / closed gate release the producer
    buf = memoryview(bytearray(ring_bytes(1)))
    prod = EventRing(buf, 1, create=True)
    assert prod.publish(_ev(0))
    assert prod.publish(_ev(1), should_abort=lambda: True) is False
    assert prod.wait_go(should_abort=lambda: True) is False


def test_pipeline_worker_crash_close_completes_teardown(token_file):
    """A crash inside a pipeline's (future-less read_notify) sessions:
    get_batch raises, and close() still runs teardown to completion —
    the file fd is really closed and no shm leaks — re-raising any
    prefetched session's error only after cleanup."""
    pipe = CkIOPipeline(
        token_file, 16, 127,
        ckio=CkIO(num_pes=4),
        file_opts=FileOptions(num_readers=2, splinter_bytes=32 * 1024,
                              backend="process", max_workers=2,
                              worker_fault=ExitAfter(0, code=45)))
    with pytest.raises(WorkerCrashed, match="exited with code 45"):
        pipe.get_batch(0)
    try:
        pipe.close()
    except WorkerCrashed:
        pass                        # a prefetched session's error, post-cleanup
    assert pipe.file.posix.closed   # teardown really finished
    assert _shm_leftovers() == []


def test_worker_soft_error_reports_message(data_file):
    path, data = data_file
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(
        num_readers=1, splinter_bytes=256 * 1024, backend="process",
        worker_fault=RaiseAfter(2, "disk-on-fire")))
    sess = ck.start_read_session_sync(fh, len(data), 0)
    with pytest.raises(WorkerCrashed, match="disk-on-fire"):
        ck.read_sync(sess, len(data), 0, timeout=60)
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


def test_session_close_races_inflight_publishes(data_file):
    """Closing a session while workers are still reading/publishing drains
    gracefully (stop request → workers exit between splinters) — no
    deadlock, no leaked segments."""
    path, data = data_file
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(
        num_readers=2, splinter_bytes=16 * 1024, backend="process",
        max_workers=2, delay_model=StallReader(reader=0, seconds=0.002)))
    sess = ck.start_read_session_sync(fh, len(data), 0)
    sess.readers.wait_attached(60)                # mid-drain, not pre-spawn
    t0 = time.monotonic()
    ck.close_read_session_sync(sess, timeout=120)
    assert time.monotonic() - t0 < 60
    assert sess.readers.stop(30)
    ck.close_sync(fh)
    assert _shm_leftovers() == []


def test_spawn_failure_cleans_up_and_propagates(data_file):
    """An unpicklable hook makes spawn fail at session start: the error
    reaches the caller, nothing leaks in /dev/shm, and no half-created
    session lingers in the Director tables."""
    path, data = data_file
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(
        num_readers=2, backend="process",
        delay_model=lambda r, sp: 0.0))        # lambdas can't cross spawn
    with pytest.raises(Exception, match="[Pp]ickl"):
        ck.start_read_session_sync(fh, len(data), 0)
    assert ck.director.sessions == {}
    assert _shm_leftovers() == []
    ck.close_sync(fh)


def test_sequenced_start_failure_releases_sequence_lock(data_file):
    """A failed sequenced start must release the global sequence lock —
    the next sequenced session would otherwise deadlock forever."""
    path, data = data_file
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(
        num_readers=1, backend="process",
        delay_model=lambda r, sp: 0.0))
    with pytest.raises(Exception, match="[Pp]ickl"):
        ck.start_read_session_sync(fh, len(data), 0, sequenced=True)
    fh.opts.delay_model = None                 # fix the options and retry
    sess = ck.start_read_session_sync(fh, len(data), 0, sequenced=True,
                                      timeout=120)
    assert sess.readers.join(120)
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)
    assert _shm_leftovers() == []


def test_process_backend_empty_session(data_file):
    path, _ = data_file
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(backend="process", num_readers=2))
    sess = ck.start_read_session_sync(fh, 0, 0)
    assert sess.readers.join(10)
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)
    assert _shm_leftovers() == []


# -- bit-identity: process vs thread ------------------------------------------
def _pipe(path, backend, streaming=False):
    return CkIOPipeline(
        path, 16, 127,
        ckio=CkIO(num_pes=4),
        file_opts=FileOptions(num_readers=2, splinter_bytes=32 * 1024,
                              backend=backend, max_workers=2),
        streaming=streaming,
    )


def test_host_batches_bit_identical_process_vs_thread(token_file):
    pt, pp = _pipe(token_file, "thread"), _pipe(token_file, "process")
    try:
        for s in range(3):
            xt, yt = pt.get_batch(s)
            xp, yp = pp.get_batch(s)
            np.testing.assert_array_equal(xt, xp)
            np.testing.assert_array_equal(yt, yp)
        assert pp.ingest.summary()["host_permute_bytes"] > 0  # host path
    finally:
        pt.close()
        pp.close()
    assert _shm_leftovers() == []


def test_device_batches_bit_identical_process_vs_thread(token_file):
    """Whole-window AND streamed device ingest: backend="process" must be
    bit-identical to the thread backend (the acceptance gate's equality
    half; perf_shm.py re-proves it at benchmark scale)."""
    whole_t, whole_p = _pipe(token_file, "thread"), _pipe(token_file, "process")
    strm_p = _pipe(token_file, "process", streaming=True)
    try:
        for s in range(2):
            xt, yt = whole_t.get_batch_device(s)
            xp, yp = whole_p.get_batch_device(s)
            xs, ys = strm_p.get_batch_device(s)
            np.testing.assert_array_equal(np.asarray(xt), np.asarray(xp))
            np.testing.assert_array_equal(np.asarray(yt), np.asarray(yp))
            np.testing.assert_array_equal(np.asarray(xt), np.asarray(xs))
            np.testing.assert_array_equal(np.asarray(yt), np.asarray(ys))
        # streamed staging really consumed cross-process ring events
        assert strm_p.stream.summary()["splinters_staged"] > 0
        assert strm_p.ingest.summary()["host_permute_bytes"] == 0
    finally:
        whole_t.close()
        whole_p.close()
        strm_p.close()
    assert _shm_leftovers() == []


# -- NetworkModel borrowed-view accounting regression -------------------------
class _CountingNet(NetworkModel):
    def __init__(self):
        super().__init__(bw_bytes_per_s=1e12, latency_s=1e-6)
        self.modeled = []

    def deliver(self, nbytes, same_node, fn):
        if not same_node:
            self.modeled.append(nbytes)
        super().deliver(nbytes, same_node, fn)


def test_borrowed_view_not_double_counted_as_transfer(data_file):
    """Regression (shm groundwork): a cross-node piece delivered as a
    same-address-space view must not count as a modeled transfer AND a
    zero-copy delivery. Pinned: copy deliveries keep cross_node_bytes and
    the NetworkModel transfer; view deliveries move those bytes to
    cross_node_view_bytes, skip the model, and copy nothing."""
    path, data = data_file
    net = _CountingNet()
    ck = CkIO(num_pes=2, pes_per_node=1)          # 2 nodes, client on node 0
    fh = ck.open_sync(path, FileOptions(
        num_readers=2, splinter_bytes=128 * 1024, network=net))
    n = len(data)
    sess = ck.start_read_session_sync(fh, n, 0)
    half = n // 2                                 # reader 1's stripe ≈ [half, n)

    out = ck.read_sync(sess, n, 0)                # copy path
    assert bytes(out) == data
    m = sess.metrics
    copied_cross = m.cross_node_bytes
    assert copied_cross > 0                       # node-1 stripe crossed
    assert m.cross_node_view_bytes == 0
    assert m.bytes_copied == n
    assert sum(net.modeled) == copied_cross       # model saw exactly those

    view = ck.read_view_sync(sess, n - half, half)  # borrowed-view path
    assert bytes(view) == data[half:]
    # reader 1's (cross-node) stripe starts on the aligned boundary
    cross_view = n - sess.plan.stripe_bounds[1][0]
    assert m.cross_node_bytes == copied_cross     # unchanged: no transfer
    assert m.cross_node_view_bytes == cross_view  # locality signal preserved
    assert m.bytes_copied == n                    # nothing copied
    assert sum(net.modeled) == copied_cross       # model never invoked
    summary = m.summary()
    assert summary["cross_node_view_bytes"] == float(cross_view)
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)
    net.shutdown()


# -- streamed per-call sharding: explicit fallback ----------------------------
def test_streamed_sharding_fallback_warns_once(token_file):
    import jax

    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    ps = _pipe(token_file, "thread", streaming=True)
    pw = _pipe(token_file, "thread", streaming=False)
    try:
        # branch 1: no sharding → streamed path, no warning
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            x0, y0 = ps.get_batch_device(0)
        # branch 2: per-call sharding → whole-window fallback + one warning
        with pytest.warns(RuntimeWarning, match="whole-window"):
            x1, y1 = ps.get_batch_device(1, sharding=sharding)
        with warnings.catch_warnings():           # warned ONCE per pipeline
            warnings.simplefilter("error")
            x2, y2 = ps.get_batch_device(2, sharding=sharding)
        for s, (x, y) in enumerate([(x0, y0), (x1, y1), (x2, y2)]):
            xr, yr = pw.get_batch_device(s)
            np.testing.assert_array_equal(np.asarray(x), np.asarray(xr))
            np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    finally:
        ps.close()
        pw.close()
