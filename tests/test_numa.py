"""Topology-aware reader runtime: NUMA model, placement-policy regressions,
first-touch arena striping, cross-domain accounting, per-reader adaptive
splinter sizing.

The placement regressions pin the two historical bugs: ``node_spread``
clamping overflow readers onto the last PE (duplicate placement before all
PEs were used) and ``near_consumers`` accepting out-of-range consumer PEs
that later indexed a nonexistent scheduler queue.
"""
import os
import threading

import numpy as np
import pytest

from repro.core import (
    CkIO,
    FileOptions,
    LocalityMetrics,
    SessionMetrics,
    SplinterSizer,
    Topology,
)
from repro.core.placement import place_readers
from repro.core.scheduler import TaskScheduler
from repro.io.layout import plan_session, pieces_for_range
from repro.io.numa import (
    current_cpus,
    detect_numa_domains,
    first_touch,
    parse_cpulist,
    pin_thread_to_cpus,
)
from repro.io.posix import DEFAULT_ALIGN, aligned_floor


@pytest.fixture(scope="module")
def data_file(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("numa") / "data.bin")
    rng = np.random.default_rng(7)
    data = rng.integers(0, 256, size=1_000_000, dtype=np.uint8).tobytes()
    with open(path, "wb") as f:
        f.write(data)
    return path, data


# -- io/numa helpers ----------------------------------------------------------

def test_parse_cpulist():
    assert parse_cpulist("0-3,8,10-11") == {0, 1, 2, 3, 8, 10, 11}
    assert parse_cpulist("5") == {5}
    assert parse_cpulist("") == set()
    with pytest.raises(ValueError):
        parse_cpulist("x-y")
    with pytest.raises(ValueError):
        parse_cpulist("7-3")


def test_detect_numa_domains_nonempty():
    doms = detect_numa_domains()
    assert doms and all(len(d) >= 1 for d in doms)
    # every CPU id is a non-negative int
    assert all(c >= 0 for d in doms for c in d)


def test_first_touch_counts_pages():
    arr = np.empty(10 * 4096 + 1, dtype=np.uint8)
    assert first_touch(arr, page_bytes=4096) == 11
    assert first_touch(np.empty(0, dtype=np.uint8)) == 0
    # memoryview input works too (the arena stripe path)
    assert first_touch(memoryview(bytearray(4096)), page_bytes=4096) == 1


def test_pin_thread_roundtrip():
    before = current_cpus()
    if not hasattr(os, "sched_setaffinity") or not before:
        pytest.skip("no sched_setaffinity on this platform")
    one = sorted(before)[:1]
    try:
        assert pin_thread_to_cpus(one)
        assert current_cpus() == set(one)
    finally:
        pin_thread_to_cpus(sorted(before))
    assert not pin_thread_to_cpus([])          # empty mask: refused


# -- Topology model -----------------------------------------------------------

def test_topology_domain_mapping():
    t = Topology(num_pes=8, pes_per_node=4, domains_per_node=2)
    assert [t.domain_of(p) for p in range(8)] == [0, 0, 1, 1, 2, 2, 3, 3]
    assert t.num_nodes == 2 and t.num_domains == 4
    assert t.pes_in_domain(2) == [4, 5]
    assert t.cpus_of_domain(0) is None         # no CPU map given
    with pytest.raises(ValueError):
        t.domain_of(8)
    with pytest.raises(ValueError):
        Topology(num_pes=4, pes_per_node=2, domains_per_node=3)
    with pytest.raises(ValueError):
        Topology(num_pes=0)


def test_topology_uneven_last_node():
    # 6 PEs, 4 per node: node 1 holds only PEs 4-5
    t = Topology(num_pes=6, pes_per_node=4, domains_per_node=2)
    assert [t.domain_of(p) for p in range(6)] == [0, 0, 1, 1, 2, 2]
    assert t.pes_in_domain(3) == []            # empty trailing domain


def test_topology_from_spec_and_detect():
    t = Topology.from_spec("2", num_pes=8, pes_per_node=4)
    assert t.domains_per_node == 2
    # clamped to pes_per_node
    t1 = Topology.from_spec("16", num_pes=4, pes_per_node=2)
    assert t1.domains_per_node == 2
    with pytest.raises(ValueError):
        Topology.from_spec("fast", num_pes=4)
    auto = Topology.from_spec("auto", num_pes=4, pes_per_node=4)
    assert auto.num_domains >= 1
    # detection attaches a CPU map usable for pinning
    assert all(auto.cpus_of_domain(d)
               for d in range(auto.num_domains))


def test_topology_from_sched():
    sched = TaskScheduler(num_pes=8, pes_per_node=2)
    t = Topology.from_sched(sched, domains_per_node=5)   # clamped to 2
    assert t.domains_per_node == 2
    assert t.num_domains == 8


# -- placement regressions ----------------------------------------------------

def test_node_spread_no_duplicates_on_uneven_topologies():
    # Historical bug: node*ppn+slot clamped to num_pes-1 piled overflow
    # readers onto the last PE when nodes*ppn != num_pes.
    for num_pes, ppn in [(5, 2), (6, 4), (7, 3), (8, 8), (3, 1)]:
        sched = TaskScheduler(num_pes=num_pes, pes_per_node=ppn)
        for num_readers in (1, num_pes - 1, num_pes, num_pes + 3,
                            3 * num_pes):
            if num_readers < 1:
                continue
            pes = place_readers("node_spread", num_readers, sched)
            assert len(pes) == num_readers
            assert all(0 <= p < num_pes for p in pes)
            # no PE repeats before every PE has been used once
            head = pes[:num_pes]
            assert len(set(head)) == len(head), (
                f"duplicate before exhaustion: pes={pes} "
                f"num_pes={num_pes} ppn={ppn}")
            if num_readers >= num_pes:
                assert set(head) == set(range(num_pes))


def test_node_spread_spreads_nodes_first():
    sched = TaskScheduler(num_pes=8, pes_per_node=2)     # 4 nodes
    pes = place_readers("node_spread", 4, sched)
    assert sorted({sched.node_of(p) for p in pes}) == [0, 1, 2, 3]


def test_domain_spread_covers_domains_first():
    sched = TaskScheduler(num_pes=8, pes_per_node=4)
    topo = Topology(num_pes=8, pes_per_node=4, domains_per_node=2)
    pes = place_readers("domain_spread", 4, sched, topology=topo)
    assert sorted(topo.domain_of(p) for p in pes) == [0, 1, 2, 3]
    # wraps without duplicates before exhaustion
    pes8 = place_readers("domain_spread", 8, sched, topology=topo)
    assert set(pes8) == set(range(8))
    # without a topology, defaults to one domain per node (== node_spread)
    assert place_readers("domain_spread", 4, sched) == \
        place_readers("node_spread", 4, sched)


def test_place_readers_rejects_mismatched_topology():
    # A topology over a different PE grid would emit reader PEs indexing
    # nonexistent scheduler queues; every session start goes through
    # place_readers, so the mismatch fails fast for every policy.
    sched = TaskScheduler(num_pes=4, pes_per_node=2)
    topo = Topology(num_pes=8, pes_per_node=4)
    for policy in ("round_robin", "node_spread", "domain_spread",
                   "near_consumers"):
        with pytest.raises(ValueError, match="topology covers"):
            place_readers(policy, 2, sched, consumer_pes=[0],
                          topology=topo)


def test_topology_domain_cpus_length_validated():
    with pytest.raises(ValueError, match="domain_cpus"):
        Topology(num_pes=8, pes_per_node=4, domains_per_node=2,
                 domain_cpus=((0,), (1,), (0,)))   # 3 sets for 4 domains
    t = Topology(num_pes=8, pes_per_node=4, domains_per_node=2,
                 domain_cpus=((0,), (1,), (0,), (1,)))
    assert t.cpus_of_domain(3) == (1,)


def test_coalescing_never_merges_across_scheduler_nodes(data_file):
    """A topology domain spanning scheduler nodes must not coalesce pieces
    across the node boundary (a merged piece is attributed to its first
    reader and would skip cross-node transfer accounting)."""
    path, data = data_file
    # 2 scheduler nodes; topology: one domain over all 4 PEs.
    ck = CkIO(num_pes=4, pes_per_node=2)
    topo = Topology(num_pes=4, pes_per_node=4, domains_per_node=1)
    opts = FileOptions(num_readers=4, splinter_bytes=32 * 1024,
                       placement="node_spread", topology=topo)
    f = ck.open_sync(path, opts)
    n = 256 * 1024
    sess = ck.start_read_session_sync(f, n, 0)
    # half the readers sit on scheduler node 1, away from the PE-0 client
    assert {ck.sched.node_of(p) for p in sess.reader_pes} == {0, 1}
    out = ck.read_sync(sess, n, 0, client=ck.make_client(pe=0))
    assert bytes(out) == data[:n]
    assert sess.metrics.cross_node_bytes > 0   # node-1 stripes stayed split
    # single shared domain -> deliveries are domain-local by definition
    assert sess.locality.summary()["cross_domain_bytes"] == 0
    ck.close_read_session_sync(sess)
    ck.close_sync(f)


def test_near_consumers_validates_pe_range():
    sched = TaskScheduler(num_pes=4, pes_per_node=2)
    with pytest.raises(ValueError, match="out of range"):
        place_readers("near_consumers", 2, sched, consumer_pes=[1, 7])
    with pytest.raises(ValueError, match="out of range"):
        place_readers("near_consumers", 2, sched, consumer_pes=[-1])


def test_near_consumers_topology_spreads_over_consumer_domains():
    sched = TaskScheduler(num_pes=8, pes_per_node=4)
    topo = Topology(num_pes=8, pes_per_node=4, domains_per_node=2)
    # consumers in domain 0 (PEs 0-1): readers use both its PEs, nothing
    # outside the domain
    pes = place_readers("near_consumers", 4, sched, consumer_pes=[0, 0, 1],
                        topology=topo)
    assert set(pes) == {0, 1}
    assert all(topo.domain_of(p) == 0 for p in pes)
    # without topology: exact consumer-PE cycling (legacy behaviour)
    legacy = place_readers("near_consumers", 4, sched, consumer_pes=[5, 6])
    assert legacy == [5, 6, 5, 6]


# -- per-reader splinter plans ------------------------------------------------

def test_plan_session_per_reader_splinter_sizes():
    plan = plan_session(0, 1 << 20, 4, splinter_bytes=256 * 1024,
                        reader_splinter_bytes=[64 * 1024, 256 * 1024,
                                               128 * 1024, 256 * 1024])
    # stripes partition the session regardless of per-reader sizes
    assert plan.stripe_bounds[0][0] == 0
    assert plan.stripe_bounds[-1][1] == 1 << 20
    # every byte in exactly one splinter, in file order
    pos = 0
    for s in plan.splinters:
        assert s.offset == pos
        pos += s.nbytes
    assert pos == 1 << 20
    # reader 0 cut fine, reader 1 coarse
    s0 = [s.nbytes for s in plan.splinters_for_reader(0)]
    s1 = [s.nbytes for s in plan.splinters_for_reader(1)]
    assert max(s0) == 64 * 1024 and max(s1) == 256 * 1024
    assert plan.reader_splinter_bytes == (64 * 1024, 256 * 1024,
                                          128 * 1024, 256 * 1024)
    with pytest.raises(ValueError, match="entries for"):
        plan_session(0, 1 << 20, 4, reader_splinter_bytes=[4096])


def test_plan_session_uniform_unchanged():
    plan = plan_session(0, 1 << 20, 4, splinter_bytes=256 * 1024)
    assert plan.reader_splinter_bytes is None


# -- SplinterSizer: per-reader + alignment clamp ------------------------------

def _straggler_metrics(num_readers=4, slow=0, reads=8,
                       nbytes=1 << 20) -> SessionMetrics:
    m = SessionMetrics()
    m.session_started(num_readers * reads * nbytes, num_readers)
    for r in range(num_readers):
        per_read_s = 0.050 if r == slow else 0.002
        for _ in range(reads):
            m.record_read(r, nbytes, per_read_s)
    for _ in range(reads // 2):          # half the straggler's tail stolen
        m.record_steal(slow)
    return m


def test_sizer_per_reader_straggler_gets_fine_splinters():
    sz = SplinterSizer(min_bytes=4096)
    for _ in range(3):
        sz.record_session(_straggler_metrics(slow=0))
    sizes = sz.suggest_per_reader(4, 8 << 20)
    assert sizes is not None and len(sizes) == 4
    assert sizes[0] < min(sizes[1:]), sizes    # straggling stripe alone fine
    assert all(s % DEFAULT_ALIGN == 0 for s in sizes)
    # readers beyond the observed set fall back to the session-level size
    sizes6 = sz.suggest_per_reader(6, 8 << 20)
    assert sizes6[4] == sizes6[5] == sz.suggest(8 << 20)


def test_sizer_per_reader_converges():
    sz = SplinterSizer(min_bytes=4096)
    prev = None
    for i in range(8):
        sz.record_session(_straggler_metrics(slow=0))
        cur = sz.suggest_per_reader(4, 8 << 20)
        if i >= 5:                       # EMA settled: suggestions stable
            assert cur == prev
        prev = cur


def test_sizer_no_observations_returns_none():
    assert SplinterSizer().suggest_per_reader(4, 8 << 20) is None


def test_sizer_alignment_floor_with_unaligned_min_bytes():
    # Historical bug: min_bytes below the 256 KiB quantum escaped the
    # quantization and could emit sub-block sizes, breaking preadv
    # alignment. The FS-block floor now applies last, unconditionally.
    sz = SplinterSizer(min_bytes=1000)
    slow = SessionMetrics()
    slow.session_started(1 << 20, 1)
    slow.record_read(0, 1024, 1.0)                # ~1 KB/s
    sz.record_session(slow)
    got = sz.suggest(8 << 20)
    assert got % DEFAULT_ALIGN == 0 and got >= DEFAULT_ALIGN
    assert aligned_floor(1000) == DEFAULT_ALIGN
    assert aligned_floor(10000) == 8192


def test_adaptive_sessions_pick_up_per_reader_sizes(data_file):
    """End-to-end: after straggler sessions, the next adaptive plan carries
    per-reader splinter sizes, driven by real per-stripe steal pressure.

    (The injected delay sleeps outside the timed pread, so per-reader
    *bandwidth* stays cache-speed and jittery on this container — the
    deterministic straggler signal here is splinters stolen from reader 0;
    the strict size-ordering under controlled metrics is covered by
    ``test_sizer_per_reader_straggler_gets_fine_splinters``.)"""
    path, data = data_file
    ck = CkIO(num_pes=4, pes_per_node=2)
    ck.director.splinter_sizer.min_bytes = 4096
    delay = {"on": True}

    def delays(r, sp):
        return 0.02 if (r == 0 and delay["on"]) else 0.0

    # Two readers: the no-delay reader drains its stripe in microseconds
    # and then steals the sleeping straggler's tail — steal direction is
    # deterministic (the straggler never sees a non-empty victim queue).
    opts = FileOptions(num_readers=2, splinter_bytes=32 * 1024,
                       adaptive_splinters=True, delay_model=delays)
    f = ck.open_sync(path, opts)
    for _ in range(2):
        s = ck.start_read_session_sync(f, 512 * 1024, 0)
        assert s.readers.join(60.0)
        ck.close_read_session_sync(s)
    delay["on"] = False
    sizer = ck.director.splinter_sizer
    stealfrac = {r: st.steal_frac for r, st in sizer.per_reader.items()}
    assert stealfrac[0] > 0                        # straggler was stolen from
    assert stealfrac.get(1, 0.0) == 0.0
    s = ck.start_read_session_sync(f, 512 * 1024, 0)
    sizes = s.plan.reader_splinter_bytes
    assert sizes is not None and len(sizes) == 2
    assert all(x % DEFAULT_ALIGN == 0 for x in sizes)
    # correctness is untouched by per-reader sizes
    out = ck.read_sync(s, 512 * 1024, 0)
    assert bytes(out) == data[:512 * 1024]
    ck.close_read_session_sync(s)
    ck.close_sync(f)


# -- cross-domain accounting + first-touch striping ---------------------------

def _run_session(ck, path, opts, consumer_pe, nbytes=256 * 1024):
    f = ck.open_sync(path, opts)
    sess = ck.start_read_session_sync(f, nbytes, 0,
                                      consumer_pes=[consumer_pe])
    client = ck.make_client(pe=consumer_pe)
    view = ck.read_view_sync(sess, nbytes, 0, client=client)
    got = bytes(view)
    loc = dict(sess.locality.summary())
    bytes_copied = sess.metrics.bytes_copied
    ck.close_read_session_sync(sess)
    ck.close_sync(f)
    return got, loc, bytes_copied


def test_cross_domain_bytes_blind_vs_aware(data_file):
    path, data = data_file
    topo = Topology(num_pes=8, pes_per_node=4, domains_per_node=2)
    n = 256 * 1024

    # Locality-blind spread: readers land across all 4 domains while the
    # consumer sits in domain 0 -> most delivered bytes are cross-domain.
    ck = CkIO(num_pes=8, pes_per_node=4)
    blind = FileOptions(num_readers=4, splinter_bytes=32 * 1024,
                        placement="domain_spread", topology=topo)
    got, loc_blind, copied = _run_session(ck, path, blind, consumer_pe=0,
                                          nbytes=n)
    assert got == data[:n]
    assert copied == 0                              # borrowed-view delivery
    assert loc_blind["cross_domain_bytes"] > 0

    # NUMA-aware: readers on the consumer's domain -> zero cross-domain.
    ck2 = CkIO(num_pes=8, pes_per_node=4)
    near = FileOptions(num_readers=4, splinter_bytes=32 * 1024,
                       placement="near_consumers", topology=topo)
    got2, loc_near, copied2 = _run_session(ck2, path, near, consumer_pe=0,
                                           nbytes=n)
    assert got2 == data[:n]
    assert copied2 == 0
    assert loc_near["cross_domain_bytes"] == 0
    assert loc_near["same_domain_bytes"] == n


def test_pieces_coalesce_by_domain_not_node():
    # 4 stripes; readers 0,1 share a domain, 2,3 share the other but all
    # share one node: node-coalescing would merge all 4, domain-coalescing
    # merges into exactly 2 pieces.
    plan = plan_session(0, 4 * 8192, 4, splinter_bytes=4096, align=1)
    domain_of_reader = [0, 0, 1, 1]
    pieces = pieces_for_range(plan, 0, 4 * 8192,
                              coalesce_key=lambda r: domain_of_reader[r])
    assert len(pieces) == 2
    assert pieces[0][2] == pieces[1][2] == 2 * 8192


def test_first_touch_prefault_and_locality_merge(data_file):
    path, data = data_file
    topo = Topology.from_spec("auto", num_pes=4, pes_per_node=4)
    ck = CkIO(num_pes=4, pes_per_node=4)
    opts = FileOptions(num_readers=2, splinter_bytes=64 * 1024,
                       topology=topo, prefault_arena=True, numa_pin=True)
    f = ck.open_sync(path, opts)
    n = 256 * 1024
    sess = ck.start_read_session_sync(f, n, 0)
    out = ck.read_sync(sess, n, 0)
    assert bytes(out) == data[:n]
    loc = sess.locality.summary()
    # every stripe page was first-touch-faulted by its reader thread
    assert loc["prefault_pages"] >= n // 4096
    # pinning was attempted per thread (best-effort: either outcome counts)
    assert loc["pinned_threads"] + loc["pin_failures"] >= 1
    ck.close_read_session_sync(sess)
    # director aggregate picked the session's counters up on close
    agg = ck.director.locality.summary()
    assert agg["prefault_pages"] == loc["prefault_pages"]
    assert agg["readers_observed"] >= 1
    ck.close_sync(f)


def test_thread_owning_multiple_domains_touches_each_on_its_own(data_file):
    """One I/O thread owning stripes in several domains (pool smaller than
    the reader count) must re-pin per stripe domain while touching."""
    path, data = data_file
    topo = Topology.with_host_cpus(4, pes_per_node=4, domains_per_node=2)
    assert topo.cpus_of_domain(1)            # host CPU sets attached
    ck = CkIO(num_pes=4, pes_per_node=4)
    opts = FileOptions(num_readers=4, max_io_threads=1,   # 1 thread, 4 stripes
                       splinter_bytes=32 * 1024, placement="domain_spread",
                       topology=topo, prefault_arena=True, numa_pin=True)
    f = ck.open_sync(path, opts)
    n = 256 * 1024
    sess = ck.start_read_session_sync(f, n, 0)
    out = ck.read_sync(sess, n, 0)
    assert bytes(out) == data[:n]
    loc = sess.locality.summary()
    assert loc["prefault_pages"] >= n // 4096
    # one thread -> exactly one pin record, whatever the number of
    # per-domain re-pins along the way (the counter is a thread count)
    assert loc["pinned_threads"] + loc["pin_failures"] == 1
    ck.close_read_session_sync(sess)
    ck.close_sync(f)


def test_streaming_locality_not_double_counted(tmp_path):
    """Streamed windows are classified once (per splinter event), not a
    second time by the whole-window residency probe: classified bytes in
    streaming mode equal the non-streaming total, not 2x."""
    from repro.data import CkIOPipeline, make_token_file

    path = str(tmp_path / "tok3.bin")
    # Exactly 3 step windows (4 rows x 65 tokens each): no prefetch
    # session beyond the fetched steps, so the classified-byte totals are
    # deterministic (a longer corpus would leave prefetched sessions'
    # classification racing close()).
    make_token_file(path, 3 * 4 * 65, vocab_size=64, seed=9)
    topo = Topology(num_pes=4, pes_per_node=4, domains_per_node=2)

    def classified(streaming):
        pipe = CkIOPipeline(
            path, global_batch=4, seq_len=64, num_pes=4, num_consumers=8,
            consumer_pes=[0, 1], streaming=streaming,
            file_opts=FileOptions(num_readers=2, splinter_bytes=32 * 1024,
                                  placement="near_consumers",
                                  topology=topo),
        )
        for s in range(3):
            pipe.get_batch_device(s)
        pipe.close()
        agg = pipe.ck.director.locality.summary()
        return agg["same_domain_bytes"] + agg["cross_domain_bytes"]

    whole, streamed = classified(False), classified(True)
    window = 4 * 65 * 4                       # bytes per step window
    assert whole == streamed == 3 * window, (whole, streamed)


def test_prefault_without_topology_keeps_zero_fill(data_file):
    """Legacy contract (perf_hotpath's 'before'): no topology -> prefault
    is the seed's whole-arena zero-fill, no locality prefault counters."""
    path, data = data_file
    ck = CkIO(num_pes=2)
    opts = FileOptions(num_readers=2, splinter_bytes=64 * 1024,
                       prefault_arena=True)
    f = ck.open_sync(path, opts)
    sess = ck.start_read_session_sync(f, 128 * 1024, 0)
    out = ck.read_sync(sess, 128 * 1024, 0)
    assert bytes(out) == data[:128 * 1024]
    assert sess.locality.summary()["prefault_pages"] == 0
    ck.close_read_session_sync(sess)
    ck.close_sync(f)


def test_locality_metrics_merge_and_hist():
    a, b = LocalityMetrics(), LocalityMetrics()
    a.record_delivery(100, True)
    a.record_splinter(0, 4096)
    b.record_delivery(50, False)
    b.record_splinter(0, 4096)
    b.record_splinter(1, 8192)
    b.record_prefault(3)
    b.record_pin(True)
    b.record_pin(False)
    a.merge(b)
    s = a.summary()
    assert s["same_domain_bytes"] == 100 and s["cross_domain_bytes"] == 50
    assert s["prefault_pages"] == 3
    assert s["pinned_threads"] == 1 and s["pin_failures"] == 1
    assert a.splinter_hist[0][4096] == 2
    assert a.reader_splinter_sizes() == {0: [4096], 1: [8192]}
    assert 0 < a.cross_domain_fraction() < 1


def test_session_metrics_per_reader_counters():
    m = SessionMetrics()
    m.session_started(1 << 20, 2)
    m.record_read(0, 4096, 0.5)
    m.record_read(0, 4096, 0.5)
    m.record_read(1, 8192, 0.1)
    m.record_steal(0)
    assert m.reads_per_reader == {0: 2, 1: 1}
    assert m.read_time_per_reader[0] == pytest.approx(1.0)
    assert m.steals_from_reader == {0: 1}
    assert m.steals == 1


# -- pipeline integration -----------------------------------------------------

def test_pipeline_consumer_pes_pinning(tmp_path):
    from repro.data import CkIOPipeline, make_token_file

    path = str(tmp_path / "tok.bin")
    make_token_file(path, 20_000, vocab_size=128, seed=3)
    topo = Topology(num_pes=4, pes_per_node=4, domains_per_node=2)
    pipe = CkIOPipeline(
        path, global_batch=4, seq_len=64, num_pes=4, num_consumers=8,
        consumer_pes=[0, 1],
        file_opts=FileOptions(num_readers=2, splinter_bytes=32 * 1024,
                              placement="near_consumers", topology=topo,
                              prefault_arena=True),
    )
    assert {c.pe for c in pipe.consumers} == {0, 1}
    raw = np.fromfile(path, dtype=np.uint32, offset=4096).view(np.int32)
    need = 4 * 65
    for s in range(3):
        x, y = pipe.get_batch(s)
        ref = raw[s * need:(s + 1) * need].reshape(4, 65)
        np.testing.assert_array_equal(np.asarray(x), ref[:, :-1])
    pipe.resize(12)                      # growth respects the pinning
    assert {c.pe for c in pipe.consumers} == {0, 1}
    pipe.close()
    # consumers and readers shared domain 0 -> no cross-domain deliveries
    agg = pipe.ck.director.locality.summary()
    assert agg["cross_domain_bytes"] == 0
    assert agg["same_domain_bytes"] > 0
    with pytest.raises(ValueError, match="out of range"):
        CkIOPipeline(path, global_batch=4, seq_len=64, num_pes=2,
                     consumer_pes=[5])


def test_pipeline_streamed_bit_identity_with_topology(tmp_path):
    from repro.data import CkIOPipeline, make_token_file

    path = str(tmp_path / "tok2.bin")
    make_token_file(path, 30_000, vocab_size=256, seed=5)
    topo = Topology(num_pes=4, pes_per_node=4, domains_per_node=2)

    def mk(streaming):
        return CkIOPipeline(
            path, global_batch=4, seq_len=64, num_pes=4, num_consumers=8,
            consumer_pes=[0, 1], streaming=streaming,
            file_opts=FileOptions(num_readers=2, splinter_bytes=32 * 1024,
                                  placement="near_consumers", topology=topo,
                                  prefault_arena=True),
        )

    pipes = [mk(False), mk(True)]
    for s in range(3):
        (wx, wy), (sx, sy) = (p.get_batch_device(s) for p in pipes)
        np.testing.assert_array_equal(np.asarray(wx), np.asarray(sx))
        np.testing.assert_array_equal(np.asarray(wy), np.asarray(sy))
    for p in pipes:
        assert p.ingest.summary()["host_permute_bytes"] == 0
        p.close()
    # Streamed deliveries are classified too (read_stream records them):
    # same-domain placement means zero cross-domain bytes on both paths.
    for p in pipes:
        agg = p.ck.director.locality.summary()
        assert agg["same_domain_bytes"] > 0
        assert agg["cross_domain_bytes"] == 0
