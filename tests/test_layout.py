"""Stripe/splinter layout math: unit + hypothesis property tests."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.io.layout import (
    plan_session,
    pieces_for_range,
    splinters_covering,
)


def test_basic_plan():
    plan = plan_session(0, 1000, 4, splinter_bytes=4096, align=1)
    assert plan.num_readers == 4
    assert plan.stripe_bounds[0][0] == 0
    assert plan.stripe_bounds[-1][1] == 1000
    # stripes partition the session
    for (s0, e0), (s1, e1) in zip(plan.stripe_bounds, plan.stripe_bounds[1:]):
        assert e0 == s1


def test_empty_session():
    plan = plan_session(10, 0, 4)
    assert plan.splinters == ()
    assert plan.nbytes == 0


def test_more_readers_than_bytes():
    plan = plan_session(0, 3, 8, align=1)
    total = sum(e - s for s, e in plan.stripe_bounds)
    assert total == 3


@settings(max_examples=200, deadline=None)
@given(
    offset=st.integers(0, 10**9),
    nbytes=st.integers(1, 10**7),
    readers=st.integers(1, 64),
    splinter=st.integers(1, 20) ,
)
def test_stripes_partition_property(offset, nbytes, readers, splinter):
    plan = plan_session(offset, nbytes, readers,
                        splinter_bytes=splinter * 4096)
    # property 1: stripes tile [offset, offset+nbytes) exactly
    cur = offset
    for s, e in plan.stripe_bounds:
        assert s == cur and e >= s
        cur = e
    assert cur == offset + nbytes
    # property 2: splinters tile their stripes exactly, once each
    covered = 0
    for sp in plan.splinters:
        s, e = plan.stripe_bounds[sp.reader]
        assert s <= sp.offset and sp.end <= e
        covered += sp.nbytes
    assert covered == nbytes
    # property 3: reader_for agrees with stripe bounds
    for probe in {offset, offset + nbytes - 1, offset + nbytes // 2}:
        r = plan.reader_for(probe)
        s, e = plan.stripe_bounds[r]
        assert s <= probe < e


@settings(max_examples=200, deadline=None)
@given(
    nbytes=st.integers(1, 10**6),
    readers=st.integers(1, 16),
    data=st.data(),
)
def test_pieces_cover_request_property(nbytes, readers, data):
    plan = plan_session(0, nbytes, readers, splinter_bytes=64 * 1024)
    off = data.draw(st.integers(0, nbytes - 1))
    ln = data.draw(st.integers(1, nbytes - off))
    pieces = pieces_for_range(plan, off, ln)
    # pieces are contiguous, in order, cover exactly [off, off+ln)
    cur = off
    for r, p_off, p_len in pieces:
        assert p_off == cur and p_len > 0
        s, e = plan.stripe_bounds[r]
        assert s <= p_off and p_off + p_len <= e
        cur += p_len
    assert cur == off + ln
    # covering splinters include every requested byte
    spl = splinters_covering(plan, off, ln)
    lo = min(s.offset for s in spl)
    hi = max(s.end for s in spl)
    assert lo <= off and hi >= off + ln


def test_out_of_session_read_rejected():
    plan = plan_session(100, 50, 2)
    with pytest.raises(ValueError):
        pieces_for_range(plan, 90, 20)
    with pytest.raises(ValueError):
        pieces_for_range(plan, 140, 20)
