"""Token file format, packing, and the CkIO training pipeline."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import FileOptions
from repro.data import (
    CkIOPipeline,
    batch_from_tokens,
    decode_rows,
    make_embedding_file,
    make_token_file,
    pack_documents,
    read_meta,
    window_rows,
    write_token_file,
)


def test_tokenfile_roundtrip(tmp_path):
    path = str(tmp_path / "t.bin")
    arr = np.arange(1000, dtype=np.uint32)
    write_token_file(path, arr)
    meta = read_meta(path)
    assert meta.shape == (1000,) and meta.dtype == np.uint32
    off, n = meta.byte_range_for_rows(10, 5)
    with open(path, "rb") as f:
        f.seek(off)
        got = decode_rows(meta, f.read(n), 10, 5)
    np.testing.assert_array_equal(got, arr[10:15])


def test_embedding_file_rows(tmp_path):
    path = str(tmp_path / "e.bin")
    make_embedding_file(path, 64, 16, seed=3)
    meta = read_meta(path)
    assert meta.shape == (64, 16)
    assert meta.row_bytes == 16 * 4


def test_window_math():
    start, n = window_rows(3, global_batch=4, seq_len=8)
    assert start == 3 * 4 * 9 and n == 4 * 9


@settings(max_examples=50, deadline=None)
@given(
    docs=st.lists(st.lists(st.integers(1, 99), min_size=1, max_size=30),
                  min_size=1, max_size=10),
    seq_len=st.integers(4, 16),
)
def test_pack_documents_preserves_tokens(docs, seq_len):
    rows, segs = pack_documents(docs, seq_len, eos_id=100)
    flat = rows[segs > 0]
    expect = []
    for d in docs:
        expect.extend(d)
        expect.append(100)
    assert list(flat[: len(expect)]) == expect[: len(flat)]
    assert rows.shape == segs.shape and rows.shape[1] == seq_len


def test_pipeline_matches_file(tmp_path):
    path = str(tmp_path / "corpus.bin")
    make_token_file(path, 50_000, vocab_size=777, seed=5)
    raw = np.fromfile(path, dtype=np.uint32, offset=4096)
    pipe = CkIOPipeline(path, global_batch=4, seq_len=64, num_pes=2,
                        num_consumers=10,
                        file_opts=FileOptions(num_readers=3,
                                              splinter_bytes=16 * 1024))
    need = 4 * 65
    for s in range(min(pipe.num_steps, 5)):
        x, y = pipe.get_batch(s)
        ref = raw[s * need:(s + 1) * need].reshape(4, 65)
        np.testing.assert_array_equal(x, ref[:, :-1])
        np.testing.assert_array_equal(y, ref[:, 1:])
    pipe.close()


def test_pipeline_elastic_resize_and_migration(tmp_path):
    path = str(tmp_path / "corpus2.bin")
    make_token_file(path, 40_000, vocab_size=100, seed=6)
    raw = np.fromfile(path, dtype=np.uint32, offset=4096)
    pipe = CkIOPipeline(path, global_batch=2, seq_len=32, num_pes=4,
                        num_consumers=4,
                        file_opts=FileOptions(num_readers=2))
    x, _ = pipe.get_batch(0)
    pipe.resize(32)                      # scale consumers up
    pipe.migrate_consumer(0, 3)          # move a consumer
    x1, _ = pipe.get_batch(1)
    need = 2 * 33
    ref = raw[need:2 * need].reshape(2, 33)
    np.testing.assert_array_equal(x1, ref[:, :-1])
    pipe.resize(3)                       # scale down
    x2, _ = pipe.get_batch(2)
    ref2 = raw[2 * need:3 * need].reshape(2, 33)
    np.testing.assert_array_equal(x2, ref2[:, :-1])
    pipe.close()


def test_pipeline_prefetch_overlap(tmp_path):
    """get_batch(0) must have already started step 1's session (double
    buffering — the paper's input/compute overlap)."""
    path = str(tmp_path / "corpus3.bin")
    make_token_file(path, 60_000, vocab_size=50, seed=7)
    pipe = CkIOPipeline(path, global_batch=2, seq_len=64, num_pes=2,
                        prefetch_depth=2,
                        file_opts=FileOptions(num_readers=2))
    pipe.get_batch(0)
    assert 1 in pipe._bufs or 2 in pipe._bufs, "no lookahead session in flight"
    pipe.close()
