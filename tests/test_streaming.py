"""Event-driven splinter streaming: completion stream semantics, fused
chunk-ingest kernels vs the NumPy oracle (arbitrary arrival permutations,
seeded sweeps — the test_device_ingest pattern), overlap-metrics invariants,
mid-stream resize/migration, stale-delivery drops, adaptive splinter sizing,
and bit-identical equivalence with the whole-window (``streaming=False``)
path.
"""
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    AutoTuner,
    CkIO,
    FileOptions,
    SessionMetrics,
    SplinterSizer,
    StreamMetrics,
)
from repro.data import CkIOPipeline, make_token_file
from repro.kernels import ops


# -- NumPy oracle (same ground truth as tests/test_device_ingest.py) ----------

def np_batch_oracle(linear, B, S, w0=0, valid_limit=None, pad_id=0):
    S1 = S + 1
    full_limit = w0 + B * S1
    if valid_limit is None:
        valid_limit = full_limit
    buf = np.full(full_limit + 1, pad_id, dtype=linear.dtype)
    n = min(linear.size, full_limit + 1)
    buf[:n] = linear[:n]
    pos = w0 + np.arange(B)[:, None] * S1 + np.arange(S1 + 1)[None, :]
    rows = buf[pos]
    inputs = np.where(pos[:, :S] < valid_limit, rows[:, :S], pad_id)
    labels = np.where(pos[:, 1:S + 1] < valid_limit, rows[:, 1:S + 1], pad_id)
    return inputs, labels


def random_chunks(rng, toks):
    """Cut a token window into 1..8 contiguous chunks, shuffled arrival."""
    n = toks.size
    ncuts = int(rng.integers(0, min(7, n - 1) + 1))
    cuts = (np.sort(rng.choice(np.arange(1, n), size=ncuts, replace=False))
            if ncuts else np.array([], int))
    bounds = [0, *cuts.tolist(), n]
    pieces = [(bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1)]
    order = rng.permutation(len(pieces))
    chunks = [jnp.asarray(toks[pieces[i][0]:pieces[i][1]]) for i in order]
    starts = [pieces[i][0] for i in order]
    return chunks, starts


# -- fused chunk-ingest kernels vs oracle -------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_ingest_chunks_window_matches_oracle(seed):
    rng = np.random.default_rng(400 + seed)
    B = int(rng.integers(1, 4))
    S = int(rng.integers(2, 12))
    valid = int(rng.integers(1, B * (S + 1) + 1))
    toks = rng.integers(1, 1 << 20, size=valid).astype(np.int32)
    chunks, starts = random_chunks(rng, toks)
    # present in file order (the pipeline's handle reorder)
    order = np.argsort(starts)
    chunks = [chunks[i] for i in order]
    want = np_batch_oracle(toks, B, S, 0, valid)
    got = ops.ingest_chunks_window(chunks, global_batch=B, seq_len=S,
                                   valid_limit=valid)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), w)


@pytest.mark.parametrize("seed", range(6))
def test_ingest_chunks_block_matches_oracle(seed):
    rng = np.random.default_rng(500 + seed)
    T = int(rng.integers(2, 9))
    NB = int(rng.integers(2, 9))
    B, S = 2, NB * T // 2 - 1          # B*(S+1) == NB*T tokens
    if S < 1:
        B, S = 1, NB * T - 1
    toks = rng.integers(1, 1 << 20, size=NB * T).astype(np.int32)
    staged_order = rng.permutation(NB)           # arrival: staged[i] = block
    chunks = [jnp.asarray(toks[b * T:(b + 1) * T]) for b in staged_order]
    perm = np.empty(NB, dtype=np.int32)          # file block -> staged block
    for i, b in enumerate(staged_order):
        perm[b] = i
    want = np_batch_oracle(toks, B, S)
    got = ops.ingest_chunks_block(chunks, jnp.asarray(perm),
                                  global_batch=B, seq_len=S)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), w)


def test_ingest_chunks_tokens_matches_ref():
    rng = np.random.default_rng(7)
    B, S, L = 2, 5, 40
    toks = rng.integers(0, 1000, size=L).astype(np.int32)
    chunks = [jnp.asarray(toks[:13]), jnp.asarray(toks[13:27]),
              jnp.asarray(toks[27:])]
    row_idx = rng.integers(-1, L, size=(B, S + 1)).astype(np.int32)
    got = ops.ingest_chunks_tokens(chunks, jnp.asarray(row_idx), pad_id=9)
    staged = jnp.asarray(toks)
    want = ops.reassemble_tokens(staged, jnp.asarray(row_idx), pad_id=9)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_staged_concat():
    a = jnp.arange(5, dtype=jnp.int32)
    assert ops.staged_concat([a]) is a
    out = ops.staged_concat([a, a + 5])
    np.testing.assert_array_equal(np.asarray(out), np.arange(10))
    with pytest.raises(ValueError):
        ops.staged_concat([])


# -- completion-stream semantics ----------------------------------------------

def _session(ck, path, nbytes, offset=4096, **opts):
    f = ck.open_sync(path, FileOptions(**opts))
    return f, ck.start_read_session_sync(f, nbytes, offset)


@pytest.fixture()
def token_path(tmp_path):
    p = str(tmp_path / "stream.bin")
    make_token_file(p, 40_000, vocab_size=97, seed=21)
    return p


def test_stream_replay_and_order(token_path):
    """A late subscriber sees every splinter exactly once, past events
    first, all in arrival order."""
    ck = CkIO(num_pes=2)
    f, sess = _session(ck, token_path, 64 * 1024,
                       num_readers=3, splinter_bytes=8 * 1024)
    assert sess.readers.join(30.0)
    got = []
    token = sess.subscribe_splinters(got.append)   # after completion: replay
    assert [e.index for e in got] == list(sess.arrival_order)
    assert sorted(e.index for e in got) == list(
        range(len(sess.plan.splinters)))
    for e in got:
        assert e.nbytes > 0 and e.arena_off == e.offset - sess.offset
        assert e.t_arrival > 0
    sess.unsubscribe_splinters(token)
    # events() snapshot agrees
    assert [e.index for e in sess.splinter_events] == list(sess.arrival_order)
    ck.close_read_session_sync(sess)
    ck.close_sync(f)


def test_stream_live_delivery_and_unsubscribe_barrier(token_path):
    ck = CkIO(num_pes=2)
    f, sess = _session(ck, token_path, 96 * 1024, num_readers=2,
                       splinter_bytes=8 * 1024,
                       delay_model=lambda r, sp: 0.005)
    got = []
    lock = threading.Lock()

    def cb(ev):
        with lock:
            got.append(ev.index)

    token = sess.readers.subscribe(cb)
    sess.readers.join(30.0)
    with lock:
        n_at_join = len(got)
    assert n_at_join == len(sess.plan.splinters)
    sess.readers.unsubscribe(token)
    # barrier: no further deliveries counted after unsubscribe returns
    assert len(got) == n_at_join
    ck.close_read_session_sync(sess)
    ck.close_sync(f)


def test_read_stream_api_routing_and_complete(token_path):
    ck = CkIO(num_pes=4)
    f, sess = _session(ck, token_path, 64 * 1024, num_readers=2,
                       splinter_bytes=8 * 1024)
    events, done = [], []
    ck.read_stream(sess, events.append, pe=1, on_complete=lambda: done.append(1))
    ck.run_until(lambda: bool(done), timeout=30.0)
    assert sorted(e.index for e in events) == list(
        range(len(sess.plan.splinters)))
    assert done == [1]
    with pytest.raises(RuntimeError):
        sess.closed = True
        ck.read_stream(sess, events.append)
    sess.closed = False
    ck.close_read_session_sync(sess)
    ck.close_sync(f)


def test_read_stream_on_complete_requires_replay(token_path):
    ck = CkIO(num_pes=2)
    f, sess = _session(ck, token_path, 32 * 1024, num_readers=2,
                       splinter_bytes=8 * 1024)
    with pytest.raises(ValueError):
        ck.read_stream(sess, lambda ev: None, replay=False,
                       on_complete=lambda: None)
    ck.close_read_session_sync(sess)
    ck.close_sync(f)


def test_read_stream_drop_stale_consumer(token_path):
    """Events routed to a deregistered consumer are dropped and counted —
    never delivered, never rerouted."""
    ck = CkIO(num_pes=2)
    f, sess = _session(ck, token_path, 64 * 1024, num_readers=2,
                       splinter_bytes=8 * 1024)
    client = ck.make_client(pe=1)
    client.deregister()                       # retired before delivery
    got = []
    ck.read_stream(sess, got.append, client=client)
    sess.readers.join(30.0)
    ck.sched.pump()
    assert got == []
    assert ck.locations.stale_deliveries == len(sess.plan.splinters)
    ck.close_read_session_sync(sess)
    ck.close_sync(f)


def test_lookup_or_drop_and_count_stale():
    ck = CkIO(num_pes=2)
    c = ck.make_client(pe=1)
    assert ck.locations.lookup_or_drop(c.vid) == 1
    c.deregister()
    assert ck.locations.lookup_or_drop(c.vid) is None
    assert ck.locations.stale_deliveries == 1
    ck.locations.count_stale()
    assert ck.locations.stale_deliveries == 2
    # drop_stale callbacks require proxy routing
    from repro.core.futures import CkCallback
    with pytest.raises(ValueError):
        CkCallback(lambda: None, pe=0, drop_stale=True)


# -- StreamMetrics invariants -------------------------------------------------

def test_stream_metrics_overlap_and_latency():
    m = StreamMetrics()
    m.record_chunk(100, 2, 0.01, [0.02, 0.04])
    assert m.splinters_staged == 2 and m.stage_chunks == 1
    assert m.max_stage_latency_s == pytest.approx(0.04)
    assert m.mean_stage_latency_s() == pytest.approx(0.03)
    m.stage_inflight(100)
    m.stage_inflight(50)
    m.stage_inflight(-100)
    assert m.inflight_bytes == 50 and m.inflight_bytes_hwm == 150
    # full overlap: stage span inside read span, clamped to step time
    m.record_step((0.0, 1.0), (0.2, 0.8), 1.0)
    assert m.overlap_fraction() == pytest.approx(0.6)
    # disjoint spans -> no overlap credit
    m.record_step((0.0, 1.0), (2.0, 3.0), 1.0)
    assert m.overlap_fraction() == pytest.approx(0.3)
    # overlap longer than the step wall is clamped
    m2 = StreamMetrics()
    m2.record_step((0.0, 10.0), (0.0, 10.0), 1.0)
    assert m2.overlap_fraction() == pytest.approx(1.0)
    s = m.summary()
    assert s["stale_events"] == 0 and s["steps"] == 2
    m.record_stale_event()
    assert m.summary()["stale_events"] == 1


# -- pipeline: equivalence, permutations, lifetime ----------------------------

@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("streaming") / "corpus.bin")
    make_token_file(path, 60_000, vocab_size=451, seed=13)
    raw = np.fromfile(path, dtype=np.uint32, offset=4096).view(np.int32)
    return path, raw


def make_pipe(path, streaming=True, **kw):
    kw.setdefault("num_pes", 2)
    kw.setdefault("num_consumers", 8)
    kw.setdefault("file_opts", FileOptions(num_readers=3,
                                           splinter_bytes=16 * 1024))
    return CkIOPipeline(path, global_batch=4, seq_len=64,
                        streaming=streaming, **kw)


def test_streaming_matches_file_and_counters(corpus):
    path, raw = corpus
    pipe = make_pipe(path)
    need = 4 * 65
    for s in range(4):
        x, y = pipe.get_batch_device(s)
        ref = raw[s * need:(s + 1) * need].reshape(4, 65)
        np.testing.assert_array_equal(np.asarray(x), ref[:, :-1])
        np.testing.assert_array_equal(np.asarray(y), ref[:, 1:])
    m = pipe.ingest.summary()
    assert m["host_permute_bytes"] == 0
    assert m["device_steps"] == 4
    sm = pipe.stream.summary()
    assert sm["steps"] == 4
    assert sm["splinters_staged"] >= 4          # at least the fetched windows
    assert sm["bytes_staged"] >= 4 * need * 4
    pipe.close()


def test_streaming_equals_whole_window_bitwise(corpus):
    """The tentpole equivalence: streamed batches are bit-identical to the
    streaming=False whole-window path, under stragglers + stealing."""
    path, _ = corpus
    delays = lambda r, sp: 0.008 if r == 0 else 0.001   # noqa: E731
    opts = FileOptions(num_readers=3, splinter_bytes=8 * 1024,
                       delay_model=delays)
    pipe_w = make_pipe(path, streaming=False, file_opts=opts)
    pipe_s = make_pipe(path, file_opts=opts)
    for s in range(4):
        wx, wy = pipe_w.get_batch_device(s)
        sx, sy = pipe_s.get_batch_device(s)
        np.testing.assert_array_equal(np.asarray(wx), np.asarray(sx))
        np.testing.assert_array_equal(np.asarray(wy), np.asarray(sy))
    assert pipe_s.ingest.host_permute_bytes == 0
    pipe_w.close()
    pipe_s.close()


@pytest.mark.parametrize("seed", range(4))
def test_streaming_arbitrary_permutations_seeded(corpus, seed):
    """Seeded sweep: per-splinter delays scramble arrival arbitrarily; the
    streamed batch must still be exact (ordering/completeness oracle)."""
    path, raw = corpus
    rng = np.random.default_rng(900 + seed)
    jitter = {i: float(d) for i, d in enumerate(
        rng.uniform(0.0, 0.01, size=256))}
    opts = FileOptions(num_readers=4, splinter_bytes=4 * 1024,
                       delay_model=lambda r, sp: jitter[sp.index % 256])
    pipe = make_pipe(path, file_opts=opts)
    need = 4 * 65
    step = int(rng.integers(0, 3))
    x, y = pipe.get_batch_device(step)
    ref = raw[step * need:(step + 1) * need].reshape(4, 65)
    np.testing.assert_array_equal(np.asarray(x), ref[:, :-1])
    np.testing.assert_array_equal(np.asarray(y), ref[:, 1:])
    pipe.close()


def test_streaming_remainder_window(tmp_path):
    path = str(tmp_path / "rem.bin")
    make_token_file(path, 1000, vocab_size=50, seed=3)
    raw = np.fromfile(path, dtype=np.uint32, offset=4096).view(np.int32)
    pipe = CkIOPipeline(path, global_batch=2, seq_len=32, num_pes=2,
                        drop_remainder=False, streaming=True,
                        file_opts=FileOptions(num_readers=2))
    rows = 2 * 33
    last = pipe.num_steps - 1
    valid = 1000 - last * rows
    assert 0 < valid < rows
    want = np_batch_oracle(raw[last * rows:], 2, 32, 0, valid)
    xd, yd = pipe.get_batch_device(last)
    np.testing.assert_array_equal(np.asarray(xd), want[0])
    np.testing.assert_array_equal(np.asarray(yd), want[1])
    pipe.close()


def test_streaming_overlap_metrics_invariants(corpus):
    path, _ = corpus
    budget = 32 * 1024
    pipe = make_pipe(path, max_inflight_stage_bytes=budget,
                     file_opts=FileOptions(num_readers=3,
                                           splinter_bytes=8 * 1024,
                                           delay_model=lambda r, sp: 0.003))
    for s in range(3):
        pipe.get_batch_device(s)
    sm = pipe.stream.summary()
    assert 0.0 <= sm["overlap_fraction"] <= 1.0
    assert sm["inflight_bytes_hwm"] <= budget
    assert sm["mean_stage_latency_s"] <= sm["max_stage_latency_s"]
    assert sm["splinters_staged"] == sm["stage_chunks"]  # one chunk each
    pipe.close()
    # Balance invariant: every staged transfer retired its in-flight
    # accounting by teardown. (Checking before close is racy by design:
    # a *prefetched* step's splinter staged during the last fetch's pump
    # is legitimately still in flight — that overlap is the feature.)
    assert pipe.stream.inflight_bytes == 0


def test_streaming_mid_stream_resize_and_migration(corpus):
    """resize()/migrate_consumer racing streamed deliveries: steps stay
    bit-exact, zero host copies, and nothing leaks."""
    path, raw = corpus
    opts = FileOptions(num_readers=3, splinter_bytes=8 * 1024,
                       delay_model=lambda r, sp: 0.004)
    pipe = make_pipe(path, file_opts=opts)
    need = 4 * 65
    x0, _ = pipe.get_batch_device(0)
    pipe.resize(12)                      # grow with deliveries in flight
    x1, _ = pipe.get_batch_device(1)
    pipe.migrate_consumer(0, 1)
    pipe.resize(3)                       # shrink: retired consumers' events drop
    x2, _ = pipe.get_batch_device(2)
    for s, x in enumerate((x0, x1, x2)):
        ref = raw[s * need:(s + 1) * need].reshape(4, 65)
        np.testing.assert_array_equal(np.asarray(x), ref[:, :-1])
    assert pipe.ingest.host_permute_bytes == 0
    assert pipe.ck.locations.count() == 3
    pipe.close()


def test_streaming_shrink_to_one_consumer_completes(tmp_path):
    """Shrink below the event-routing fan-out mid-read: dropped events are
    counted and the batch still completes from the event log."""
    path = str(tmp_path / "shrink.bin")
    make_token_file(path, 30_000, vocab_size=77, seed=8)
    raw = np.fromfile(path, dtype=np.uint32, offset=4096).view(np.int32)
    opts = FileOptions(num_readers=2, splinter_bytes=8 * 1024,
                       delay_model=lambda r, sp: 0.01)
    pipe = CkIOPipeline(path, global_batch=2, seq_len=32, num_pes=2,
                        num_consumers=8, file_opts=opts, streaming=True)
    pipe.resize(1)                       # most in-flight events now stale
    x, y = pipe.get_batch_device(0)
    need = 2 * 33
    np.testing.assert_array_equal(np.asarray(x),
                                  raw[:need].reshape(2, 33)[:, :-1])
    pipe.close()


def test_late_event_after_finalize_is_dropped_and_counted(corpus):
    """A splinter event reaching a finalized step is dropped + counted (the
    stale_deliveries counter extension), never staged twice."""
    path, _ = corpus
    pipe = make_pipe(path)
    pipe.get_batch_device(0)
    st_before = pipe.ck.locations.stale_deliveries
    buf = type("B", (), {"ready": None})()
    # replay the authoritative events of the *retired* step's stream into
    # the handler: every one must be dropped
    retired_sess = pipe._retired[-1] if pipe._retired else None
    assert retired_sess is not None
    from repro.data.pipeline import _StreamState
    st = _StreamState(session=retired_sess, retired=True)
    events = retired_sess.splinter_events[:3]
    assert events
    for ev in events:
        pipe._on_stream_event(buf, st, ev)
    assert pipe.stream.stale_events == len(events)
    assert pipe.ck.locations.stale_deliveries == st_before + len(events)
    assert st.pending == [] and st.chunks == []
    pipe.close()


def test_streaming_host_path_still_works(corpus):
    """get_batch on a streaming pipeline aborts the stream cleanly and
    returns the host-path batch."""
    path, raw = corpus
    pipe = make_pipe(path)
    need = 4 * 65
    x, y = pipe.get_batch(0)
    np.testing.assert_array_equal(x, raw[:need].reshape(4, 65)[:, :-1])
    # stream state was torn down, not leaked
    assert all(b.stream is None for b in pipe._bufs.values()
               if b.session is not None and b.ready.done)
    xd, _ = pipe.get_batch_device(1)     # device path still fine afterwards
    np.testing.assert_array_equal(np.asarray(xd),
                                  raw[need:2 * need].reshape(4, 65)[:, :-1])
    pipe.close()


def test_streaming_sharding_falls_back_to_whole_window(corpus):
    path, raw = corpus
    pipe = make_pipe(path)
    dev = jax.devices()[0]
    from jax.sharding import SingleDeviceSharding

    # The fallback is explicit now: one RuntimeWarning per pipeline
    # (streamed chunks are placed before a per-call sharding is known).
    with pytest.warns(RuntimeWarning, match="whole-window"):
        x, y = pipe.get_batch_device(0, sharding=SingleDeviceSharding(dev))
    need = 4 * 65
    np.testing.assert_array_equal(np.asarray(x),
                                  raw[:need].reshape(4, 65)[:, :-1])
    pipe.close()


def test_streaming_requires_zero_copy(corpus):
    path, _ = corpus
    with pytest.raises(ValueError):
        CkIOPipeline(path, global_batch=2, seq_len=16, num_pes=2,
                     streaming=True, zero_copy=False)


def test_streaming_rejects_misaligned_splinters(corpus):
    path, _ = corpus
    with pytest.raises(ValueError, match="multiple of the token itemsize"):
        CkIOPipeline(path, global_batch=2, seq_len=16, num_pes=2,
                     streaming=True,
                     file_opts=FileOptions(num_readers=2,
                                           splinter_bytes=10_001))
    # the whole-window path accepts the same options
    pipe = CkIOPipeline(path, global_batch=2, seq_len=16, num_pes=2,
                        streaming=False,
                        file_opts=FileOptions(num_readers=2,
                                              splinter_bytes=10_001))
    pipe.close()


def test_streaming_chunk_views_lifetime(corpus):
    """Streamed chunk views: pinned until the step retires, then released
    (use-after-retire raises)."""
    path, _ = corpus
    pipe = make_pipe(path)
    pipe.get_batch_device(0)
    st = pipe._staged[-1]
    views = [v for _, v in st.host_tokens]
    assert views and all(not v.readonly or True for v in views)
    for v in views:
        bytes(v[:4])                     # alive before the next fetch
    pipe.get_batch_device(1)             # retires step 0
    with pytest.raises(ValueError):
        bytes(views[0])
    pipe.close()


def test_reset_stream_metrics_carries_inflight(corpus):
    """reset_stream_metrics opens a fresh window without desynchronizing
    the in-flight balance of already-issued transfers."""
    path, raw = corpus
    pipe = make_pipe(path, file_opts=FileOptions(
        num_readers=3, splinter_bytes=8 * 1024,
        delay_model=lambda r, sp: 0.002))
    pipe.get_batch_device(0)             # warm; prefetch streams staging
    old = pipe.reset_stream_metrics()
    assert pipe.stream is not old
    assert pipe.stream.inflight_bytes == old.inflight_bytes
    assert pipe.stream.steps == 0
    need = 4 * 65
    x, _ = pipe.get_batch_device(1)
    np.testing.assert_array_equal(np.asarray(x),
                                  raw[need:2 * need].reshape(4, 65)[:, :-1])
    # every transfer retired cleanly against the new window
    pipe.get_batch_device(2)
    assert pipe.stream.inflight_bytes >= 0
    pipe.close()
    assert pipe.stream.inflight_bytes == 0


# -- adaptive splinter sizing + autotuner satellite ---------------------------

def test_autotuner_no_trial_queue_and_deterministic():
    t = AutoTuner(num_pes=4)
    assert not hasattr(t, "_trial_queue")
    assert t.suggest(1 << 30) == t.suggest(1 << 30)   # no history: seed
    t.record(4, 100.0)
    # fixed exploration order: best(4, tried) -> 2 -> 8
    assert t.suggest(1 << 30) == 2
    t.record(2, 50.0)
    assert t.suggest(1 << 30) == 8
    t.record(8, 80.0)
    # neighbourhood explored: exploit the best
    assert t.suggest(1 << 30) == 4
    assert t.suggest(1 << 30) == 4                    # deterministic


def test_autotuner_record_session_hook():
    t = AutoTuner(num_pes=4)
    m = SessionMetrics()
    m.session_started(1 << 20, 3)
    m.record_read(0, 1 << 20, 0.01)
    t.record_session(m)
    assert t.best() == 3
    empty = SessionMetrics()
    t.record_session(empty)              # no signal: ignored
    assert list(t.observations) == [3]


def test_splinter_sizer_throughput_and_steals():
    sz = SplinterSizer()
    assert sz.suggest(8 << 20) == 8 << 20         # unobserved: default
    fast = SessionMetrics()
    fast.session_started(1 << 26, 4)
    fast.record_read(0, 1 << 26, 0.1)             # ~671 MB/s per thread
    sz.record_session(fast)
    big = sz.suggest(8 << 20)
    assert big >= 16 << 20                        # large on streaming stripes
    assert big % (256 * 1024) == 0
    # heavy stealing shrinks the unit
    stolen = SessionMetrics()
    stolen.session_started(1 << 26, 4)
    for _ in range(10):
        stolen.record_read(0, 1 << 22, 0.00625)
    stolen.steals = 8
    sz2 = SplinterSizer()
    sz2.record_session(stolen)
    sz_no_steals = SplinterSizer()
    calm = SessionMetrics()
    calm.session_started(1 << 26, 4)
    for _ in range(10):
        calm.record_read(0, 1 << 22, 0.00625)
    sz_no_steals.record_session(calm)
    assert sz2.suggest(8 << 20) < sz_no_steals.suggest(8 << 20)
    # clamped to bounds
    slow = SessionMetrics()
    slow.session_started(1 << 20, 1)
    slow.record_read(0, 1024, 1.0)
    sz3 = SplinterSizer()
    sz3.record_session(slow)
    assert sz3.suggest(8 << 20) == sz3.min_bytes


def test_adaptive_splinters_resize_sessions(corpus):
    """adaptive_splinters=True: after observed sessions, new session plans
    use the sizer's suggestion (shared Director observation path)."""
    path, _ = corpus
    ck = CkIO(num_pes=2)
    opts = FileOptions(num_readers=2, splinter_bytes=8 * 1024,
                       adaptive_splinters=True)
    f = ck.open_sync(path, opts)
    s1 = ck.start_read_session_sync(f, 64 * 1024, 4096)
    assert s1.plan.splinter_bytes == 8 * 1024     # seed: no observations
    s1.readers.join(30.0)
    ck.close_read_session_sync(s1)
    assert ck.director.splinter_sizer.sessions_observed == 1
    assert ck.director.tuner.observations            # tuner fed too
    want = ck.director.splinter_sizer.suggest(8 * 1024)
    s2 = ck.start_read_session_sync(f, 64 * 1024, 4096)
    assert s2.plan.splinter_bytes == max(4096, want)
    ck.close_read_session_sync(s2)
    ck.close_sync(f)


def test_streaming_pipeline_with_adaptive_splinters(corpus):
    path, raw = corpus
    opts = FileOptions(num_readers=2, splinter_bytes=8 * 1024,
                       adaptive_splinters=True)
    pipe = make_pipe(path, file_opts=opts)
    need = 4 * 65
    for s in range(4):                   # sizes adapt across step sessions
        x, _ = pipe.get_batch_device(s)
        ref = raw[s * need:(s + 1) * need].reshape(4, 65)
        np.testing.assert_array_equal(np.asarray(x), ref[:, :-1])
    assert pipe.ck.director.splinter_sizer.sessions_observed >= 1
    pipe.close()
