"""Zero-copy hot path: preadv semantics, borrowed-view lifetime, piece
coalescing, bytes_copied accounting, and scheduler batch/O(1) dispatch."""
import os

import numpy as np
import pytest

from repro.core import CkIO, FileOptions
from repro.core.scheduler import TaskScheduler
from repro.io.layout import pieces_for_range, plan_session
from repro.io.posix import HAVE_PREADV, PosixFile


@pytest.fixture(scope="module")
def data_file(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("hotpath") / "data.bin")
    rng = np.random.default_rng(7)
    data = rng.integers(0, 256, size=1_000_000, dtype=np.uint8).tobytes()
    with open(path, "wb") as f:
        f.write(data)
    return path, data


# -- posix pread_into ---------------------------------------------------------

@pytest.mark.parametrize("use_preadv", [True, False])
def test_pread_into_full_read(data_file, use_preadv):
    path, data = data_file
    f = PosixFile.open(path)
    f.use_preadv = use_preadv
    buf = bytearray(4096)
    n = f.pread_into(1000, memoryview(buf))
    assert n == 4096
    assert bytes(buf) == data[1000:5096]
    f.close()


@pytest.mark.parametrize("use_preadv", [True, False])
def test_pread_into_short_read_at_eof(data_file, use_preadv):
    """A range crossing EOF fills up to EOF and returns the partial count
    (the short-read loop must stop, not spin or raise)."""
    path, data = data_file
    f = PosixFile.open(path)
    f.use_preadv = use_preadv
    want = 5000
    buf = bytearray(want)
    off = len(data) - 1234
    n = f.pread_into(off, memoryview(buf))
    assert n == 1234
    assert bytes(buf[:n]) == data[off:]
    # entirely past EOF -> 0 bytes, no error
    assert f.pread_into(len(data) + 10, memoryview(bytearray(64))) == 0
    f.close()


def test_preadv_available_on_this_platform():
    # The container targets Linux; if this ever fails the fallback still
    # keeps everything correct, but the zero-copy claim needs preadv.
    assert HAVE_PREADV


def test_advise_sequential_best_effort(data_file):
    path, _ = data_file
    f = PosixFile.open(path)
    # Must not raise either way; on Linux it should succeed.
    assert f.advise_sequential(0, f.size) in (True, False)
    f.close()


# -- layout coalescing --------------------------------------------------------

def test_pieces_coalesce_by_key():
    plan = plan_session(0, 40960, 4, splinter_bytes=4096, align=1)
    # no key: exact per-stripe split
    raw = pieces_for_range(plan, 0, 40960)
    assert len(raw) == 4
    # all readers same node -> one piece covering the whole range
    one = pieces_for_range(plan, 0, 40960, coalesce_key=lambda r: 0)
    assert one == [(0, 0, 40960)]
    # two-node split (readers 0,1 | 2,3) -> two contiguous runs
    two = pieces_for_range(plan, 0, 40960, coalesce_key=lambda r: r // 2)
    assert len(two) == 2
    assert two[0][1] + two[0][2] == two[1][1]
    assert sum(p[2] for p in two) == 40960


def test_coalesced_read_single_waiter_same_node(data_file):
    """All readers co-located -> a request spanning every stripe is served
    as ONE piece (one waiter, one delivery task)."""
    path, data = data_file
    ck = CkIO(num_pes=4, pes_per_node=4)           # one node
    fh = ck.open_sync(path, FileOptions(num_readers=4,
                                        splinter_bytes=64 * 1024))
    sess = ck.start_read_session_sync(fh, 800_000, 0)
    out = ck.read_sync(sess, 800_000, 0)
    assert bytes(out) == data[:800_000]
    assert sess.metrics.pieces_served == 1
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


def test_cross_node_read_one_piece_per_node_run(data_file):
    """Readers on distinct nodes -> one piece per contiguous node run (here:
    4 readers, 4 nodes, so 4 pieces), preserving cross-node accounting."""
    path, data = data_file
    ck = CkIO(num_pes=4, pes_per_node=1)           # four nodes
    fh = ck.open_sync(path, FileOptions(num_readers=4,
                                        splinter_bytes=64 * 1024))
    sess = ck.start_read_session_sync(fh, 800_000, 0)
    out = ck.read_sync(sess, 800_000, 0)
    assert bytes(out) == data[:800_000]
    assert sess.metrics.pieces_served == 4
    assert sess.metrics.cross_node_bytes > 0       # client on PE 0, node 0
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


# -- borrowed-view (zero-copy) path -------------------------------------------

def test_read_view_zero_copy_and_correct(data_file):
    path, data = data_file
    ck = CkIO(num_pes=2, pes_per_node=2)
    fh = ck.open_sync(path, FileOptions(num_readers=3,
                                        splinter_bytes=128 * 1024))
    sess = ck.start_read_session_sync(fh, 500_000, 1000)
    view = ck.read_view_sync(sess, 200_000, 2000)
    assert isinstance(view, memoryview)
    assert view.readonly
    assert bytes(view) == data[2000:202_000]
    # the zero-copy guarantee, proven by the counter:
    assert sess.metrics.bytes_copied == 0
    assert sess.metrics.bytes_served == 200_000
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


def test_zero_length_read_completes(data_file):
    """A 0-byte read has no pieces; its callback must still fire (split-
    phase) instead of hanging the future."""
    path, _ = data_file
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(num_readers=2))
    sess = ck.start_read_session_sync(fh, 10_000, 0)
    out = ck.read_sync(sess, 0, 100, timeout=10)
    assert len(bytes(out)) == 0
    view = ck.read_view_sync(sess, 0, 0, timeout=10)
    assert len(view) == 0
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


def test_copy_path_counts_bytes_copied(data_file):
    path, data = data_file
    ck = CkIO(num_pes=2, pes_per_node=2)
    fh = ck.open_sync(path, FileOptions(num_readers=2))
    sess = ck.start_read_session_sync(fh, 100_000, 0)
    out = ck.read_sync(sess, 60_000, 100)
    assert bytes(out) == data[100:60_100]
    assert sess.metrics.bytes_copied == 60_000
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


def test_view_invalidated_after_close(data_file):
    path, data = data_file
    ck = CkIO(num_pes=2, pes_per_node=2)
    fh = ck.open_sync(path, FileOptions(num_readers=2))
    sess = ck.start_read_session_sync(fh, 100_000, 0)
    view = ck.read_view_sync(sess, 10_000, 500)
    assert bytes(view) == data[500:10_500]
    ck.close_read_session_sync(sess)
    with pytest.raises(ValueError):
        view[0]                       # session-lifetime borrow: released
    with pytest.raises(ValueError):
        bytes(view)
    ck.close_sync(fh)


def test_view_with_live_export_survives_close(data_file):
    """A borrow pinned by a live buffer export (np.frombuffer) cannot be
    released — close must not raise, and the memory stays valid for the
    exporter (Python pins it)."""
    path, data = data_file
    ck = CkIO(num_pes=2, pes_per_node=2)
    fh = ck.open_sync(path, FileOptions(num_readers=2))
    sess = ck.start_read_session_sync(fh, 100_000, 0)
    view = ck.read_view_sync(sess, 8_192, 0)
    arr = np.frombuffer(view, dtype=np.uint8)
    ck.close_read_session_sync(sess)   # must not raise BufferError
    assert bytes(arr.tobytes()) == data[:8_192]
    ck.close_sync(fh)


def test_view_survives_until_close(data_file):
    """Views from multiple reads all stay valid while the session is open."""
    path, data = data_file
    ck = CkIO(num_pes=2, pes_per_node=2)
    fh = ck.open_sync(path, FileOptions(num_readers=3))
    sess = ck.start_read_session_sync(fh, 300_000, 0)
    views = [ck.read_view_sync(sess, 10_000, i * 50_000) for i in range(5)]
    for i, v in enumerate(views):
        assert bytes(v) == data[i * 50_000:i * 50_000 + 10_000]
    assert sess.metrics.bytes_copied == 0
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


# -- pipeline on the zero-copy path -------------------------------------------

def test_pipeline_zero_copy_matches_and_copies_nothing(tmp_path):
    from repro.data import CkIOPipeline, make_token_file

    path = str(tmp_path / "corpus.bin")
    make_token_file(path, 50_000, vocab_size=321, seed=11)
    raw = np.fromfile(path, dtype=np.uint32, offset=4096)
    pipe = CkIOPipeline(path, global_batch=4, seq_len=64, num_pes=2,
                        num_consumers=8, zero_copy=True,
                        file_opts=FileOptions(num_readers=2,
                                              splinter_bytes=32 * 1024))
    need = 4 * 65
    sessions = []
    for s in range(min(pipe.num_steps, 4)):
        x, y = pipe.get_batch(s)
        ref = raw[s * need:(s + 1) * need].reshape(4, 65)
        np.testing.assert_array_equal(x, ref[:, :-1])
        np.testing.assert_array_equal(y, ref[:, 1:])
        sessions.append(pipe._retired[-1])
    for sess in sessions:
        assert sess.metrics.bytes_copied == 0
    pipe.close()


def test_pipeline_copy_mode_still_works(tmp_path):
    from repro.data import CkIOPipeline, make_token_file

    path = str(tmp_path / "corpus_copy.bin")
    make_token_file(path, 30_000, vocab_size=99, seed=12)
    raw = np.fromfile(path, dtype=np.uint32, offset=4096)
    pipe = CkIOPipeline(path, global_batch=2, seq_len=32, num_pes=2,
                        zero_copy=False,
                        file_opts=FileOptions(num_readers=2))
    need = 2 * 33
    x, y = pipe.get_batch(0)
    np.testing.assert_array_equal(x, raw[:need].reshape(2, 33)[:, :-1])
    pipe.close()


# -- scheduler: O(1) dispatch + batching --------------------------------------

def test_enqueue_many_single_batch():
    s = TaskScheduler(num_pes=8)
    order = []
    n = s.enqueue_many((pe, order.append, (f"t{pe}",)) for pe in range(8))
    assert n == 8
    assert s.stats["enqueued"] == 8
    s.pump()
    assert sorted(order) == [f"t{i}" for i in range(8)]


def test_batch_context_defers_and_flushes():
    s = TaskScheduler(num_pes=2)
    seen = []
    with s.batch():
        s.enqueue(0, seen.append, "a")
        s.enqueue(1, seen.append, "b")
        assert s.pump() == 0          # nothing visible until flush
    assert s.pump() == 2
    assert sorted(seen) == ["a", "b"]


def test_batch_nesting_flushes_once_at_outermost():
    s = TaskScheduler(num_pes=1)
    seen = []
    with s.batch():
        s.enqueue(0, seen.append, 1)
        with s.batch():               # nested: no-op
            s.enqueue(0, seen.append, 2)
        assert s.pump() == 0
    assert s.pump() == 2
    assert seen == [1, 2]


def test_ready_deque_many_pes_fifo_and_fair():
    """Dispatch must stay correct with sparse activity across many PEs
    (the O(1) ready-deque replaces a per-pop scan of all queues)."""
    s = TaskScheduler(num_pes=512)
    order = []
    for i in range(3):
        s.enqueue(500, order.append, f"x{i}")
        s.enqueue(7, order.append, f"y{i}")
    s.pump()
    assert [o for o in order if o.startswith("x")] == ["x0", "x1", "x2"]
    assert [o for o in order if o.startswith("y")] == ["y0", "y1", "y2"]
    # interleaved round-robin, not one queue drained wholesale
    assert order[0][0] != order[1][0]


def test_piece_timing_sampled_off_by_default(data_file):
    path, _ = data_file
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(num_readers=2))
    sess = ck.start_read_session_sync(fh, 100_000, 0)
    ck.read_sync(sess, 50_000, 0)
    assert sess.metrics.timed_pieces == 0          # off the hot path
    assert sess.metrics.permute_time_s == 0.0
    ck.close_read_session_sync(sess)
    # opt-in sampling
    ck2 = CkIO(num_pes=2)
    fh2 = ck2.open_sync(path, FileOptions(num_readers=2,
                                          piece_timing_every=1))
    sess2 = ck2.start_read_session_sync(fh2, 100_000, 0)
    ck2.read_sync(sess2, 50_000, 0)
    assert sess2.metrics.timed_pieces > 0
    ck2.close_read_session_sync(sess2)
    ck2.close_sync(fh2)
    ck.close_sync(fh)
