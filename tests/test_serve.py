"""Serving subsystem: churn/admission interop, batching policy, metrics.

Covers ``src/repro/serve/`` end to end:

* continuous batching vs the sequential oracle is BIT-identical across the
  {thread, process, service} reader backends — slot assignment, admission
  order, and co-residency never change a request's token stream;
* the backpressure path: a saturated ``ReaderService`` (``ServiceBusy``)
  queues admitted requests in the ingester's bounded FIFO and sheds new
  submits with ``ServeOverloaded`` once the queue is full — no admitted
  request is lost or double-answered, and the state machine walks
  open -> queueing -> shedding and back down as the queue drains;
* the inflight-ingest-byte budget trips the same queueing path without a
  service;
* mid-decode eviction/admission: slots turn over while neighbours keep
  decoding (a later request starts before the longest finishes);
* a seeded ``FaultPlan`` worker crash mid-churn recovers exactly one
  request's session (per its own ``recovery`` option) while sibling
  requests keep serving through the same pool;
* the metrics fold: nearest-rank percentiles are monotone in q, and the
  legacy ``BatchServer`` reports true arrival->response latency split into
  queueing + service time.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CkIO, FileOptions, ServeMetrics, percentile
from repro.core.faults import FaultPlan
from repro.data import FileSet, write_token_shards
from repro.data.tokenfile import read_meta, write_token_file
from repro.ipc.service import ReaderService, ServiceOptions
from repro.serve import (
    BatchServer,
    ContinuousBatcher,
    ModeledEngine,
    ModelEngine,
    Request,
    RequestIngester,
    ServeOverloaded,
    ServeRequest,
    StaticBatcher,
    greedy_generate,
    sequential_oracle,
)

SEED = int(os.environ.get("CKIO_FAULT_SEED", "20260809"))
VOCAB = 97


def _shm_leftovers():
    d = "/dev/shm"
    if not os.path.isdir(d):
        return []
    return [n for n in os.listdir(d) if n.startswith("ckio-")]


@pytest.fixture(autouse=True)
def _clean_shm():
    for n in _shm_leftovers():
        try:
            os.unlink(os.path.join("/dev/shm", n))
        except OSError:
            pass
    yield


def _token_file(tmp_path, n_rows, name="prompts.bin"):
    rng = np.random.default_rng(SEED)
    arr = rng.integers(0, 512, size=(n_rows,), dtype=np.int32)
    path = str(tmp_path / name)
    write_token_file(path, arr)
    return path, arr, read_meta(path)


def _requests(n, rows_per, max_new, eos_id=None, **kw):
    return [
        ServeRequest(rid=i, row_start=i * rows_per, num_rows=rows_per,
                     max_new_tokens=max_new[i], eos_id=eos_id, **kw)
        for i in range(n)
    ]


def _oracle(arr, reqs):
    return sequential_oracle(
        ModeledEngine(slots=1, vocab=VOCAB),
        [arr[r.row_start: r.row_start + r.num_rows] for r in reqs],
        [r.max_new_tokens for r in reqs],
        eos_id=reqs[0].eos_id if reqs else None,
    )


# -- continuous == sequential oracle, across reader backends ------------------
def test_continuous_matches_oracle_thread_fileset(tmp_path):
    """Thread backend over a sharded FileSet: prompt spans cross no shard
    (rows land wholly in one), outputs bit-identical to the oracle."""
    n, L = 8, 64
    rng = np.random.default_rng(SEED)
    arr = rng.integers(0, 512, size=(n * L,), dtype=np.int32)
    fs = FileSet.build(write_token_shards(
        str(tmp_path), arr, [n * L // 2, n * L // 2]))
    ck = CkIO(num_pes=2)
    metrics = ServeMetrics()
    ck.director.add_observer(metrics.record_session)
    fh = ck.open_fileset_sync(fs, FileOptions(num_readers=2,
                                              backend="thread"))
    ing = RequestIngester(ck, fh, fs, metrics)
    bat = ContinuousBatcher(ModeledEngine(slots=3, vocab=VOCAB), ing)
    reqs = _requests(n, L, [3 + (i * 5) % 9 for i in range(n)])
    for r in reqs:
        ing.submit(r)
    done = bat.run()
    assert sorted(r.rid for r in done) == list(range(n))
    outs = {r.rid: r.result for r in done}
    for r, want in zip(reqs, _oracle(arr, reqs)):
        assert outs[r.rid] == want
    assert metrics.ingest_bytes_copied == 0       # zero-copy ingest
    assert metrics.ingest_sessions == n           # one session per request
    ck.close_sync(fh)


def test_continuous_matches_oracle_process(tmp_path):
    """Legacy per-session-spawn process backend: same bit-identity (small
    N — each request session pays a real worker spawn)."""
    n, L = 3, 2048
    path, arr, meta = _token_file(tmp_path, n * L)
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(
        num_readers=1, max_workers=1, backend="process"))
    ing = RequestIngester(ck, fh, meta)
    bat = ContinuousBatcher(ModeledEngine(slots=2, vocab=VOCAB), ing)
    reqs = _requests(n, L, [4, 6, 5])
    for r in reqs:
        ing.submit(r)
    done = bat.run(timeout_s=120.0)
    outs = {r.rid: r.result for r in done}
    for r, want in zip(reqs, _oracle(arr, reqs)):
        assert outs[r.rid] == want
    ck.close_sync(fh)
    assert _shm_leftovers() == []


def test_continuous_matches_oracle_service(tmp_path):
    """Pooled ReaderService routing: bit-identity + arena recycling (no
    quarantine — the prompt view never outlives its session)."""
    n, L = 8, 256
    path, arr, meta = _token_file(tmp_path, n * L)
    ck = CkIO(num_pes=2)
    svc = ReaderService(ServiceOptions(pool_workers=2, backend="thread"))
    ck.director.attach_service(svc)
    metrics = ServeMetrics()
    ck.director.add_observer(metrics.record_session)
    try:
        fh = ck.open_sync(path, FileOptions(
            num_readers=1, max_workers=1, backend="process",
            use_service=True))
        # budget = one prompt span: sessions serialize, so recycling MUST
        # happen for the run to finish — a quarantined (pinned) arena would
        # show up as all-miss checkouts below
        ing = RequestIngester(ck, fh, meta, metrics, service=svc,
                              max_inflight_bytes=L * 4)
        bat = ContinuousBatcher(ModeledEngine(slots=3, vocab=VOCAB), ing)
        reqs = _requests(n, L, [2 + (i * 3) % 7 for i in range(n)])
        for r in reqs:
            ing.submit(r)
        done = bat.run(timeout_s=120.0)
        outs = {r.rid: r.result for r in done}
        for r, want in zip(reqs, _oracle(arr, reqs)):
            assert outs[r.rid] == want
        assert metrics.pooled_sessions == n
        assert metrics.ingest_bytes_copied == 0
        # released views never pin the arena -> segments recycle
        assert svc.metrics.arena_hit_rate() > 0.0
        ck.close_sync(fh)
    finally:
        svc.shutdown()
    assert _shm_leftovers() == []


# -- backpressure -------------------------------------------------------------
def test_servicebusy_queues_then_sheds_no_request_lost(tmp_path):
    """Saturated service (1 inflight session, queue 0) + tiny ingest queue:
    early submits are admitted (some via the queue), the rest shed with a
    descriptive ServeOverloaded; every admitted request completes exactly
    once and the state machine walks open->queueing->shedding and back."""
    n, L = 8, 64
    path, arr, meta = _token_file(tmp_path, n * L)
    ck = CkIO(num_pes=2)
    svc = ReaderService(ServiceOptions(pool_workers=2, backend="thread",
                                       max_sessions=1, max_queue=0))
    ck.director.attach_service(svc)
    metrics = ServeMetrics()
    try:
        fh = ck.open_sync(path, FileOptions(
            num_readers=1, max_workers=1, backend="process",
            use_service=True))
        ing = RequestIngester(ck, fh, meta, metrics, max_pending=2,
                              service=svc)
        bat = ContinuousBatcher(ModeledEngine(slots=2, vocab=VOCAB), ing)
        reqs = _requests(n, L, [4] * n)
        admitted, shed = [], []
        for r in reqs:
            try:
                ing.submit(r)
                admitted.append(r)
            except ServeOverloaded as e:
                shed.append(r)
                assert "shed" in str(e) and "queue full" in str(e)
        assert shed, "expected the bounded queue to overflow"
        assert len(admitted) >= 3                 # 1 started + 2 queued
        done = bat.run(timeout_s=120.0)
        # no admitted request lost, none double-answered
        assert sorted(r.rid for r in done) == sorted(r.rid for r in admitted)
        assert all(r.result is not None for r in admitted)
        assert all(r.result is None for r in shed)
        outs = {r.rid: r.result for r in done}
        for r, want in zip(admitted, _oracle(arr, admitted)):
            assert outs[r.rid] == want
        assert metrics.shed == len(shed)
        assert metrics.busy_events >= 1
        assert metrics.transitions.get("open->queueing", 0) >= 1
        assert metrics.transitions.get("queueing->shedding", 0) >= 1
        assert metrics.state == "open"            # walked back down
        ck.close_sync(fh)
    finally:
        svc.shutdown()
    assert _shm_leftovers() == []


def test_inflight_byte_budget_queues_without_service(tmp_path):
    """The second backpressure trigger: open-session prompt bytes over
    ``max_inflight_bytes`` queue new submits even on the thread backend."""
    n, L = 6, 64
    path, arr, meta = _token_file(tmp_path, n * L)
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(num_readers=1, backend="thread"))
    nbytes_one = L * 4
    metrics = ServeMetrics()
    ing = RequestIngester(ck, fh, meta, metrics, max_pending=n,
                          max_inflight_bytes=nbytes_one)   # one session max
    bat = ContinuousBatcher(ModeledEngine(slots=2, vocab=VOCAB), ing)
    reqs = _requests(n, L, [3] * n)
    for r in reqs:
        ing.submit(r)
    assert metrics.over_budget_events >= 1
    assert metrics.state == "queueing"
    done = bat.run()
    assert sorted(r.rid for r in done) == list(range(n))
    outs = {r.rid: r.result for r in done}
    for r, want in zip(reqs, _oracle(arr, reqs)):
        assert outs[r.rid] == want
    assert metrics.inflight_bytes_hwm <= nbytes_one
    ck.close_sync(fh)


# -- slot turnover ------------------------------------------------------------
def test_eviction_and_admission_mid_decode(tmp_path):
    """With more requests than slots, a slot must turn over mid-decode:
    some request's first token lands AFTER another's eviction, which a
    static batch never does within a batch."""
    n, L = 5, 32
    path, arr, meta = _token_file(tmp_path, n * L)
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(num_readers=1, backend="thread"))
    metrics = ServeMetrics()
    ing = RequestIngester(ck, fh, meta, metrics)
    eng = ModeledEngine(slots=2, vocab=VOCAB)
    bat = ContinuousBatcher(eng, ing)
    reqs = _requests(n, L, [8, 1, 1, 1, 8])
    for r in reqs:
        ing.submit(r)
    done = bat.run()
    assert len(done) == n
    outs = {r.rid: r.result for r in done}
    for r, want in zip(reqs, _oracle(arr, reqs)):
        assert outs[r.rid] == want
    assert metrics.admissions == n > eng.slots    # slots were reused
    assert metrics.evictions == n
    first_evict = min(r.t_done for r in done)
    last_first_token = max(r.t_first_token for r in done)
    assert first_evict < last_first_token         # admission mid-decode
    assert 0.0 < metrics.mean_occupancy() <= 1.0
    ck.close_sync(fh)


def test_eos_eviction(tmp_path):
    """EOS mid-stream evicts early (EOS token included, stream truncated)
    and matches the oracle under the same completion rule."""
    n, L = 2, 32
    path, arr, meta = _token_file(tmp_path, n * L)
    base = sequential_oracle(
        ModeledEngine(slots=1, vocab=VOCAB),
        [arr[i * L:(i + 1) * L] for i in range(n)], [8, 8])
    eos = base[0][2]                              # request 0 hits EOS at pos 2
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(num_readers=1, backend="thread"))
    ing = RequestIngester(ck, fh, meta)
    bat = ContinuousBatcher(ModeledEngine(slots=2, vocab=VOCAB), ing)
    reqs = _requests(n, L, [8, 8], eos_id=eos)
    for r in reqs:
        ing.submit(r)
    done = bat.run()
    outs = {r.rid: r.result for r in done}
    for r, want in zip(reqs, _oracle(arr, reqs)):
        assert outs[r.rid] == want
    assert outs[0][-1] == eos and len(outs[0]) <= 8
    ck.close_sync(fh)


# -- static baseline (engine-based) -------------------------------------------
def test_static_batcher_bit_identical_but_batched_latency(tmp_path):
    """The StaticBatcher baseline produces the same tokens (bit-identity)
    but returns every batch member at batch end — its per-request e2e
    latency is bounded below by the batch straggler."""
    n, L = 4, 32
    path, arr, meta = _token_file(tmp_path, n * L)
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(num_readers=1, backend="thread"))
    metrics = ServeMetrics()
    ing = RequestIngester(ck, fh, meta, metrics)
    bat = StaticBatcher(ModeledEngine(slots=4, vocab=VOCAB), ing,
                        batch_size=4)
    reqs = _requests(n, L, [1, 2, 3, 9])
    for r in reqs:
        ing.submit(r)
    done = bat.run()
    outs = {r.rid: r.result for r in done}
    for r, want in zip(reqs, _oracle(arr, reqs)):
        assert outs[r.rid] == want
    t_dones = {r.rid: r.t_done for r in done}
    assert len(set(round(t, 6) for t in t_dones.values())) == 1  # batch end
    ck.close_sync(fh)


# -- faults under churn -------------------------------------------------------
def test_fault_plan_crash_mid_churn_recovers_one_request(tmp_path):
    """Seeded FaultPlan worker crash on ONE request's pooled session
    (process substrate — crash hooks os._exit): that session recovers via
    its own ``recovery="reissue"`` and the sibling requests keep serving
    through the same pool, all bit-identical."""
    n, L = 3, 64 * 1024                           # 256 KiB per prompt span
    path, arr, meta = _token_file(tmp_path, n * L)
    plan = FaultPlan(seed=SEED, crash=True, num_readers=2, num_splinters=8)
    ck = CkIO(num_pes=4)
    svc = ReaderService(ServiceOptions(pool_workers=2, backend="process"))
    ck.director.attach_service(svc)
    metrics = ServeMetrics()
    session_metrics = []
    ck.director.add_observer(metrics.record_session)
    ck.director.add_observer(session_metrics.append)
    try:
        common = dict(num_readers=2, max_workers=2,
                      splinter_bytes=32 * 1024, backend="process",
                      use_service=True)
        fh_ok = ck.open_sync(path, FileOptions(**common))
        fh_bad = ck.open_sync(path, FileOptions(
            recovery="reissue", fault_plan=plan, **common))
        ing = RequestIngester(ck, fh_ok, meta, metrics, service=svc)
        bat = ContinuousBatcher(ModeledEngine(slots=2, vocab=VOCAB), ing)
        reqs = _requests(n, L, [4, 4, 4])
        reqs[1].file = fh_bad                     # the faulted request
        for r in reqs:
            ing.submit(r)
        done = bat.run(timeout_s=300.0)
        assert sorted(r.rid for r in done) == list(range(n))
        outs = {r.rid: r.result for r in done}
        for r, want in zip(reqs, _oracle(arr, reqs)):
            assert outs[r.rid] == want
        assert metrics.failed == 0
        # exactly one session recovered; siblings rode clean workers
        recovered = [m for m in session_metrics if m.recovery.reissues > 0]
        assert len(recovered) == 1
        assert svc.metrics.workers_evicted >= 1
        assert svc.metrics.sessions_failed == 0
        ck.close_sync(fh_ok)
        ck.close_sync(fh_bad)
    finally:
        svc.shutdown()
    assert _shm_leftovers() == []


# -- metrics fold -------------------------------------------------------------
def test_percentile_fold_monotone():
    rng = np.random.default_rng(SEED)
    for n in (1, 2, 7, 100, 999):
        vals = rng.exponential(1.0, size=n).tolist()
        qs = [0.0, 10.0, 50.0, 90.0, 99.0, 99.9, 100.0]
        ps = [percentile(vals, q) for q in qs]
        assert ps == sorted(ps)                   # monotone in q
        assert ps[-1] == max(vals)
        assert min(vals) <= ps[0]
    assert percentile([], 99.0) == 0.0


def test_serve_metrics_percentiles_and_states():
    m = ServeMetrics()
    for v in (0.1, 0.5, 0.2, 0.9, 0.3):
        m.record_ingested(v)
    p = m.latency_percentiles("ingest")
    assert p["p50"] <= p["p99"] <= p["p999"] <= 0.9
    m.set_state("queueing")
    m.set_state("queueing")                       # no self-transition
    m.set_state("shedding")
    m.set_state("queueing")
    m.set_state("open")
    assert m.transitions == {"open->queueing": 1, "queueing->shedding": 1,
                             "shedding->queueing": 1, "queueing->open": 1}
    s = m.summary()
    assert s["bp_transitions"] == 4.0
    for k in ("ingest_p50_s", "first_token_p99_s", "e2e_p999_s",
              "mean_occupancy", "sessions_per_s"):
        assert k in s


# -- legacy static path: arrival-time accounting ------------------------------
class _FakeModel:
    """Duck-typed model_zoo.Model: deterministic hash-state decode, jit-safe."""

    vocab = 61

    def init(self, key):
        return {"w": jnp.zeros(())}

    def init_decode_state(self, params, B, budget, frames=None):
        return {"h": jnp.ones((B,), jnp.int32)}

    def decode(self, params, state, batch):
        tok = batch["tokens"][:, -1].astype(jnp.int32)
        h = (state["h"] * 31 + tok + 7) % 1009
        logits = jax.nn.one_hot((h * 17) % self.vocab, self.vocab,
                                dtype=jnp.float32)
        return logits[:, None, :], {"h": h}


def test_batchserver_latency_measured_from_arrival():
    model = _FakeModel()
    params = model.init(None)
    rng = np.random.default_rng(SEED)
    prompts = rng.integers(0, 61, size=(3, 8), dtype=np.int32)
    t_arrive = time.perf_counter() - 0.5          # arrived 500 ms ago
    reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=4,
                    arrival_t=t_arrive) for i in range(3)]
    server = BatchServer(model, params, batch_size=2)
    done = server.serve(reqs)
    for r in done:
        assert r.latency_s >= 0.5                 # queueing time included
        assert r.queue_wait_s >= 0.5
        assert r.service_s > 0.0
        assert abs((r.queue_wait_s + r.service_s) - r.latency_s) < 0.05
    # legacy callers without arrival stamps: latency == service-side time
    legacy = [Request(rid=9, prompt=prompts[0], max_new_tokens=4)]
    server.serve(legacy)
    assert legacy[0].latency_s < 0.5
    assert legacy[0].arrival_t is not None


def test_model_engine_matches_greedy_generate(tmp_path):
    """ModelEngine continuous decode == per-request greedy_generate (the
    serve_step reference), prompts ingested through CkIO."""
    n, L = 4, 8
    rng = np.random.default_rng(SEED)
    arr = rng.integers(0, 61, size=(n * L,), dtype=np.int32)
    path = str(tmp_path / "fake_prompts.bin")
    write_token_file(path, arr)
    meta = read_meta(path)
    model = _FakeModel()
    params = model.init(None)
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(num_readers=1, backend="thread"))
    ing = RequestIngester(ck, fh, meta)
    eng = ModelEngine(model, params, slots=2, seq_budget=L + 6)
    bat = ContinuousBatcher(eng, ing)
    reqs = _requests(n, L, [5, 3, 4, 5])
    for r in reqs:
        ing.submit(r)
    done = bat.run()
    outs = {r.rid: r.result for r in done}
    for r in reqs:
        prompt = arr[r.row_start: r.row_start + r.num_rows]
        want = np.asarray(greedy_generate(
            model, params, jnp.asarray(prompt[None, :]),
            r.max_new_tokens))[0].tolist()
        assert outs[r.rid] == want
    ck.close_sync(fh)
