"""Sharding-rule validation for every arch on abstract production meshes —
no devices needed: every assigned spec must divide its dim evenly (jit
argument requirement) and batch/vocab/expert rules must hold."""
import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs.base import SHAPES
from repro.configs.registry import cells, get_config, list_archs
from repro.launch import sharding as shd
from repro.models import build_model
from repro.train.optimizer import init_opt_state

def _abstract_mesh(shape, names):
    """AbstractMesh across jax versions: newer jax takes one
    ``((name, size), ...)`` tuple, older jax took ``(shape, names)``."""
    try:
        return AbstractMesh(tuple(zip(names, shape)))
    except TypeError:
        return AbstractMesh(shape, names)


POD = _abstract_mesh((16, 16), ("data", "model"))
MULTIPOD = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _axis_sizes(mesh):
    return dict(mesh.shape)


def _check_divisible(abstract_tree, spec_tree, mesh, ctx):
    sizes = _axis_sizes(mesh)
    flat_a, treedef = jax.tree_util.tree_flatten(abstract_tree)
    flat_s = treedef.flatten_up_to(spec_tree)
    sharded = 0
    for leaf, spec in zip(flat_a, flat_s):
        assert isinstance(spec, P), (ctx, spec)
        assert len(spec) <= len(leaf.shape), (ctx, leaf.shape, spec)
        for dim, s in zip(leaf.shape, tuple(spec)):
            if s is None:
                continue
            axes = (s,) if isinstance(s, str) else s
            k = 1
            for a in axes:
                k *= sizes[a]
            assert dim % k == 0, (ctx, leaf.shape, spec, dim, k)
            sharded += 1
    return sharded


@pytest.mark.parametrize("mesh", [POD, MULTIPOD], ids=["pod", "multipod"])
@pytest.mark.parametrize("arch", list_archs())
def test_param_and_opt_specs_divide(arch, mesh):
    model = build_model(get_config(arch))
    p_abs = model.abstract_params()
    p_specs = shd.param_specs(p_abs, mesh)
    n = _check_divisible(p_abs, p_specs, mesh, f"{arch}/params")
    assert n > 0, f"{arch}: nothing sharded at all"
    o_abs = jax.eval_shape(lambda p: init_opt_state(p, master_weights=True),
                           p_abs)
    o_specs = shd.opt_state_specs(p_abs, p_specs, mesh, master_weights=True)
    _check_divisible(o_abs, o_specs, mesh, f"{arch}/opt")
    # ZeRO: moments must be sharded strictly more than params somewhere
    p_axes = sum(1 for s in jax.tree.leaves(p_specs,
                 is_leaf=lambda x: isinstance(x, P))
                 for a in s if a is not None)
    m_axes = sum(1 for s in jax.tree.leaves(o_specs["mu"],
                 is_leaf=lambda x: isinstance(x, P))
                 for a in s if a is not None)
    assert m_axes > p_axes, f"{arch}: ZeRO-1 added no data-axis sharding"


@pytest.mark.parametrize("mesh", [POD, MULTIPOD], ids=["pod", "multipod"])
def test_batch_and_state_specs_all_cells(mesh):
    for arch, shape in cells():
        model = build_model(get_config(arch))
        b_abs = model.input_specs(shape)
        b_specs = shd.batch_specs(b_abs, mesh)
        _check_divisible(b_abs, b_specs, mesh, f"{arch}/{shape.name}/batch")
        if shape.kind == "decode":
            st_abs = model.decode_state_specs(shape)
            st_specs = shd.decode_state_specs(st_abs, mesh)
            _check_divisible(st_abs, st_specs, mesh,
                             f"{arch}/{shape.name}/state")


def test_expert_dim_is_sharded_for_moe():
    mesh = POD
    for arch in ("qwen2-moe-a2.7b", "olmoe-1b-7b"):
        model = build_model(get_config(arch))
        p_specs = shd.param_specs(model.abstract_params(), mesh)
        spec = p_specs["blocks"]["l0"]["ffn"]["gate"]
        assert tuple(spec) == (None, "model", None, None), (arch, spec)


def test_headdim_fallback_for_small_kv():
    mesh = POD
    model = build_model(get_config("qwen2-vl-2b"))     # kv = 2 < 16
    p_specs = shd.param_specs(model.abstract_params(), mesh)
    wk = p_specs["blocks"]["l0"]["mixer"]["wk"]
    assert tuple(wk) == (None, None, None, "model"), wk  # head_dim sharded
    wq = p_specs["blocks"]["l0"]["mixer"]["wq"]
    assert "model" in tuple(wq), wq


def test_logits_spec_rules():
    assert shd.logits_spec(POD, 128, 151936) == P("data", None, "model")
    assert shd.logits_spec(POD, 1, 151936) == P(None, None, "model")
    assert shd.logits_spec(POD, 128, 51865) == P("data", None, None)
    mp = shd.logits_spec(MULTIPOD, 256, 151936)
    assert mp == P(("pod", "data"), None, "model")
