"""Optional-hypothesis shim for the property-based tests.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt). When it
is installed the real ``given``/``settings``/``st`` are re-exported and the
property tests run; when it is missing each ``@given`` test is marked
skipped — module collection (and every non-property test in the module)
survives either way, unlike a module-level ``pytest.importorskip`` which
would drop the whole file.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any strategy construction; never executed."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda fn: fn
