"""Device-side reassembly: index maps, gather kernels (interpret mode),
pipeline device-ingest path, staged-buffer lifetime, and elastic-shrink
deregistration.

Property tests run under hypothesis when installed (tests/hypothesis_compat);
seeded randomized sweeps cover the same ground without it.
"""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import CkIO, FileOptions
from repro.data import CkIOPipeline, make_token_file
from repro.data.packing import (
    as_block_permutation,
    pieces_in_arrival_order,
    row_gather_index,
    token_gather_from_pieces,
)
from repro.io.layout import plan_session
from repro.kernels import ops, ref
from repro.kernels.reassemble import (
    reassemble_pallas,
    reassemble_tokens_pallas,
    reassemble_window_pallas,
)


# -- NumPy oracle -------------------------------------------------------------

def np_batch_oracle(linear, B, S, w0=0, valid_limit=None, pad_id=0):
    """Ground truth for the fused window reassembly (pure NumPy)."""
    S1 = S + 1
    full_limit = w0 + B * S1
    if valid_limit is None:
        valid_limit = full_limit
    buf = np.full(full_limit + 1, pad_id, dtype=linear.dtype)
    n = min(linear.size, full_limit + 1)
    buf[:n] = linear[:n]
    pos = w0 + np.arange(B)[:, None] * S1 + np.arange(S1 + 1)[None, :]
    rows = buf[pos]
    inputs = np.where(pos[:, :S] < valid_limit, rows[:, :S], pad_id)
    labels = np.where(pos[:, 1:S + 1] < valid_limit, rows[:, 1:S + 1], pad_id)
    return inputs, labels


def random_arrival_pieces(rng, session_off, num_tokens, itemsize):
    """Split a session into 1..8 contiguous token ranges, shuffle arrival."""
    ncuts = int(rng.integers(0, min(7, num_tokens - 1) + 1))
    cuts = np.sort(rng.choice(np.arange(1, num_tokens), size=ncuts,
                              replace=False)) if ncuts else np.array([], int)
    bounds = [0, *cuts.tolist(), num_tokens]
    pieces = [
        (session_off + bounds[i] * itemsize,
         (bounds[i + 1] - bounds[i]) * itemsize)
        for i in range(len(bounds) - 1)
    ]
    rng.shuffle(pieces)
    return pieces


# -- index-map construction ---------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_token_gather_roundtrips_random_pieces(seed):
    rng = np.random.default_rng(seed)
    num_tokens = int(rng.integers(1, 200))
    session_off = int(rng.integers(0, 5)) * 4
    toks = rng.integers(0, 1 << 30, size=num_tokens).astype(np.int32)
    pieces = random_arrival_pieces(rng, session_off, num_tokens, 4)
    g = token_gather_from_pieces(pieces, session_off, 4)
    staged = np.concatenate([
        toks[(off - session_off) // 4:(off - session_off) // 4 + nb // 4]
        for off, nb in pieces
    ])
    np.testing.assert_array_equal(staged[g], toks)


def test_token_gather_rejects_bad_plans():
    with pytest.raises(ValueError):
        token_gather_from_pieces([(0, 8), (4, 8)], 0, 4)       # overlap
    with pytest.raises(ValueError):
        token_gather_from_pieces([(0, 6)], 0, 4)               # misaligned
    with pytest.raises(ValueError):
        token_gather_from_pieces([(8, 8)], 0, 4)               # outside


def test_as_block_permutation_detects_and_rejects():
    T = 4
    perm = np.array([2, 0, 3, 1], np.int32)
    # g for "file block f sits at staged block perm[f]"
    g = (perm[:, None] * T + np.arange(T)[None, :]).reshape(-1)
    got = as_block_permutation(g, T)
    assert got is not None
    np.testing.assert_array_equal(got, perm)
    # identity
    ident = np.arange(16, dtype=np.int32)
    np.testing.assert_array_equal(as_block_permutation(ident, 4),
                                  np.arange(4))
    # non-uniform layout -> None
    g2 = g.copy()
    g2[[0, 1]] = g2[[1, 0]]
    assert as_block_permutation(g2, T) is None
    assert as_block_permutation(g, 3) is None                  # wrong T


def test_row_gather_index_marks_padding():
    g = np.arange(20, dtype=np.int32)
    idx = row_gather_index(g, global_batch=2, seq_len=3, window_tok_off=2,
                           valid_tokens=7)
    assert idx.shape == (2, 4)            # (B, S+1)
    # window flat token p valid iff p < 7 and 2+p < 20
    S1 = 4
    for b in range(2):
        for j in range(4):
            p = b * S1 + j
            if p < 7:
                assert idx[b, j] == 2 + p
            else:
                assert idx[b, j] == -1


# -- kernels vs oracle (interpret mode) ---------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_window_kernel_random_offsets_and_remainders(seed):
    rng = np.random.default_rng(100 + seed)
    B = int(rng.integers(1, 5))
    S = int(rng.integers(2, 17))
    S1 = S + 1
    w0 = int(rng.integers(0, 3 * S1))
    valid = int(rng.integers(1, B * S1 + 1))
    lin = rng.integers(1, 1 << 20, size=w0 + valid).astype(np.int32)
    want = np_batch_oracle(lin, B, S, w0, w0 + valid, pad_id=0)
    got = reassemble_window_pallas(
        jnp.asarray(lin), global_batch=B, seq_len=S, window_tok_off=w0,
        valid_limit=w0 + valid, pad_id=0, interpret=True)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), w)
    # jnp reference agrees too
    got_ref = ref.window_batch_ref(
        jnp.asarray(lin), global_batch=B, seq_len=S, window_tok_off=w0,
        valid_limit=w0 + valid, pad_id=0)
    for g, w in zip(got_ref, want):
        np.testing.assert_array_equal(np.asarray(g), w)


def test_window_kernel_label_shift_exact():
    B, S = 2, 4
    lin = np.arange(100, 100 + B * (S + 1) + 1, dtype=np.int32)
    x, y = reassemble_window_pallas(jnp.asarray(lin), global_batch=B,
                                    seq_len=S, interpret=True)
    np.testing.assert_array_equal(np.asarray(x),
                                  [[100, 101, 102, 103], [105, 106, 107, 108]])
    np.testing.assert_array_equal(np.asarray(y),
                                  [[101, 102, 103, 104], [106, 107, 108, 109]])


@pytest.mark.parametrize("NB,T", [(6, 4), (3, 8), (1, 5)])
def test_block_gather_2d_roundtrip(NB, T):
    rng = np.random.default_rng(7)
    src = rng.integers(0, 1000, size=(NB, T)).astype(np.int32)
    perm = rng.permutation(NB).astype(np.int32)
    out = reassemble_pallas(jnp.asarray(src), jnp.asarray(perm),
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(out), src[perm])


@pytest.mark.parametrize("seed", range(4))
def test_token_kernel_matches_ref(seed):
    rng = np.random.default_rng(200 + seed)
    B = int(rng.integers(1, 4))
    S = int(rng.integers(2, 10))
    L = int(rng.integers(B * (S + 1), 4 * B * (S + 1)))
    staged = rng.integers(0, 1000, size=L).astype(np.int32)
    row_idx = rng.integers(-1, L, size=(B, S + 1)).astype(np.int32)
    got = reassemble_tokens_pallas(jnp.asarray(staged), jnp.asarray(row_idx),
                                   pad_id=9, interpret=True)
    want = ref.tokens_gather_ref(jnp.asarray(staged), jnp.asarray(row_idx),
                                 pad_id=9)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# -- end-to-end device_ingest dispatch ----------------------------------------

@pytest.mark.parametrize("seed", range(5))
def test_device_ingest_arbitrary_arrival_order(seed):
    """Arbitrary splinter permutations + window offsets + remainder windows
    round-trip exactly through the on-device path (interpret kernels)."""
    rng = np.random.default_rng(300 + seed)
    B = int(rng.integers(1, 4))
    S = int(rng.integers(2, 12))
    S1 = S + 1
    w0 = int(rng.integers(0, 2 * S1))
    valid = int(rng.integers(1, B * S1 + 1))
    session_tokens = rng.integers(1, 1 << 20, size=w0 + valid).astype(np.int32)
    pieces = random_arrival_pieces(rng, 0, session_tokens.size, 4)
    g = token_gather_from_pieces(pieces, 0, 4)
    staged = np.concatenate(
        [session_tokens[o // 4:o // 4 + nb // 4] for o, nb in pieces])
    want = np_batch_oracle(session_tokens, B, S, w0, w0 + valid, pad_id=0)
    got = ops.device_ingest(
        jnp.asarray(staged), g, global_batch=B, seq_len=S,
        window_tok_off=w0, valid_tokens=valid, use_pallas=True)
    for a, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), w)


def test_device_ingest_block_permutation_path():
    rng = np.random.default_rng(9)
    T, NB, B, S = 8, 6, 4, 11   # NB*T = 48 = B*(S+1) tokens
    session_tokens = rng.integers(1, 1000, size=NB * T).astype(np.int32)
    perm = rng.permutation(NB).astype(np.int32)
    pieces = [(int(f) * T * 4, T * 4)
              for f in np.argsort(perm)]       # arrival = staged order
    g = token_gather_from_pieces(pieces, 0, 4)
    assert as_block_permutation(g, T) is not None
    staged = session_tokens.reshape(NB, T)[np.argsort(perm)].reshape(-1)
    want = np_batch_oracle(session_tokens, B, S)
    got = ops.device_ingest(jnp.asarray(staged), g, global_batch=B,
                            seq_len=S, block_tokens=T, use_pallas=True)
    for a, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), w)


# -- hypothesis properties (auto-skipped when hypothesis is missing) ----------

@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_prop_token_gather_roundtrip(data):
    num_tokens = data.draw(st.integers(1, 300))
    session_off = data.draw(st.integers(0, 8)) * 4
    ncuts = data.draw(st.integers(0, min(10, num_tokens - 1)))
    cuts = sorted(data.draw(st.sets(
        st.integers(1, num_tokens - 1), min_size=ncuts, max_size=ncuts))
    ) if num_tokens > 1 else []
    bounds = [0, *cuts, num_tokens]
    pieces = [
        (session_off + bounds[i] * 4, (bounds[i + 1] - bounds[i]) * 4)
        for i in range(len(bounds) - 1)
    ]
    pieces = data.draw(st.permutations(pieces))
    toks = np.arange(num_tokens, dtype=np.int32)
    g = token_gather_from_pieces(pieces, session_off, 4)
    staged = np.concatenate([
        toks[(o - session_off) // 4:(o - session_off) // 4 + nb // 4]
        for o, nb in pieces])
    np.testing.assert_array_equal(staged[g], toks)


@settings(max_examples=30, deadline=None)
@given(
    B=st.integers(1, 4), S=st.integers(2, 16),
    w0=st.integers(0, 40), frac=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**16),
)
def test_prop_window_kernel_matches_oracle(B, S, w0, frac, seed):
    S1 = S + 1
    valid = max(1, int(frac * B * S1))
    rng = np.random.default_rng(seed)
    lin = rng.integers(1, 1 << 20, size=w0 + valid).astype(np.int32)
    want = np_batch_oracle(lin, B, S, w0, w0 + valid)
    got = reassemble_window_pallas(
        jnp.asarray(lin), global_batch=B, seq_len=S, window_tok_off=w0,
        valid_limit=w0 + valid, interpret=True)
    for a, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), w)


@settings(max_examples=25, deadline=None)
@given(B=st.integers(1, 3), S=st.integers(2, 10),
       seed=st.integers(0, 2**16))
def test_prop_device_ingest_permuted_pieces(B, S, seed):
    rng = np.random.default_rng(seed)
    S1 = S + 1
    w0 = int(rng.integers(0, 2 * S1))
    valid = int(rng.integers(1, B * S1 + 1))
    toks = rng.integers(1, 1 << 20, size=w0 + valid).astype(np.int32)
    pieces = random_arrival_pieces(rng, 0, toks.size, 4)
    g = token_gather_from_pieces(pieces, 0, 4)
    staged = np.concatenate(
        [toks[o // 4:o // 4 + nb // 4] for o, nb in pieces])
    want = np_batch_oracle(toks, B, S, w0, w0 + valid)
    got = ops.device_ingest(jnp.asarray(staged), g, global_batch=B,
                            seq_len=S, window_tok_off=w0,
                            valid_tokens=valid, use_pallas=True)
    for a, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), w)


# -- pipeline device path -----------------------------------------------------

@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("devingest") / "corpus.bin")
    make_token_file(path, 50_000, vocab_size=321, seed=11)
    raw = np.fromfile(path, dtype=np.uint32, offset=4096).view(np.int32)
    return path, raw


def make_pipe(path, **kw):
    kw.setdefault("num_pes", 2)
    kw.setdefault("num_consumers", 8)
    kw.setdefault("file_opts", FileOptions(num_readers=2,
                                           splinter_bytes=32 * 1024))
    return CkIOPipeline(path, global_batch=4, seq_len=64, **kw)


def test_pipeline_device_path_matches_file(corpus):
    path, raw = corpus
    pipe = make_pipe(path)
    need = 4 * 65
    for s in range(4):
        x, y = pipe.get_batch_device(s)
        ref_w = raw[s * need:(s + 1) * need].reshape(4, 65)
        np.testing.assert_array_equal(np.asarray(x), ref_w[:, :-1])
        np.testing.assert_array_equal(np.asarray(y), ref_w[:, 1:])
    m = pipe.ingest.summary()
    assert m["host_permute_bytes"] == 0
    assert m["h2d_transfers"] == 4          # exactly one transfer per step
    assert m["device_steps"] == 4
    pipe.close()


def test_pipeline_device_matches_host_path(corpus):
    path, _ = corpus
    pipe_h = make_pipe(path)
    pipe_d = make_pipe(path)
    for s in range(3):
        xh, yh = pipe_h.get_batch(s)
        xd, yd = pipe_d.get_batch_device(s)
        np.testing.assert_array_equal(xh, np.asarray(xd))
        np.testing.assert_array_equal(yh, np.asarray(yd))
    assert pipe_h.ingest.host_permute_bytes > 0
    assert pipe_d.ingest.host_permute_bytes == 0
    pipe_h.close()
    pipe_d.close()


def test_pipeline_device_pallas_interpret_matches(corpus):
    path, raw = corpus
    pipe = make_pipe(path)
    need = 4 * 65
    x, y = pipe.get_batch_device(0, use_pallas=True)   # interpret on CPU
    ref_w = raw[:need].reshape(4, 65)
    np.testing.assert_array_equal(np.asarray(x), ref_w[:, :-1])
    np.testing.assert_array_equal(np.asarray(y), ref_w[:, 1:])
    pipe.close()


def test_pipeline_device_remainder_window(tmp_path):
    path = str(tmp_path / "rem.bin")
    make_token_file(path, 1000, vocab_size=50, seed=3)
    raw = np.fromfile(path, dtype=np.uint32, offset=4096).view(np.int32)
    pipe = CkIOPipeline(path, global_batch=2, seq_len=32, num_pes=2,
                        drop_remainder=False,
                        file_opts=FileOptions(num_readers=2))
    S1 = 33
    rows = 2 * S1
    assert pipe.num_steps == (1000 + rows - 1) // rows
    last = pipe.num_steps - 1
    valid = 1000 - last * rows
    assert 0 < valid < rows
    want = np_batch_oracle(raw[last * rows:], 2, 32, 0, valid)
    xd, yd = pipe.get_batch_device(last)
    np.testing.assert_array_equal(np.asarray(xd), want[0])
    np.testing.assert_array_equal(np.asarray(yd), want[1])
    # host path agrees on the padded remainder
    xh, yh = pipe.get_batch(last)
    np.testing.assert_array_equal(xh, want[0])
    np.testing.assert_array_equal(yh, want[1])
    pipe.close()


def test_pipeline_copy_mode_device_path(corpus):
    path, raw = corpus
    pipe = make_pipe(path, zero_copy=False)
    need = 4 * 65
    x, y = pipe.get_batch_device(0)
    np.testing.assert_array_equal(np.asarray(x),
                                  raw[:need].reshape(4, 65)[:, :-1])
    assert pipe.ingest.h2d_transfers == 1
    # copy mode pays the session→step-arena copy; the counter must say so
    assert pipe.ingest.host_permute_bytes == need * 4
    pipe.close()


def test_pipeline_arrival_order_feeds_index_map(corpus):
    """The exposed per-session arrival order + the layout plan reconstruct
    the session exactly (the staged-by-arrival model the maps serve)."""
    path, raw = corpus
    pipe = make_pipe(path, file_opts=FileOptions(num_readers=3,
                                                 splinter_bytes=8 * 1024))
    pipe.get_batch(0)
    sess = pipe._retired[-1]
    order = pipe.ck.session_arrival_order(sess)
    assert sorted(order) == list(range(len(sess.plan.splinters)))
    pieces = pieces_in_arrival_order(sess.plan.splinters, order)
    g = token_gather_from_pieces(pieces, sess.offset, 4)
    # simulate the arrival-ordered staging from the file bytes
    base = (sess.offset - 4096) // 4
    session_toks = raw[base:base + sess.nbytes // 4]
    staged = np.concatenate(
        [raw[(o - 4096) // 4:(o - 4096) // 4 + nb // 4] for o, nb in pieces])
    np.testing.assert_array_equal(staged[g], session_toks)
    pipe.close()


# -- lifetime regression ------------------------------------------------------

def test_staged_view_retires_on_next_fetch(corpus):
    path, raw = corpus
    pipe = make_pipe(path)
    need = 4 * 65
    x0, y0 = pipe.get_batch_device(0)
    st = pipe._staged[-1]
    mv = st.host_view
    assert mv is not None and not st.staged is None
    x1, _ = pipe.get_batch_device(1)
    # use-after-retire raises rather than reading freed arena
    with pytest.raises(ValueError):
        bytes(mv)
    assert st.host_tokens is None and st.staged is None
    # the device arrays own their storage: both steps still readable
    np.testing.assert_array_equal(np.asarray(x0),
                                  raw[:need].reshape(4, 65)[:, :-1])
    np.testing.assert_array_equal(np.asarray(x1),
                                  raw[need:2 * need].reshape(4, 65)[:, :-1])
    pipe.close()


def test_staged_view_valid_until_next_fetch(corpus):
    path, raw = corpus
    pipe = make_pipe(path)
    pipe.get_batch_device(2)
    st = pipe._staged[-1]
    # until the next get_batch*/close the staged host view stays readable
    got = np.frombuffer(bytes(st.host_view), dtype=np.int32)
    need = 4 * 65
    np.testing.assert_array_equal(got, raw[2 * need:3 * need])
    pipe.close()


def test_close_releases_staged_refs(corpus):
    path, _ = corpus
    pipe = make_pipe(path)
    pipe.get_batch_device(0)
    mv = pipe._staged[-1].host_view
    pipe.close()
    with pytest.raises(ValueError):
        bytes(mv)


def test_zero_copy_across_resize_and_migration(corpus):
    path, raw = corpus
    pipe = make_pipe(path)
    need = 4 * 65
    sessions = []
    x, _ = pipe.get_batch_device(0)
    sessions.append(pipe._retired[-1])
    pipe.resize(12)                       # grow mid-stream
    x1, _ = pipe.get_batch_device(1)
    sessions.append(pipe._retired[-1])
    pipe.migrate_consumer(0, 1)
    pipe.resize(5)                        # shrink mid-stream
    x2, _ = pipe.get_batch_device(2)
    sessions.append(pipe._retired[-1])
    for s, sess in enumerate(sessions):
        assert sess.metrics.bytes_copied == 0, f"step {s} copied bytes"
    np.testing.assert_array_equal(np.asarray(x2),
                                  raw[2 * need:3 * need].reshape(4, 65)[:, :-1])
    assert pipe.ingest.host_permute_bytes == 0
    pipe.close()


# -- elastic shrink deregistration (satellite fix) ----------------------------

def test_resize_shrink_deregisters_consumers(corpus):
    path, _ = corpus
    pipe = make_pipe(path)
    loc = pipe.ck.locations
    assert loc.count() == 8
    pipe.resize(16)
    assert loc.count() == 16
    pipe.resize(4)
    assert loc.count() == 4               # dropped consumers deregistered
    for _ in range(5):                    # shrink→grow cycles must not leak
        pipe.resize(12)
        pipe.resize(6)
    assert loc.count() == 6
    pipe.close()


def test_deregistered_consumer_delivery_falls_back_home(tmp_path):
    """A completion racing an elastic shrink lands on the home PE instead of
    raising KeyError on the retired virtual id."""
    ck = CkIO(num_pes=4)
    client = ck.make_client(pe=3)
    got = []
    cb = client.callback(got.append)
    client.deregister()
    cb.send(ck.sched, "late-completion")   # must not raise
    ck.sched.pump()
    assert got == ["late-completion"]
    assert ck.locations.stale_deliveries == 1
    client.deregister()                    # idempotent
    with pytest.raises(KeyError):
        client.migrate(0)                  # strict ops still raise


def test_shrink_with_inflight_reads_completes(tmp_path):
    """Shrink while a delayed session is mid-read: the step still completes
    (stale deliveries fall back) and nothing leaks."""
    path = str(tmp_path / "slow.bin")
    make_token_file(path, 30_000, vocab_size=77, seed=8)
    opts = FileOptions(num_readers=2, splinter_bytes=16 * 1024,
                       delay_model=lambda r, sp: 0.02)
    pipe = CkIOPipeline(path, global_batch=2, seq_len=32, num_pes=2,
                        num_consumers=8, file_opts=opts)
    pipe.resize(2)                         # drop consumers with reads in flight
    x, y = pipe.get_batch(0)
    assert x.shape == (2, 32)
    assert pipe.ck.locations.count() == 2
    pipe.close()                           # joins the delayed reader threads
