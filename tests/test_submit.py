"""Cold-cache read engine tests: io/submit.py depth-managed submission,
O_DIRECT alignment contracts, backend selection, and fault survival.

The invariants under test (see the io/submit.py and io/posix.py module
docstrings for the contracts):

* queue depth is a hard ceiling — a submitter never holds more than
  ``depth`` reads in flight, and close() drains to zero;
* backend selection is explicit and inspectable — io_uring only for plain
  files without a delay model, descriptive ValueError when forced wrongly,
  ``CKIO_NO_IOURING`` forces the preadv pool;
* O_DIRECT never silently falls back — misaligned offsets/buffers/shards
  raise ``DirectIOError`` naming the violation; legal sub-block tails go
  through the buffered fd and are counted;
* every mode x backend combination drains bit-identically with zero
  copies;
* the PR-6 fault hooks (FlakyEIO / ShortRead) survive under async
  submission with retries counted in RecoveryMetrics.
"""
from __future__ import annotations

import hashlib
import os

import numpy as np
import pytest

from repro.core.api import CkIO
from repro.core.buffers import BufferReaderSet, ReaderOptions
from repro.core.faults import ComposedIOFault, FlakyEIO, ShortRead
from repro.core.scheduler import TaskScheduler
from repro.core.session import FileOptions
from repro.io.layout import plan_session
from repro.io.posix import DirectIOError, PosixFile, ShardedFile, fs_block_size
from repro.io.submit import (
    AsyncReadEngine,
    ThreadPoolSubmitter,
    io_uring_supported,
    make_submitter,
)

SEED = 20260809


@pytest.fixture
def blob(tmp_path):
    rng = np.random.default_rng(SEED)
    # Deliberately NOT a block multiple: the last splinter's tail is
    # shorter than an FS block (the O_DIRECT edge case).
    data = rng.integers(0, 256, 2 * 1024 * 1024 + 777,
                        dtype=np.uint8).tobytes()
    path = str(tmp_path / "submit_blob.bin")
    with open(path, "wb") as f:
        f.write(data)
    return path, data


def _items(data_len, chunk, arena):
    """Simple splinter source over [0, data_len) into ``arena``."""
    out = []
    pos = 0
    i = 0
    while pos < data_len:
        n = min(chunk, data_len - pos)
        out.append((i, pos, memoryview(arena)[pos: pos + n]))
        pos += n
        i += 1
    return out


# -- queue-depth invariants ----------------------------------------------------
@pytest.mark.parametrize("mode", ["threads", "auto"])
def test_depth_is_a_hard_ceiling(blob, mode):
    path, data = blob
    f = PosixFile.open(path)
    try:
        arena = np.empty(len(data), dtype=np.uint8)
        eng = AsyncReadEngine(f, 4, mode=mode)
        items = iter(_items(len(data), 128 * 1024, arena))
        got = {}

        def on_complete(token, n, dt):
            got[token] = n
            # live check, not just the high-water mark afterwards
            assert eng.sub.inflight() <= 4

        done = eng.run(lambda: next(items, None), on_complete)
        assert done == len(got) == (len(data) + 128 * 1024 - 1) // (128 * 1024)
        assert 1 <= eng.max_inflight <= 4
        assert arena.tobytes() == data
    finally:
        f.close()


def test_depth_violation_is_an_error(blob):
    path, data = blob
    f = PosixFile.open(path)
    try:
        arena = np.empty(4096 * 3, dtype=np.uint8)
        sub = ThreadPoolSubmitter(f, 2)
        try:
            sub.submit(0, 0, memoryview(arena)[0:4096])
            sub.submit(1, 4096, memoryview(arena)[4096:8192])
            assert not sub.can_submit()
            with pytest.raises(RuntimeError, match="depth"):
                sub.submit(2, 8192, memoryview(arena)[8192:12288])
        finally:
            sub.close(drain=True)
        assert sub.inflight() == 0          # drained on close
    finally:
        f.close()


def test_stop_drains_inflight(blob):
    path, data = blob
    f = PosixFile.open(path)
    try:
        arena = np.empty(len(data), dtype=np.uint8)
        eng = AsyncReadEngine(f, 4, mode="threads")
        items = iter(_items(len(data), 64 * 1024, arena))
        done = eng.run(lambda: next(items, None), lambda *a: None,
                       stop=lambda: True)
        assert done == 0                    # stopped before any delivery
        assert eng.sub.inflight() == 0      # nothing left in flight
    finally:
        f.close()


# -- backend selection ---------------------------------------------------------
def test_auto_selection_and_forced_io_uring_errors(blob, tmp_path,
                                                   monkeypatch):
    path, data = blob
    f = PosixFile.open(path)
    try:
        sub = make_submitter(f, 2, mode="auto")
        assert sub.kind == ("io_uring" if io_uring_supported() else "threads")
        sub.close()
        # a delay model forces the pool (the modeled-PFS sleep must run
        # per-read on a thread; the ring has nowhere to run it)
        sub = make_submitter(f, 2, mode="auto", delay=lambda t, n: None)
        assert sub.kind == "threads"
        sub.close()
        with pytest.raises(ValueError, match="delay"):
            make_submitter(f, 2, mode="io_uring", delay=lambda t, n: None)
        # env kill-switch wins over the kernel probe
        monkeypatch.setenv("CKIO_NO_IOURING", "1")
        assert not io_uring_supported()
        sub = make_submitter(f, 2, mode="auto")
        assert sub.kind == "threads"
        sub.close()
        with pytest.raises(ValueError, match="io_uring"):
            make_submitter(f, 2, mode="io_uring")
    finally:
        f.close()
    # sharded files never ride the ring directly
    half = len(data) // 2
    p2 = str(tmp_path / "s2.bin")
    with open(p2, "wb") as fh:
        fh.write(data[half:])
    sf = ShardedFile([(path, 0, 0, half, 0), (p2, half, 0, len(data) - half,
                                              1)])
    try:
        monkeypatch.delenv("CKIO_NO_IOURING", raising=False)
        sub = make_submitter(sf, 2, mode="auto")
        assert sub.kind == "threads"
        sub.close()
        with pytest.raises(ValueError, match="[Ss]harded"):
            make_submitter(sf, 2, mode="io_uring")
    finally:
        sf.close()


# -- O_DIRECT alignment contracts ----------------------------------------------
def test_direct_tail_shorter_than_block(blob):
    path, data = blob
    f = PosixFile.open(path, direct_io=True)
    try:
        bs = f.block_size
        assert len(data) % bs != 0          # fixture guarantees a tail
        raw = np.empty(len(data) + bs, dtype=np.uint8)
        skew = (-raw.ctypes.data) % bs
        arena = raw[skew: skew + len(data)]

        class Sink:
            tails = retries = 0

            def record_direct_tail(self, n=0):
                Sink.tails += 1

            def record_io_retry(self, err=None):
                Sink.retries += 1

        n = f.pread_into(0, memoryview(arena), stats=Sink())
        assert n == len(data)
        assert arena.tobytes() == data
        assert Sink.tails >= 1              # the sub-block tail was counted
    finally:
        f.close()


def test_direct_rejects_misalignment(blob, tmp_path):
    path, data = blob
    f = PosixFile.open(path, direct_io=True)
    try:
        bs = f.block_size
        raw = np.empty(bs * 2, dtype=np.uint8)
        skew = (-raw.ctypes.data) % bs
        aligned = raw[skew: skew + bs]
        with pytest.raises(DirectIOError, match="offset"):
            f.pread_into(1, memoryview(aligned))         # unaligned offset
        with pytest.raises(DirectIOError, match="buffer"):
            f.pread_into(0, memoryview(raw[skew + 1: skew + 1 + bs]))
    finally:
        f.close()
    # sharded: a shard whose data region starts off-grid is rejected at
    # open — with the offending segment named
    p2 = str(tmp_path / "shard2.bin")
    with open(p2, "wb") as fh:
        fh.write(data)
    bs = fs_block_size(path)
    with pytest.raises(DirectIOError, match="file_base"):
        ShardedFile([(path, 0, 100, len(data) - 100, 0)], direct_io=True)
    # an odd-sized INTERIOR shard puts every later shard's global start (and
    # with it that shard's arena positions) off the grid — rejected up front
    with pytest.raises(DirectIOError, match="global_start"):
        ShardedFile([(path, 0, 0, bs + 1, 0), (p2, bs + 1, 0, bs, 1)],
                    direct_io=True)


def test_direct_session_plan_misalignment_fails_fast(blob):
    """A direct session whose window sits off the block grid must fail at
    start() with a descriptive DirectIOError — never silently go buffered."""
    path, data = blob
    f = PosixFile.open(path, direct_io=True)
    sched = TaskScheduler(num_pes=2)
    try:
        plan = plan_session(100, 64 * 1024, 1, splinter_bytes=32 * 1024)
        rs = BufferReaderSet(f, plan, sched, [0],
                             ReaderOptions(splinter_bytes=32 * 1024,
                                           direct_io=True))
        with pytest.raises(DirectIOError, match="offset"):
            rs.start()
    finally:
        f.close()


# -- bit-identity matrix -------------------------------------------------------
def _drain(path, nbytes, opts):
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, opts)
    sess = ck.start_read_session_sync(fh, nbytes, 0)
    assert sess.readers.join(180)
    out = bytes(ck.read_view_sync(sess, nbytes, 0))
    m = sess.metrics
    stats = dict(copied=m.bytes_copied, backend=m.submit_backend,
                 direct=m.direct_io, hwm=m.inflight_hwm,
                 retries=m.recovery.io_retries
                 + m.recovery.worker_io_retries)
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)
    return out, stats


@pytest.mark.parametrize("name,opts", [
    ("blocking", dict()),
    ("async_threads", dict(queue_depth=4, submit_mode="threads",
                           readahead_bytes=1 << 20)),
    ("async_auto", dict(queue_depth=4)),
    ("direct_async", dict(queue_depth=4, direct_io=True)),
    ("direct_blocking", dict(direct_io=True)),
])
def test_bit_identity_thread_backend(blob, name, opts):
    path, data = blob
    sha = hashlib.sha256(data).hexdigest()
    out, stats = _drain(path, len(data), FileOptions(
        num_readers=2, splinter_bytes=256 * 1024, **opts))
    assert hashlib.sha256(out).hexdigest() == sha, name
    assert stats["copied"] == 0
    if opts.get("queue_depth", 0) >= 2:
        assert stats["backend"] in ("io_uring", "threads")
        assert 1 <= stats["hwm"] <= 4
    if opts.get("direct_io"):
        assert stats["direct"]


@pytest.mark.parametrize("name,opts", [
    ("async", dict(queue_depth=4)),
    ("direct_async", dict(queue_depth=4, direct_io=True)),
])
def test_bit_identity_process_backend(blob, name, opts):
    path, data = blob
    sha = hashlib.sha256(data).hexdigest()
    out, stats = _drain(path, len(data), FileOptions(
        num_readers=2, splinter_bytes=256 * 1024, backend="process",
        max_workers=2, **opts))
    assert hashlib.sha256(out).hexdigest() == sha, name
    assert stats["copied"] == 0


# -- faults under async submission ---------------------------------------------
def test_flaky_eio_retried_under_async(blob):
    path, data = blob
    sha = hashlib.sha256(data).hexdigest()
    out, stats = _drain(path, len(data), FileOptions(
        num_readers=2, splinter_bytes=128 * 1024, queue_depth=4,
        io_fault=FlakyEIO(every=5)))
    assert hashlib.sha256(out).hexdigest() == sha
    assert stats["copied"] == 0
    assert stats["retries"] > 0             # absorbed, counted, survived


def test_short_reads_resumed_under_async(blob):
    path, data = blob
    sha = hashlib.sha256(data).hexdigest()
    out, stats = _drain(path, len(data), FileOptions(
        num_readers=2, splinter_bytes=128 * 1024, queue_depth=4,
        submit_mode="threads",
        io_fault=ComposedIOFault((ShortRead(every=2, max_bytes=16 * 1024),
                                  FlakyEIO(every=9)))))
    assert hashlib.sha256(out).hexdigest() == sha
    assert stats["copied"] == 0
    assert stats["retries"] > 0


def test_options_validation():
    with pytest.raises(ValueError, match="submit mode"):
        FileOptions(submit_mode="sidecar").reader_options()
    with pytest.raises(ValueError, match="queue_depth"):
        FileOptions(queue_depth=-1).reader_options()
    with pytest.raises(ValueError, match="readahead"):
        FileOptions(readahead_bytes=-4096).reader_options()
