import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
# NB: no XLA_FLAGS here — tests must see 1 device; only the dry-run forces 512.

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
