"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.reassemble import reassemble_pallas
from repro.kernels.rglru_scan import rglru_scan_pallas

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,K,Sq,Sk,hd,causal,window,bq,bk",
    [
        (1, 2, 2, 64, 64, 32, True, 0, 16, 16),     # MHA causal
        (2, 4, 2, 128, 128, 64, True, 0, 32, 64),   # GQA, uneven blocks
        (1, 4, 1, 64, 64, 32, True, 0, 64, 16),     # MQA
        (1, 2, 2, 64, 64, 32, True, 16, 16, 16),    # sliding window
        (1, 2, 2, 96, 96, 16, True, 24, 32, 32),    # window > block
        (2, 2, 2, 64, 64, 32, False, 0, 32, 32),    # bidirectional
    ],
)
def test_flash_attention_sweep(B, H, K, Sq, Sk, hd, causal, window, bq, bk,
                               dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), dtype)
    k = jax.random.normal(ks[1], (B, K, Sk, hd), dtype)
    v = jax.random.normal(ks[2], (B, K, Sk, hd), dtype)
    out = flash_attention_bhsd(q, k, v, causal=causal, window=window,
                               block_q=bq, block_k=bk, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 5e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize(
    "B,S,D,N,chunk,block_d",
    [
        (1, 32, 16, 4, 8, 8),
        (2, 64, 32, 8, 16, 16),
        (1, 128, 64, 16, 128, 32),    # single chunk
        (2, 96, 16, 4, 32, 16),       # S % chunk == 0 multi-chunk
    ],
)
def test_mamba_scan_sweep(B, S, D, N, chunk, block_d):
    ks = jax.random.split(KEY, 3)
    A = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, D, N)))
    Bx = jax.random.normal(ks[1], (B, S, D, N)) * 0.1
    C = jax.random.normal(ks[2], (B, S, N))
    out = mamba_scan_pallas(A, Bx, C, chunk=chunk, block_d=block_d,
                            interpret=True)
    expect = ref.ssm_scan_ref(A, Bx, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize(
    "B,S,W,chunk,block_w",
    [(1, 32, 16, 8, 8), (2, 64, 64, 16, 32), (1, 256, 32, 64, 32)],
)
def test_rglru_scan_sweep(B, S, W, chunk, block_w):
    ks = jax.random.split(KEY, 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W)))
    b = jax.random.normal(ks[1], (B, S, W)) * 0.1
    out = rglru_scan_pallas(a, b, chunk=chunk, block_w=block_w, interpret=True)
    expect = ref.lru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("NB,rows,d", [(8, 4, 16), (32, 8, 64), (5, 2, 8)])
def test_reassemble_sweep(NB, rows, d, dtype):
    if dtype == jnp.int32:
        src = jax.random.randint(KEY, (NB, rows, d), 0, 1000, dtype)
    else:
        src = jax.random.normal(KEY, (NB, rows, d), dtype)
    idx = jax.random.permutation(jax.random.PRNGKey(1),
                                 jnp.arange(NB, dtype=jnp.int32))
    out = reassemble_pallas(src, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.reassemble_ref(src, idx)))
    # gather with repeats (one splinter feeding two consumers)
    idx2 = jnp.zeros((NB,), jnp.int32)
    out2 = reassemble_pallas(src, idx2, interpret=True)
    np.testing.assert_array_equal(np.asarray(out2),
                                  np.asarray(ref.reassemble_ref(src, idx2)))


def test_ops_wrappers_dispatch_reference_on_cpu():
    q = jax.random.normal(KEY, (1, 32, 2, 16))
    k = jax.random.normal(KEY, (1, 32, 2, 16))
    v = jax.random.normal(KEY, (1, 32, 2, 16))
    out = ops.flash_attention(q, k, v)          # default: ref path on CPU
    assert out.shape == q.shape
    out2 = ops.flash_attention(q, k, v, use_pallas=True, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               atol=1e-5, rtol=1e-5)
