"""§Perf levers must be exact math-preserving rewrites."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import softmax_xent
from repro.models.ssm import mamba_apply, mamba_init
from repro.models.attention import attention_train, attn_init
from repro.models.layers import rope_angles

KEY = jax.random.PRNGKey(0)


def test_fused_ssm_matches_materialized():
    p = mamba_init(KEY, 32, 64, 8, 8, 4, jnp.float32)
    x = jax.random.normal(KEY, (2, 50, 32)) * 0.1
    y1 = mamba_apply(p, x, dtype=jnp.float32, chunk=16, impl="materialized")
    y2 = mamba_apply(p, x, dtype=jnp.float32, chunk=16, impl="fused")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-5, rtol=1e-5)
    g1 = jax.grad(lambda q: mamba_apply(q, x, dtype=jnp.float32, chunk=16,
                                        impl="materialized").sum())(p)
    g2 = jax.grad(lambda q: mamba_apply(q, x, dtype=jnp.float32, chunk=16,
                                        impl="fused").sum())(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)


def test_onehot_xent_matches_gather():
    logits = jax.random.normal(KEY, (2, 8, 32))
    labels = jax.random.randint(KEY, (2, 8), 0, 32)
    a = softmax_xent(logits, labels, mode="gather")
    b = softmax_xent(logits, labels, mode="onehot")
    assert abs(float(a) - float(b)) < 1e-6
    ga = jax.grad(lambda l: softmax_xent(l, labels, mode="gather"))(logits)
    gb = jax.grad(lambda l: softmax_xent(l, labels, mode="onehot"))(logits)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb), atol=1e-6)


def test_q_chunked_attention_matches_dense():
    d, H, hd, S = 16, 2, 8, 64
    params = attn_init(KEY, d, H, H, hd, jnp.float32)
    x = jax.random.normal(KEY, (1, S, d))
    cos, sin = rope_angles(jnp.arange(S)[None], hd, 1e4)
    for window in (0, 12):
        dense = attention_train(params, x, cos, sin, dtype=jnp.float32,
                                eps=1e-6, window=window, q_chunk=0)
        chunked = attention_train(params, x, cos, sin, dtype=jnp.float32,
                                  eps=1e-6, window=window, q_chunk=16)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                                   atol=1e-5, rtol=1e-5)
        gd = jax.grad(lambda q: attention_train(
            q, x, cos, sin, dtype=jnp.float32, eps=1e-6, window=window,
            q_chunk=0).sum())(params)
        gc = jax.grad(lambda q: attention_train(
            q, x, cos, sin, dtype=jnp.float32, eps=1e-6, window=window,
            q_chunk=16).sum())(params)
        for a, b in zip(jax.tree.leaves(gd), jax.tree.leaves(gc)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4, rtol=1e-4)
