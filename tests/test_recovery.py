"""Fault-tolerant reader runtime: respawn, re-issue, retry, fault harness.

Covers the recovery layer added across ``io/posix.py`` (transient-I/O retry
with deadline-capped backoff, narrowed advisory-error suppression),
``ipc/ring.py`` (torn-slot CRC retry, worker I/O counter words),
``core/faults.py`` (the seeded deterministic fault-injection harness),
``core/buffers.py`` (worker respawn / splinter re-issue / no-progress
watchdog) and ``core/director.py`` (graceful thread-backend degradation):

* retry policy edges: a transient EIO is absorbed and counted, exhaustion
  surfaces the real errno, short reads loop to completion, a zero deadline
  fails fast;
* advisory narrowing: only the expected-errno class is suppressed (and
  counted); ``EBADF`` propagates;
* ``FaultPlan``: same seed -> identical plan and identical recovery
  counters (the CKIO_FAULT_SEED matrix leg in scripts/ci.sh sweeps this);
* respawn: a crashed worker's replacement attaches to the SAME arena and
  the session completes bit-identically with ``bytes_copied == 0`` and
  every splinter streamed exactly once; budget exhaustion is terminal
  with a descriptive ``WorkerCrashed``;
* re-issue: the supervisor re-reads the dead worker's unfinished tail;
* watchdog: a stalled (not dead) worker is killed and recovered from;
* degraded mode: ``fallback_backend="thread"`` rebuilds a failed process
  session on the thread backend, warning once per FileOptions;
* the ``train/fault.py`` StepSupervisor counts ``WorkerCrashed`` from the
  batch path as a reader failure and replays the step.
"""
from __future__ import annotations

import errno
import os
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import CkIO, FaultPlan, FileOptions, WorkerCrashed
from repro.core.faults import (
    ComposedIOFault,
    CrashReader,
    CrashSplinter,
    DelayEach,
    FlakyEIO,
    ShortRead,
    TornSlot,
)
from repro.core.metrics import RecoveryMetrics
from repro.io.posix import IOEventCounts, PosixFile, RetryPolicy, write_file
from repro.ipc.ring import EventRing, RingEvent, ring_bytes
from repro.ipc.worker import StallReader

SEED = int(os.environ.get("CKIO_FAULT_SEED", "20260809"))


def _shm_leftovers():
    d = "/dev/shm"
    if not os.path.isdir(d):
        return []
    return [n for n in os.listdir(d) if n.startswith("ckio-")]


@pytest.fixture
def data_file(tmp_path):
    rng = np.random.default_rng(SEED)
    data = rng.integers(0, 256, 1 << 20, dtype=np.uint8).tobytes()
    path = str(tmp_path / "recovery_blob.bin")
    write_file(path, data)
    return path, data


def _proc_opts(**kw):
    base = dict(num_readers=2, splinter_bytes=128 * 1024,
                backend="process", max_workers=2)
    base.update(kw)
    return FileOptions(**base)


# -- io/posix.py retry policy -------------------------------------------------
def test_retry_absorbs_transient_eio(data_file):
    path, data = data_file
    f = PosixFile.open(path)
    try:
        # ShortRead forces many syscalls (a full-range preadv would finish
        # in one), so the every-3rd EIO actually fires mid-read.
        f.fault = ComposedIOFault((ShortRead(every=1, max_bytes=128 * 1024),
                                   FlakyEIO(every=3)))
        stats = RecoveryMetrics()
        out = np.empty(len(data), dtype=np.uint8)
        n = f.pread_into(0, memoryview(out), stats=stats, fault=f.fault)
        assert n == len(data)
        assert out.tobytes() == data
        assert stats.io_retries > 0
        assert stats.retried_errnos.get(errno.EIO) == stats.io_retries
    finally:
        f.close()


def test_retry_exhaustion_surfaces_errno(data_file):
    path, _ = data_file
    f = PosixFile.open(path)
    try:
        out = np.empty(4096, dtype=np.uint8)
        with pytest.raises(OSError) as ei:
            f.pread_into(0, memoryview(out), fault=FlakyEIO(every=1))
        assert ei.value.errno == errno.EIO
    finally:
        f.close()


def test_retry_zero_deadline_fails_fast(data_file):
    path, _ = data_file
    f = PosixFile.open(path)
    try:
        f.retry = RetryPolicy(deadline_s=0.0)
        out = np.empty(4096, dtype=np.uint8)
        t0 = time.monotonic()
        with pytest.raises(OSError):
            f.pread_into(0, memoryview(out), fault=FlakyEIO(every=1))
        assert time.monotonic() - t0 < 1.0
    finally:
        f.close()


def test_short_reads_loop_to_completion(data_file):
    path, data = data_file
    f = PosixFile.open(path)
    try:
        stats = RecoveryMetrics()
        out = np.empty(len(data), dtype=np.uint8)
        n = f.pread_into(0, memoryview(out), stats=stats,
                         fault=ShortRead(every=1, max_bytes=64 * 1024))
        assert n == len(data)
        assert out.tobytes() == data
        # short reads are normal POSIX behavior, not retries
        assert stats.io_retries == 0
    finally:
        f.close()


def test_composed_fault_short_plus_flaky(data_file):
    path, data = data_file
    f = PosixFile.open(path)
    try:
        stats = RecoveryMetrics()
        hook = ComposedIOFault((ShortRead(every=1, max_bytes=32 * 1024),
                                FlakyEIO(every=7)))
        out = np.empty(len(data), dtype=np.uint8)
        n = f.pread_into(0, memoryview(out), stats=stats, fault=hook)
        assert n == len(data)
        assert out.tobytes() == data
        assert stats.io_retries > 0
    finally:
        f.close()


# -- io/posix.py narrowed advisory suppression --------------------------------
def test_fadvise_expected_errno_suppressed_and_counted(data_file,
                                                       monkeypatch):
    path, _ = data_file
    f = PosixFile.open(path)
    try:
        def raise_einval(*a, **kw):
            raise OSError(errno.EINVAL, "Invalid argument")

        monkeypatch.setattr(os, "posix_fadvise", raise_einval)
        stats = RecoveryMetrics()
        assert f.advise_sequential(0, 4096, stats=stats) is False
        assert stats.suppressed_errors == 1
    finally:
        f.close()


def test_fadvise_unexpected_errno_propagates(data_file, monkeypatch):
    path, _ = data_file
    f = PosixFile.open(path)
    try:
        def raise_ebadf(*a, **kw):
            raise OSError(errno.EBADF, "Bad file descriptor")

        monkeypatch.setattr(os, "posix_fadvise", raise_ebadf)
        with pytest.raises(OSError) as ei:
            f.advise_sequential(0, 4096)
        assert ei.value.errno == errno.EBADF
    finally:
        f.close()


def test_drop_page_cache_missing_path_counted(tmp_path):
    from repro.io.posix import drop_page_cache

    stats = RecoveryMetrics()
    assert drop_page_cache(str(tmp_path / "nope.bin"), stats=stats) is False
    assert stats.suppressed_errors == 1


def test_io_event_counts_module_fallback(data_file, monkeypatch):
    """Without an explicit stats sink, suppressions land in IO_EVENTS."""
    from repro.io import posix as px

    path, _ = data_file
    f = PosixFile.open(path)
    try:
        fresh = IOEventCounts()
        monkeypatch.setattr(px, "IO_EVENTS", fresh)

        def raise_einval(*a, **kw):
            raise OSError(errno.EINVAL, "Invalid argument")

        monkeypatch.setattr(os, "posix_fadvise", raise_einval)
        assert f.advise_sequential(0, 4096) is False
        assert fresh.suppressed == 1
    finally:
        f.close()


# -- core/faults.py: deterministic plan ---------------------------------------
def test_fault_plan_deterministic():
    a = FaultPlan(seed=SEED, crash=True, short_reads=True, flaky_io=True,
                  torn_slots=True, num_readers=2, num_splinters=16)
    b = FaultPlan(seed=SEED, crash=True, short_reads=True, flaky_io=True,
                  torn_slots=True, num_readers=2, num_splinters=16)
    assert a.describe() == b.describe()
    c = FaultPlan(seed=SEED + 1, crash=True, short_reads=True,
                  flaky_io=True, torn_slots=True, num_readers=2,
                  num_splinters=16)
    assert a.describe() != c.describe()


# -- thread backend: retry counters through a session -------------------------
def test_thread_backend_session_counts_retries(data_file):
    path, data = data_file
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(num_readers=2,
                                        splinter_bytes=128 * 1024,
                                        io_fault=FlakyEIO(every=3)))
    sess = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
    out = ck.read_sync(sess, len(data), 0, timeout=120)
    assert bytes(out) == data
    assert sess.metrics.recovery.io_retries > 0
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


# -- process backend: respawn -------------------------------------------------
def test_respawn_completes_bit_identical(data_file):
    path, data = data_file
    ck = CkIO(num_pes=4)
    fh = ck.open_sync(path, _proc_opts(
        recovery="respawn", max_respawns=2,
        worker_fault=CrashReader(reader=0, after=2, code=67)))
    sess = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
    seen, lock = [], threading.Lock()
    sess.subscribe_splinters(
        lambda ev: (lock.acquire(), seen.append(ev.index), lock.release()),
        replay=True)
    view = ck.read_view_sync(sess, len(data), 0, timeout=120)
    assert bytes(view) == data
    m = sess.metrics.recovery
    assert m.respawns == 1
    assert m.reissued_splinters == 2          # the dead worker's tail
    assert m.reissued_bytes == 2 * 128 * 1024
    assert m.recovery_latency_s > 0
    assert sess.metrics.bytes_copied == 0     # still zero-copy
    with lock:
        assert sorted(seen) == list(range(8))  # exactly once each
    assert sorted(sess.arrival_order) == list(range(8))
    ck.close_read_session_sync(sess)
    # recovery counters feed the Director-lifetime aggregate on close
    assert ck.director.recovery.respawns >= 1
    ck.close_sync(fh)
    assert _shm_leftovers() == []


def test_respawn_budget_exhaustion_is_terminal(data_file):
    path, data = data_file
    ck = CkIO(num_pes=4)
    # splinter 0 is poisoned for every generation: each replacement dies
    # on it too, so a budget of 1 must exhaust.
    fh = ck.open_sync(path, _proc_opts(
        recovery="respawn", max_respawns=1,
        worker_fault=CrashSplinter(index=0, code=71)))
    sess = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
    with pytest.raises(WorkerCrashed, match="respawn budget exhausted"):
        ck.read_sync(sess, len(data), 0, timeout=120)
    ck.close_sync(fh)


def test_cascading_respawns_within_budget(data_file):
    """after=1 kills every generation until the tail fits: 3 respawns."""
    path, data = data_file
    ck = CkIO(num_pes=4)
    fh = ck.open_sync(path, _proc_opts(
        recovery="respawn", max_respawns=3,
        worker_fault=CrashReader(reader=0, after=1, code=69)))
    sess = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
    view = ck.read_view_sync(sess, len(data), 0, timeout=120)
    assert bytes(view) == data
    assert sess.metrics.recovery.respawns == 3
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)
    assert _shm_leftovers() == []


# -- process backend: re-issue ------------------------------------------------
def test_reissue_completes_bit_identical(data_file):
    path, data = data_file
    ck = CkIO(num_pes=4)
    fh = ck.open_sync(path, _proc_opts(
        recovery="reissue",
        worker_fault=CrashReader(reader=1, after=1, code=68)))
    sess = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
    seen, lock = [], threading.Lock()
    sess.subscribe_splinters(
        lambda ev: (lock.acquire(), seen.append(ev.index), lock.release()),
        replay=True)
    view = ck.read_view_sync(sess, len(data), 0, timeout=120)
    assert bytes(view) == data
    m = sess.metrics.recovery
    assert m.reissues == 1
    assert m.reissued_splinters == 3
    assert m.respawns == 0
    assert sess.metrics.bytes_copied == 0
    with lock:
        assert sorted(seen) == list(range(8))
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)
    assert _shm_leftovers() == []


# -- process backend: watchdog ------------------------------------------------
def test_watchdog_recovers_stalled_worker(data_file):
    path, data = data_file
    ck = CkIO(num_pes=4)
    fh = ck.open_sync(path, _proc_opts(
        recovery="reissue", worker_watchdog_s=1.0,
        delay_model=StallReader(0, 30.0)))   # would stall 30s unkilled
    sess = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
    t0 = time.monotonic()
    view = ck.read_view_sync(sess, len(data), 0, timeout=120)
    assert time.monotonic() - t0 < 20.0       # did NOT wait out the stall
    assert bytes(view) == data
    m = sess.metrics.recovery
    assert m.watchdog_kills >= 1
    assert m.reissues >= 1
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


# -- degraded mode: thread-backend fallback -----------------------------------
def test_fallback_to_thread_backend_warns_once(data_file):
    path, data = data_file
    ck = CkIO(num_pes=2)
    # a lambda delay_model is unpicklable -> spawn fails at session start
    fh = ck.open_sync(path, FileOptions(
        num_readers=2, splinter_bytes=256 * 1024, backend="process",
        fallback_backend="thread", delay_model=lambda r, sp: 0.0))
    with warnings.catch_warnings(record=True) as wlog:
        warnings.simplefilter("always")
        sess = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
        out = ck.read_sync(sess, len(data), 0, timeout=120)
        assert bytes(out) == data
        assert sess.metrics.recovery.degraded_mode
        ck.close_read_session_sync(sess)
        sess2 = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
        out2 = ck.read_sync(sess2, len(data), 0, timeout=120)
        assert bytes(out2) == data
        assert sess2.metrics.recovery.degraded_mode
        ck.close_read_session_sync(sess2)
    fb = [w for w in wlog if "falling back" in str(w.message)]
    assert len(fb) == 1                       # sticky: warned once, not per
    assert issubclass(fb[0].category, RuntimeWarning)   # session
    assert ck.director.recovery.degraded_mode
    ck.close_sync(fh)


def test_no_fallback_without_opt_in(data_file):
    path, data = data_file
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(path, FileOptions(
        num_readers=2, backend="process",
        delay_model=lambda r, sp: 0.0))       # unpicklable, no fallback
    with pytest.raises(Exception):
        ck.start_read_session_sync(fh, len(data), 0, timeout=120)
    ck.close_sync(fh)
    assert _shm_leftovers() == []


def test_option_validation():
    with pytest.raises(ValueError, match="recovery"):
        FileOptions(recovery="retry").reader_options()
    with pytest.raises(ValueError, match="fallback"):
        FileOptions(fallback_backend="process").reader_options()


# -- deterministic replay from a seed -----------------------------------------
def test_deterministic_fault_replay(data_file):
    path, data = data_file

    def run_once():
        plan = FaultPlan(seed=SEED, crash=True, num_readers=2,
                         num_splinters=8)
        ck = CkIO(num_pes=4)
        fh = ck.open_sync(path, _proc_opts(
            recovery="reissue", fault_plan=plan))
        sess = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
        view = ck.read_view_sync(sess, len(data), 0, timeout=120)
        ok = bytes(view) == data
        m = sess.metrics.recovery
        counters = (m.reissues, m.reissued_splinters, m.reissued_bytes,
                    m.respawns)
        ck.close_read_session_sync(sess)
        ck.close_sync(fh)
        return plan.describe(), counters, ok

    d1, c1, ok1 = run_once()
    d2, c2, ok2 = run_once()
    assert ok1 and ok2
    assert d1 == d2
    assert c1 == c2
    assert c1[1] > 0                          # the seeded crash really fired


# -- ring CRC-retry path (torn/stale slot stamps) -----------------------------
def test_ring_torn_slot_injection_retried_never_delivered():
    """A stamped-before-payload slot must be re-read, delivered exactly
    once with the CORRECT payload, and never deadlock the consumer."""
    slots = 4
    buf = memoryview(bytearray(ring_bytes(slots)))
    prod = EventRing(buf, slots, create=True)
    prod.fault = TornSlot(every=3, delay_s=0.005)
    cons = EventRing(buf, slots)
    n = 64
    got, errs = [], []

    def producer():
        try:
            for i in range(n):
                ok = prod.publish(RingEvent(
                    index=i, reader=i % 2, offset=i * 100, nbytes=100,
                    arena_off=i * 100, t_arrival=0.0, read_dt=0.0),
                    timeout=30.0)
                assert ok
        except BaseException as e:            # pragma: no cover
            errs.append(e)

    th = threading.Thread(target=producer, daemon=True)
    th.start()
    deadline = time.monotonic() + 30.0
    while len(got) < n:
        assert time.monotonic() < deadline, "consumer deadlocked"
        got.extend(cons.consume())
    th.join(10.0)
    assert not errs
    assert [ev.index for ev in got] == list(range(n))       # in order, once
    assert all(ev.offset == ev.index * 100 for ev in got)   # never torn


def test_process_session_with_torn_ring_slots(data_file):
    path, data = data_file
    ck = CkIO(num_pes=4)
    fh = ck.open_sync(path, _proc_opts(
        ring_fault=TornSlot(every=2, delay_s=0.002)))
    sess = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
    view = ck.read_view_sync(sess, len(data), 0, timeout=120)
    assert bytes(view) == data
    assert sorted(sess.arrival_order) == list(range(8))
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)
    assert _shm_leftovers() == []


# -- worker-side I/O counters cross the ring header ---------------------------
def test_worker_io_retries_folded_into_session(data_file):
    path, data = data_file
    ck = CkIO(num_pes=4)
    fh = ck.open_sync(path, _proc_opts(io_fault=FlakyEIO(every=2)))
    sess = ck.start_read_session_sync(fh, len(data), 0, timeout=120)
    out = ck.read_sync(sess, len(data), 0, timeout=120)
    assert bytes(out) == data
    ck.close_read_session_sync(sess)
    assert sess.metrics.recovery.worker_io_retries > 0
    assert ck.director.recovery.worker_io_retries > 0
    ck.close_sync(fh)


# -- metrics plumbing ---------------------------------------------------------
def test_recovery_metrics_merge_and_summary():
    a = RecoveryMetrics()
    a.record_respawn(2, 1024)
    a.record_io_retry(errno.EIO)
    a.record_watchdog_kill()
    a.record_recovery_latency(0.25)
    b = RecoveryMetrics()
    b.record_reissue(3, 2048)
    b.record_suppressed(errno.EINVAL)
    b.mark_degraded()
    b.merge(a)
    assert b.respawns == 1 and b.reissues == 1
    assert b.reissued_splinters == 5
    assert b.reissued_bytes == 3072
    assert b.io_retries == 1 and b.retried_errnos == {errno.EIO: 1}
    assert b.suppressed_errors == 1
    assert b.watchdog_kills == 1
    assert b.recovery_latency_s == pytest.approx(0.25)
    assert b.degraded_mode
    assert b.recoveries() == 2
    s = b.summary()
    assert s["respawns"] == 1.0 and s["reissues"] == 1.0


# -- train/fault.py: WorkerCrashed is a step failure --------------------------
def test_step_supervisor_recovers_reader_crash(tmp_path):
    import jax.numpy as jnp

    from repro.train.checkpoint import AsyncCheckpointer
    from repro.train.fault import StepSupervisor

    ck = AsyncCheckpointer(str(tmp_path / "ckpts"), keep=2)
    crash = {"left": 1}
    recovered = []

    def batches(step):
        if step == 2 and crash["left"] > 0:
            crash["left"] -= 1
            raise WorkerCrashed("reader worker 0 (pid 1) exited")
        return jnp.asarray(float(step))

    def step_fn(state, batch):
        return {"x": state["x"] + batch}, {}

    sup = StepSupervisor(step_fn, ck, ckpt_every=1, max_retries=3,
                         input_recover=recovered.append)
    state = sup.run({"x": jnp.zeros(())}, batches, 4)
    assert sup.stats.reader_failures == 1
    assert sup.stats.failures == 1
    assert sup.stats.restores == 1
    assert recovered == [2]                   # hook saw the failing step
    assert float(state["x"]) == 0.0 + 1.0 + 2.0 + 3.0
    ck.shutdown()


def test_step_supervisor_terminal_reader_crash(tmp_path):
    import jax.numpy as jnp

    from repro.train.checkpoint import AsyncCheckpointer
    from repro.train.fault import StepSupervisor

    ck = AsyncCheckpointer(str(tmp_path / "c2"), keep=1)

    def batches(step):
        raise WorkerCrashed("respawn budget exhausted")

    sup = StepSupervisor(lambda s, b: (s, {}), ck, ckpt_every=1,
                         max_retries=2)
    with pytest.raises(RuntimeError, match="retries exhausted"):
        sup.run({"x": jnp.zeros(())}, batches, 3)
    assert sup.stats.reader_failures == sup.stats.failures == 3
    ck.shutdown()
