"""Multi-file sharded sessions: FileSet addressing, shard-aware planning,
sharded streaming, and the cross-backend bit-identity matrix.

Covers the FileSet layer end to end:

* ``read_meta`` torn-header regressions: truncated header, garbage JSON,
  wrong magic, bad dtype/shape fields — each a descriptive ``ValueError``
  naming the path;
* ``FileSet.build`` validation: dtype / inner-shape mismatch across shards,
  truncated shard body;
* global row addressing vs a NumPy concat oracle (seeded sweeps +
  hypothesis when installed): arbitrary shard sizes including empty and
  remainder shards, windows straddling shard boundaries;
* ``ShardedFile``: global-space preads across boundaries, ``bounds_in``,
  ``shard_of``, refcounted close;
* ``plan_session(hard_bounds=...)``: no stripe/splinter spans a shard
  start, >= one reader per hard segment, too-few-readers raises;
* ``device_token_spans``: the pure chunk->device placement function, unit
  tested with fake multi-device index maps (including a non-addressable
  remote span — no jax devices needed);
* the cross-backend bit-identity matrix {thread, process} x {whole-window,
  streaming} x {single-file, FileSet}: identical batches with consumer
  ``bytes_copied == 0``;
* sharded streaming (constructor ``sharding=``): per-chunk staging with NO
  whole-window-fallback ``RuntimeWarning``, ``host_permute_bytes == 0``,
  bit-identical to the unsharded path, ``ShardMetrics`` staged-bytes
  ledger balanced; per-call-sharding mismatch raises;
* recovery interop: ``recovery="respawn"`` on a FileSet session — the
  worker owning one shard dies mid-drain, completion is bit-identical and
  ``RecoveryMetrics.reissued_bytes_by_shard`` attributes the re-read to
  exactly that shard;
* ``drop_remainder`` both ways over a FileSet (the remainder window's
  padding path).
"""
from __future__ import annotations

import os
import threading
import warnings

import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core import CkIO, FileOptions
from repro.core.faults import CrashReader
from repro.data import CkIOPipeline, FileSet, make_token_file, write_token_shards
from repro.data.fileset import ShardInfo
from repro.data.pipeline import device_token_spans
from repro.data.tokenfile import HEADER_BYTES, MAGIC, read_meta, write_token_file
from repro.io.layout import plan_session
from repro.io.posix import ShardedFile

SEED = 20260809


def _shm_leftovers():
    d = "/dev/shm"
    if not os.path.isdir(d):
        return []
    return [n for n in os.listdir(d) if n.startswith("ckio-")]


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    """One flat token file + its token array (the oracle)."""
    d = tmp_path_factory.mktemp("fileset_corpus")
    path = str(d / "tokens.bin")
    make_token_file(path, 16 * 128 * 4 + 64, vocab_size=32000, seed=SEED)
    meta = read_meta(path)
    arr = np.fromfile(path, dtype=meta.dtype, offset=HEADER_BYTES)
    return path, arr


@pytest.fixture(scope="module")
def sharded(corpus, tmp_path_factory):
    """The same corpus split into 4 shards: remainder sizes, one empty."""
    _, arr = corpus
    d = tmp_path_factory.mktemp("fileset_shards")
    counts = [3000, 0, 4096, len(arr) - 7096]
    paths = write_token_shards(str(d), arr, counts)
    return FileSet.build(paths), paths


# -- read_meta torn/corrupt header regressions --------------------------------
def test_read_meta_truncated_header(tmp_path):
    p = str(tmp_path / "torn.bin")
    with open(p, "wb") as f:
        f.write(b"x" * 100)
    with pytest.raises(ValueError, match="truncated token-file header"):
        read_meta(p)
    with pytest.raises(ValueError, match="torn.bin"):
        read_meta(p)


def test_read_meta_garbage_header(tmp_path):
    p = str(tmp_path / "garbage.bin")
    with open(p, "wb") as f:
        f.write(b"\xff" * HEADER_BYTES)
    with pytest.raises(ValueError, match="garbage.bin.*corrupt token-file"):
        read_meta(p)


def test_read_meta_wrong_magic(tmp_path):
    p = str(tmp_path / "notmine.bin")
    with open(p, "wb") as f:
        f.write(b'{"magic": "SOMETHING-ELSE"}'.ljust(HEADER_BYTES))
    with pytest.raises(ValueError, match=f"notmine.bin: not a {MAGIC} file"):
        read_meta(p)


def test_read_meta_bad_fields(tmp_path):
    bad_dtype = str(tmp_path / "bad_dtype.bin")
    with open(bad_dtype, "wb") as f:
        f.write((f'{{"magic": "{MAGIC}", "dtype": "notadtype", '
                 f'"shape": [4]}}').encode().ljust(HEADER_BYTES))
    with pytest.raises(ValueError, match="bad_dtype.bin.*bad dtype/shape"):
        read_meta(bad_dtype)
    bad_shape = str(tmp_path / "bad_shape.bin")
    with open(bad_shape, "wb") as f:
        f.write((f'{{"magic": "{MAGIC}", "dtype": "uint32", '
                 f'"shape": [-4]}}').encode().ljust(HEADER_BYTES))
    with pytest.raises(ValueError, match="bad_shape.bin.*shape"):
        read_meta(bad_shape)


# -- FileSet.build validation --------------------------------------------------
def test_build_rejects_dtype_mismatch(tmp_path):
    a = str(tmp_path / "a.bin")
    b = str(tmp_path / "b.bin")
    write_token_file(a, np.arange(10, dtype=np.uint32))
    write_token_file(b, np.arange(10, dtype=np.uint16))
    with pytest.raises(ValueError, match=r"b\.bin: shard dtype"):
        FileSet.build([a, b])


def test_build_rejects_inner_shape_mismatch(tmp_path):
    a = str(tmp_path / "a.bin")
    b = str(tmp_path / "b.bin")
    write_token_file(a, np.zeros((10, 3), dtype=np.uint32))
    write_token_file(b, np.zeros((10, 4), dtype=np.uint32))
    with pytest.raises(ValueError, match=r"b\.bin: shard inner shape"):
        FileSet.build([a, b])


def test_build_rejects_truncated_body(tmp_path):
    a = str(tmp_path / "a.bin")
    write_token_file(a, np.arange(1000, dtype=np.uint32))
    with open(a, "r+b") as f:
        f.truncate(HEADER_BYTES + 100)
    with pytest.raises(ValueError, match=r"a\.bin: truncated shard body"):
        FileSet.build([a])


def test_build_empty_list_rejected():
    with pytest.raises(ValueError, match="empty path list"):
        FileSet.build([])


# -- global row addressing vs the NumPy concat oracle --------------------------
def _oracle_window(fs: FileSet, arr: np.ndarray, start: int, n: int) -> bytes:
    """Read rows [start, start+n) through shard_ranges_for_rows, straight
    from the shard files, and compare against the concat oracle."""
    got = bytearray()
    for shard_idx, file_off, nb in fs.shard_ranges_for_rows(start, n):
        with open(fs.shards[shard_idx].path, "rb") as f:
            f.seek(file_off)
            piece = f.read(nb)
        assert len(piece) == nb
        got += piece
    assert bytes(got) == arr[start: start + n].tobytes()
    return bytes(got)


def test_addressing_seeded_sweep(tmp_path):
    """Arbitrary shard splits (empty + remainder shards) x random windows,
    every window checked against the concat oracle."""
    rng = np.random.default_rng(SEED)
    arr = rng.integers(0, 2**31, size=5000, dtype=np.uint32)
    for case in range(6):
        nshards = int(rng.integers(1, 7))
        cuts = np.sort(rng.integers(0, len(arr) + 1, size=nshards - 1))
        counts = np.diff(np.concatenate([[0], cuts, [len(arr)]]))
        d = str(tmp_path / f"sweep{case}")
        fs = FileSet.build(write_token_shards(d, arr, counts.tolist()))
        assert fs.num_rows == len(arr)
        assert fs.data_bytes == arr.nbytes
        assert fs.data_offset == 0
        for _ in range(20):
            start = int(rng.integers(0, len(arr)))
            n = int(rng.integers(1, len(arr) - start + 1))
            off, nb = fs.byte_range_for_rows(start, n)
            assert (off, nb) == (start * 4, n * 4)
            _oracle_window(fs, arr, start, n)
        # shard_of_row agrees with searchsorted over the cut points
        for _ in range(50):
            row = int(rng.integers(0, len(arr)))
            i = fs.shard_of_row(row)
            s = fs.shards[i]
            assert s.row_start <= row < s.row_end
            assert fs.shard_of_byte(row * 4) == i


def test_addressing_bounds_checked(sharded):
    fs, _ = sharded
    with pytest.raises(ValueError, match="out of bounds"):
        fs.byte_range_for_rows(-1, 1)
    with pytest.raises(ValueError, match="out of bounds"):
        fs.byte_range_for_rows(0, fs.num_rows + 1)
    with pytest.raises(ValueError, match="out of bounds"):
        fs.shard_of_row(fs.num_rows)
    with pytest.raises(ValueError, match="out of bounds"):
        fs.shard_of_byte(fs.data_bytes)


@settings(max_examples=30, deadline=None)
@given(
    counts=st.lists(st.integers(min_value=0, max_value=40), min_size=1,
                    max_size=6),
    start_frac=st.floats(min_value=0.0, max_value=1.0),
    len_frac=st.floats(min_value=0.0, max_value=1.0),
)
def test_addressing_property(tmp_path_factory, counts, start_frac, len_frac):
    total = sum(counts)
    if total == 0:
        counts = counts + [3]
        total = 3
    rng = np.random.default_rng(SEED + total)
    arr = rng.integers(0, 2**31, size=total, dtype=np.uint32)
    d = tmp_path_factory.mktemp("prop")
    fs = FileSet.build(write_token_shards(str(d), arr, counts))
    start = min(int(start_frac * total), total - 1)
    n = max(1, min(int(len_frac * total), total - start))
    _oracle_window(fs, arr, start, n)
    # straddling resolution covers the window exactly once, in order
    ranges = fs.shard_ranges_for_rows(start, n)
    assert sum(nb for _, _, nb in ranges) == n * 4
    assert [i for i, _, _ in ranges] == sorted({i for i, _, _ in ranges})


# -- ShardedFile: the physical byte space --------------------------------------
def test_sharded_file_preads_across_boundaries(sharded, corpus):
    fs, _ = sharded
    _, arr = corpus
    raw = arr.tobytes()
    f = fs.sharded_file()
    try:
        assert f.size == len(raw)
        assert f.offset == 0
        # windows straddling both populated boundaries
        for off, n in [(0, 100), (12000 * 1 - 8, 64), (3000 * 4 - 4, 12),
                       (7096 * 4 - 100, 300), (len(raw) - 64, 64)]:
            assert f.pread(off, n) == raw[off: off + n]
            out = bytearray(n)
            assert f.pread_into(off, memoryview(out)) == n
            assert bytes(out) == raw[off: off + n]
        assert f.bounds_in(0, len(raw)) == [3000 * 4, 7096 * 4]
        assert f.shard_of(0) == 0
        assert f.shard_of(3000 * 4) == 2      # shard 1 is empty
        assert f.shard_of(len(raw) - 1) == 3
        f.advise_sequential(0, len(raw))
    finally:
        f.close()
    assert f.closed


def test_sharded_file_rejects_gaps():
    with pytest.raises(ValueError, match="gap"):
        ShardedFile.from_segments(
            [("/nonexistent-a", 0, HEADER_BYTES, 100, 0),
             ("/nonexistent-b", 150, HEADER_BYTES, 100, 1)])


# -- shard-aware planning ------------------------------------------------------
def test_plan_hard_bounds_never_spanned(sharded):
    fs, _ = sharded
    bounds = fs.shard_bounds_in(0, fs.data_bytes)
    assert bounds == [3000 * 4, 7096 * 4]
    plan = plan_session(0, fs.data_bytes, 4, splinter_bytes=8 * 1024,
                        hard_bounds=bounds)
    assert plan.hard_bounds == tuple(bounds)
    for b in bounds:
        for lo, hi in plan.stripe_bounds:
            assert not (lo < b < hi), f"stripe [{lo},{hi}) spans bound {b}"
        for sp in plan.splinters:
            assert not (sp.offset < b < sp.end), (
                f"splinter [{sp.offset},{sp.end}) spans bound {b}")
        # every segment got at least one reader: some stripe starts at b
        assert any(lo == b for lo, hi in plan.stripe_bounds if hi > lo)
    # full coverage, in order, no overlap
    pos = 0
    for sp in sorted(plan.splinters, key=lambda s: s.offset):
        assert sp.offset == pos
        pos += sp.nbytes
    assert pos == fs.data_bytes


def test_plan_too_few_readers_for_segments():
    with pytest.raises(ValueError, match="cannot honour"):
        plan_session(0, 4000, 2, splinter_bytes=1024,
                     hard_bounds=[1000, 2000, 3000])


def test_session_bumps_readers_to_cover_shards(sharded):
    """A FileSet session transparently raises num_readers to the hard
    segment count (the Director's pre-plan bump)."""
    fs, _ = sharded
    ck = CkIO(num_pes=4)
    fh = ck.open_fileset_sync(fs, FileOptions(num_readers=1,
                                              splinter_bytes=8 * 1024))
    sess = ck.start_read_session_sync(fh, fs.data_bytes, 0, timeout=120)
    assert sess.plan.num_readers >= 3          # 3 populated segments
    assert sess.plan.hard_bounds == (3000 * 4, 7096 * 4)
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)


# -- device_token_spans: pure placement function -------------------------------
def test_device_token_spans_fake_maps():
    W = 128
    # 4 fake devices, batch split 8 rows -> 2 rows each, full width
    fake = {f"dev{i}": (slice(2 * i, 2 * i + 2), slice(None)) for i in range(4)}
    spans = device_token_spans(fake, 8, W)
    assert spans == {f"dev{i}": (2 * i * W, (2 * i + 2) * W) for i in range(4)}
    # spans tile the window exactly
    ordered = sorted(spans.values())
    assert ordered[0][0] == 0 and ordered[-1][1] == 8 * W
    for (a0, a1), (b0, b1) in zip(ordered, ordered[1:]):
        assert a1 == b0
    # replicated devices (same block on two devices) both get the span
    rep = {"d0": (slice(0, 8), slice(None)), "d1": (slice(0, 8), slice(None))}
    assert device_token_spans(rep, 8, W) == {"d0": (0, 8 * W),
                                             "d1": (0, 8 * W)}


def test_device_token_spans_rejects_seq_split():
    with pytest.raises(ValueError, match="splits the sequence dimension"):
        device_token_spans({"d0": (slice(None), slice(0, 64)),
                            "d1": (slice(None), slice(64, 128))}, 8, 128)


def test_device_token_spans_rejects_strides_and_rank():
    with pytest.raises(ValueError, match="unit-stride"):
        device_token_spans({"d0": (slice(0, 8, 2), slice(None))}, 8, 128)
    with pytest.raises(ValueError, match="2-d"):
        device_token_spans({"d0": (slice(None),)}, 8, 128)


def test_chunk_routing_with_remote_spans():
    """Interval intersection against fake spans: an arriving chunk is split
    between a local and a remote device's span; only the local slice would
    be staged (the multi-host routing math, no jax devices needed)."""
    W = 128
    spans = device_token_spans(
        {"local": (slice(0, 4), slice(None)),
         "remote": (slice(4, 8), slice(None))}, 8, W)
    tok0, ntok = 3 * W, 2 * W                    # straddles the 4*W boundary
    pieces = {}
    for dev, (s0, s1) in spans.items():
        lo, hi = max(tok0, s0), min(tok0 + ntok, s1)
        if lo < hi:
            pieces[dev] = (lo, hi)
    assert pieces == {"local": (3 * W, 4 * W), "remote": (4 * W, 5 * W)}


# -- cross-backend bit-identity matrix -----------------------------------------
B, S = 16, 127


def _pipe(source, backend, streaming=False, **kw):
    return CkIOPipeline(
        source, B, S, ckio=CkIO(num_pes=4),
        file_opts=FileOptions(num_readers=2, splinter_bytes=32 * 1024,
                              backend=backend, max_workers=2),
        streaming=streaming, **kw)


def _drain_device(pipe):
    out = []
    for s in range(pipe.num_steps):
        x, y = pipe.get_batch_device(s)
        out.append((np.asarray(x), np.asarray(y)))
    pipe.close()
    return out


@pytest.fixture(scope="module")
def reference_batches(corpus):
    path, _ = corpus
    return _drain_device(_pipe(path, "thread"))


@pytest.mark.parametrize("backend", ["thread", "process"])
@pytest.mark.parametrize("streaming", [False, True])
@pytest.mark.parametrize("source", ["file", "fileset"])
def test_bit_identity_matrix(corpus, sharded, reference_batches,
                             backend, streaming, source):
    path, _ = corpus
    fs, _ = sharded
    src = fs if source == "fileset" else path
    pipe = _pipe(src, backend, streaming=streaming)
    copied = []
    pipe.ck.director.add_observer(lambda sm: copied.append(sm.bytes_copied))
    out = _drain_device(pipe)
    assert len(out) == len(reference_batches) == 4
    for (x, y), (rx, ry) in zip(out, reference_batches):
        assert np.array_equal(x, rx)
        assert np.array_equal(y, ry)
    # consumer-side zero-copy in every cell of the matrix
    assert copied and all(c == 0 for c in copied)
    assert pipe.ingest.summary()["host_permute_bytes"] == 0
    if backend == "process":
        assert _shm_leftovers() == []


def test_host_path_drop_remainder_both_ways(corpus, sharded):
    """get_batch over a FileSet == single file, with and without the
    remainder window (the 64 leftover tokens pad with pad_id)."""
    path, _ = corpus
    fs, _ = sharded
    for drop in (True, False):
        ref = CkIOPipeline(path, B, S, ckio=CkIO(num_pes=4),
                           file_opts=FileOptions(num_readers=2),
                           drop_remainder=drop, pad_id=7)
        got = CkIOPipeline(fs, B, S, ckio=CkIO(num_pes=4),
                           file_opts=FileOptions(num_readers=2),
                           drop_remainder=drop, pad_id=7)
        assert ref.num_steps == got.num_steps == (4 if drop else 5)
        for s in range(ref.num_steps):
            rx, ry = ref.get_batch(s)
            gx, gy = got.get_batch(s)
            assert np.array_equal(np.asarray(rx), np.asarray(gx))
            assert np.array_equal(np.asarray(ry), np.asarray(gy))
        ref.close()
        got.close()


# -- sharded streaming (constructor sharding=) ---------------------------------
def _one_device_sharding():
    import jax
    from jax.sharding import Mesh, NamedSharding, PartitionSpec

    mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))
    return NamedSharding(mesh, PartitionSpec("dp", None))


@pytest.mark.parametrize("backend", ["thread", "process"])
@pytest.mark.parametrize("streaming", [False, True])
def test_sharded_staging_no_fallback(corpus, sharded, reference_batches,
                                     backend, streaming):
    """Constructor sharding streams each chunk INTO the sharding: batches
    bit-identical to the unsharded path, host_permute_bytes == 0, and the
    whole-window fallback RuntimeWarning NEVER fires."""
    fs, _ = sharded
    sh = _one_device_sharding()
    pipe = _pipe(fs, backend, streaming=streaming, sharding=sh)
    out = []
    with warnings.catch_warnings():
        warnings.simplefilter("error")         # any RuntimeWarning fails
        for s in range(pipe.num_steps):
            x, y = pipe.get_batch_device(s)
            assert x.sharding.is_equivalent_to(sh, 2)
            out.append((np.asarray(x), np.asarray(y)))
        pipe.close()
    for (x, y), (rx, ry) in zip(out, reference_batches):
        assert np.array_equal(x, rx)
        assert np.array_equal(y, ry)
    assert pipe.ingest.summary()["host_permute_bytes"] == 0
    m = pipe.ck.director.shards.summary()
    window = 4 * B * (S + 1) * 4               # 4 steps of (B, S+1) uint32
    assert m["window_bytes"] == window
    # single host: every byte addressable, nothing crosses hosts, and the
    # staged ledger balances — each host stages exactly its slice
    assert m["addressable_bytes"] == window
    assert m["cross_host_placements"] == 0
    if streaming:
        assert m["device_put_calls"] > 4       # per-chunk, not per-window
    else:
        assert m["device_put_calls"] == 4      # one per step per device


def test_sharded_remainder_window(corpus, sharded):
    """drop_remainder=False + sharding: the final short window pads
    on-device and still matches the host path."""
    fs, _ = sharded
    sh = _one_device_sharding()
    host = CkIOPipeline(fs, B, S, ckio=CkIO(num_pes=4),
                        file_opts=FileOptions(num_readers=2,
                                              splinter_bytes=32 * 1024),
                        drop_remainder=False, pad_id=3)
    dev = _pipe(fs, "thread", streaming=True, sharding=sh,
                drop_remainder=False, pad_id=3)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        for s in range(host.num_steps):
            hx, hy = host.get_batch(s)
            dx, dy = dev.get_batch_device(s)
            assert np.array_equal(np.asarray(hx), np.asarray(dx))
            assert np.array_equal(np.asarray(hy), np.asarray(dy))
    host.close()
    dev.close()


def test_per_call_sharding_mismatch_raises(corpus):
    path, _ = corpus
    import jax

    sh = _one_device_sharding()
    pipe = _pipe(path, "thread", streaming=True, sharding=sh)
    try:
        with pytest.raises(ValueError, match="constructor sharding"):
            pipe.get_batch_device(
                0, sharding=jax.sharding.SingleDeviceSharding(jax.devices()[0]))
        # the matching sharding (and None) both work
        x, _ = pipe.get_batch_device(0, sharding=sh)
        x2, _ = pipe.get_batch_device(1)
        assert np.asarray(x).shape == (B, S)
        assert np.asarray(x2).shape == (B, S)
    finally:
        pipe.close()


# -- recovery interop ----------------------------------------------------------
def test_respawn_attributes_reissue_to_shard(tmp_path):
    """Kill the worker owning shard 1 mid-drain on a 2-shard FileSet:
    completion is bit-identical and RecoveryMetrics attributes the re-read
    bytes to shard 1 (exact — splinters never span shards)."""
    rows = 64 * 1024                            # 256 KiB per shard (uint32)
    rng = np.random.default_rng(SEED)
    arr = rng.integers(0, 2**31, size=2 * rows, dtype=np.uint32)
    fs = FileSet.build(write_token_shards(str(tmp_path), arr, [rows, rows]))
    ck = CkIO(num_pes=4)
    # 2 hard segments -> reader k owns shard k; max_workers=2 -> worker k
    # runs reader k alone. CrashReader(reader=1, after=1) kills worker 1
    # before its 2nd splinter: the unfinished tail is entirely in shard 1.
    fh = ck.open_fileset_sync(fs, FileOptions(
        num_readers=2, splinter_bytes=128 * 1024, backend="process",
        max_workers=2, recovery="respawn", max_respawns=2,
        worker_fault=CrashReader(reader=1, after=1, code=66)))
    sess = ck.start_read_session_sync(fh, fs.data_bytes, 0, timeout=120)
    seen, lock = [], threading.Lock()
    sess.subscribe_splinters(
        lambda ev: (lock.acquire(), seen.append(ev.index), lock.release()),
        replay=True)
    view = ck.read_view_sync(sess, fs.data_bytes, 0, timeout=120)
    assert bytes(view) == arr.tobytes()         # bit-identical completion
    m = sess.metrics.recovery
    assert m.respawns == 1
    assert m.reissued_splinters == 1
    assert dict(m.reissued_bytes_by_shard) == {1: 128 * 1024}
    assert sess.metrics.bytes_copied == 0
    with lock:
        assert sorted(seen) == list(range(4))   # each splinter exactly once
    # per-shard read accounting: re-reads land on the right shard too
    assert sess.metrics.shard_bytes[0] == rows * 4
    assert sess.metrics.shard_bytes[1] == rows * 4
    ck.close_read_session_sync(sess)
    assert ck.director.recovery.reissued_bytes_by_shard.get(1) == 128 * 1024
    ck.close_sync(fh)
    assert _shm_leftovers() == []
