"""Optimizer, gradient compression, checkpointing, fault supervisor."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, smoke_config
from repro.models import build_model
from repro.train import (
    AsyncCheckpointer,
    FaultInjected,
    OptConfig,
    StepSupervisor,
    adamw_update,
    grad_compress,
    init_opt_state,
    lr_at,
    make_train_step,
    restore_tree,
    save_checkpoint,
)

KEY = jax.random.PRNGKey(0)


def test_lr_schedule():
    cfg = OptConfig(peak_lr=1.0, warmup_steps=10, decay_steps=110,
                    min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr_at(cfg, jnp.asarray(110))) == pytest.approx(0.1, abs=1e-3)


def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params)
    cfg = OptConfig(peak_lr=0.1, warmup_steps=1, decay_steps=200,
                    weight_decay=0.0)
    for _ in range(150):
        g = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.15


def test_master_weights_beat_bf16_drift():
    """With bf16 params, master weights must accumulate small updates that
    plain bf16 params would lose to rounding."""
    p0 = jnp.full((8,), 100.0, jnp.bfloat16)
    cfg = OptConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=10**6,
                    weight_decay=0.0, grad_clip=0)
    pm, sm = {"w": p0}, init_opt_state({"w": p0}, master_weights=True)
    pn, sn = {"w": p0}, init_opt_state({"w": p0})
    for _ in range(50):
        g = {"w": jnp.ones((8,), jnp.float32)}
        pm, sm, _ = adamw_update(g, sm, pm, cfg)
        pn, sn, _ = adamw_update(g, sn, pn, cfg)
    drift_master = float(jnp.abs(sm["master"]["w"] - 100.0).mean())
    assert drift_master > 0.04          # master accumulated ~50 * 1e-3
    assert np.isfinite(np.asarray(pm["w"], np.float32)).all()


def test_int8_error_feedback_bounded():
    g = {"a": jax.random.normal(KEY, (256,)) * 0.1}
    ef = grad_compress.init_ef_state(g)
    total_applied = jnp.zeros((256,))
    for i in range(20):
        q, deq, ef = grad_compress.ef_compress(g, ef)
        total_applied = total_applied + deq["a"]
    # error feedback: accumulated applied updates track accumulated true grads
    err = float(jnp.abs(total_applied - 20 * g["a"]).max())
    scale = float(jnp.abs(g["a"]).max())
    assert err < scale, f"EF residual unbounded: {err} vs {scale}"


def test_compressed_bytes_accounting():
    g = {"a": jnp.zeros((100,)), "b": jnp.zeros((10, 10))}
    assert grad_compress.compressed_bytes(g, "fp32") == 800
    assert grad_compress.compressed_bytes(g, "bf16") == 400
    assert grad_compress.compressed_bytes(g, "int8") == 208


def test_microbatching_matches_full_batch():
    cfg = smoke_config(get_config("phi4-mini-3.8b")).replace(dtype="float32",
                                                             remat_policy="none")
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (4, 33), 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    from repro.train.train_step import make_loss_and_grads

    loss1, g1, _ = make_loss_and_grads(model, 1)(params, batch)
    for nmb in (2, 4):
        lossn, gn, _ = make_loss_and_grads(model, nmb)(params, batch)
        assert float(loss1) == pytest.approx(float(lossn), rel=1e-5)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(gn)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-4)


def test_checkpoint_roundtrip_and_ckio_restore(tmp_path):
    tree = {
        "a": jnp.arange(1000, dtype=jnp.float32).reshape(10, 100),
        "nested": {"b": jnp.ones((7,), jnp.bfloat16),
                   "c": jnp.asarray(3, jnp.int32)},
    }
    path = str(tmp_path / "t.ckpt")
    save_checkpoint(path, tree, step=42)
    for use_ckio in (False, True):
        restored, step = restore_tree(path, tree, use_ckio=use_ckio)
        assert step == 42
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_elastic_restore_sharded(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.train import restore_sharded

    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    path = str(tmp_path / "e.ckpt")
    save_checkpoint(path, tree, step=1)
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    restored, step = restore_sharded(path, tree, shardings)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding == shardings["w"]


def test_supervisor_recovers_from_faults(tmp_path):
    cfg = smoke_config(get_config("qwen2-vl-2b"))
    model = build_model(cfg)
    params = model.init(KEY)
    opt = init_opt_state(params)
    step_jit = jax.jit(make_train_step(model, OptConfig(peak_lr=1e-3,
                                                        warmup_steps=1,
                                                        decay_steps=50)))

    def step_fn(state, batch):
        p, o, m = step_jit(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, m

    def batch_for(s):
        k = jax.random.PRNGKey(s)
        t = jax.random.randint(k, (2, 17), 0, cfg.vocab_size)
        return {"tokens": t[:, :-1], "labels": t[:, 1:]}

    ck = AsyncCheckpointer(str(tmp_path / "ckpts"), keep=2)
    boom = {"left": 2}

    def fault_hook(step):
        if step == 5 and boom["left"] > 0:
            boom["left"] -= 1
            raise FaultInjected("node died")

    sup = StepSupervisor(step_fn, ck, ckpt_every=3, max_retries=3)
    state = sup.run({"params": params, "opt": opt}, batch_for, 8,
                    fault_hook=fault_hook)
    assert sup.stats.failures == 2
    assert sup.stats.restores == 2
    assert int(jax.device_get(state["opt"]["step"])) >= 8
    ck.shutdown()


def test_supervisor_gives_up_after_max_retries(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path / "c2"), keep=1)

    def step_fn(state, batch):
        return state, {}

    def always_fail(step):
        raise FaultInjected("persistent failure")

    sup = StepSupervisor(step_fn, ck, ckpt_every=1, max_retries=2)
    with pytest.raises(RuntimeError, match="retries exhausted"):
        sup.run({"x": jnp.zeros(())}, lambda s: None, 3,
                fault_hook=always_fail)
    ck.shutdown()


def test_checkpoint_alignment_edge_cases(tmp_path):
    """Regression: (a) a final leaf ending exactly on the 128-byte alignment
    boundary must not be clobbered by tail padding; (b) a misaligned final
    leaf must still be fully readable through a CkIO session (no EOF)."""
    aligned = {"w": jnp.arange(64, dtype=jnp.float32)}        # 256 B = 2*128
    odd = {"w": jnp.arange(64, dtype=jnp.float32),
           "c": jnp.asarray(7, jnp.int32)}                     # 4 B tail
    for i, tree in enumerate((aligned, odd)):
        path = str(tmp_path / f"edge{i}.ckpt")
        save_checkpoint(path, tree, step=i)
        for use_ckio in (False, True):
            restored, step = restore_tree(path, tree, use_ckio=use_ckio)
            assert step == i
            for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
