# Runtime environment tuning for the benchmark and training legs.
#
#   source scripts/env.sh        (ci.sh does this before its bench legs;
#                                 `repro.launch.train --tuned-env` re-execs
#                                 itself through it)
#
# Every knob degrades SILENTLY when the host lacks the library or the
# variable is already set — sourcing this file never fails a run and never
# overrides an operator's explicit environment.

# -- allocator: tcmalloc when present -----------------------------------------
# The hot path hands out zero-copy arena views, but the surrounding driver
# (batch assembly, checkpoint serialization) still allocates; tcmalloc's
# thread caches cut the malloc contention that shows up as jitter in the
# depth-managed submission benchmarks. Preload only when the host ships it.
if [ -z "${CKIO_NO_TCMALLOC:-}" ]; then
  for _ckio_tc in \
      /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
      /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
      /usr/lib/x86_64-linux-gnu/libtcmalloc.so \
      /usr/lib/libtcmalloc.so.4 \
      /usr/lib/libtcmalloc.so; do
    if [ -e "$_ckio_tc" ]; then
      case ":${LD_PRELOAD:-}:" in
        *":$_ckio_tc:"*) ;;                      # already preloaded
        *) export LD_PRELOAD="${LD_PRELOAD:+$LD_PRELOAD:}$_ckio_tc" ;;
      esac
      # Silence tcmalloc's large-alloc stderr reports: session arenas are
      # deliberately file-window-sized and would trip the default 1 GiB
      # threshold on every big session.
      export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-10737418240}"
      break
    fi
  done
  unset _ckio_tc
fi

# -- XLA / JAX ----------------------------------------------------------------
# Quiet the TF/XLA C++ banner spam that otherwise interleaves with benchmark
# CSV output, and keep single-host CPU runs deterministic: one intra-op
# thread so XLA's Eigen pool doesn't fight the reader I/O threads for cores
# (benchmark variance, not correctness). Respect pre-set values.
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"
if [ -z "${XLA_FLAGS:-}" ]; then
  export XLA_FLAGS="--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1"
fi

# Marker so re-exec wrappers (launch/train.py --tuned-env) can tell the
# environment is already applied and avoid an exec loop.
export CKIO_TUNED_ENV=1
