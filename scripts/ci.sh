#!/usr/bin/env bash
# CI entry point: tier-1 test suite + hot-path benchmark smoke.
#
# Usage: scripts/ci.sh            (from the repo root)
#
# Tier-1 (must stay green; see ROADMAP.md):
#   PYTHONPATH=src python -m pytest -x -q
# Smoke: benchmarks/perf_hotpath.py --quick exercises the zero-copy
# session-drain path end to end and refreshes BENCH_hotpath.json.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1 tests =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

echo "== hot-path benchmark (smoke) =="
python benchmarks/perf_hotpath.py --quick

echo "== ci OK =="
