#!/usr/bin/env bash
# CI entry point: tier-1 test suite + benchmark smokes + coverage floor.
#
# Usage: scripts/ci.sh            (from the repo root)
#
# Tier-1 (must stay green; see ROADMAP.md):
#   PYTHONPATH=src python -m pytest -x -q
# Smokes (quick mode writes scratch-dir BENCH_*.quick.json files; the
# committed repo-root BENCH_*.json artifacts are full-mode only and are
# NOT touched by CI — regenerate them by running the benchmarks without
# --quick):
#   benchmarks/perf_hotpath.py --quick       zero-copy session drain
#   benchmarks/perf_device_ingest.py --quick device-ingest path (incl. the
#                                            Pallas interpret-mode kernel
#                                            check)
#   benchmarks/perf_streaming.py --quick     event-driven splinter streaming
#                                            (overlap fraction + streamed/
#                                            whole-window bit-equality)
#   benchmarks/perf_numa.py --quick          topology-aware placement
#                                            (cross-domain delivery bytes
#                                            drop, zero-copy + bit-identity
#                                            preserved)
#   benchmarks/perf_shm.py --quick           multi-process reader backend
#                                            (shm arena drain >= 1.2x the
#                                            copy-through-pipe baseline,
#                                            consumer bytes_copied == 0,
#                                            process/thread bit-identity)
#   benchmarks/perf_recovery.py --quick      fault recovery (worker SIGKILLed
#                                            mid-drain completes bit-
#                                            identically via respawn AND
#                                            re-issue, overhead <= 1.5x a
#                                            clean paced drain)
#   benchmarks/perf_fileset.py --quick       multi-shard FileSet sessions
#                                            (sharded drain bit-identical to
#                                            the single-file stream, 8-device
#                                            staged-bytes ledger: constructor
#                                            sharding stages 1x the window
#                                            balanced across devices, legacy
#                                            per-call fallback ~2x)
#   benchmarks/perf_service.py --quick       persistent reader service
#                                            (pooled re-arm steady-state
#                                            setup >= 5x per-session spawn,
#                                            arena recycling, >= 4 concurrent
#                                            sessions through one pool,
#                                            bit-identical + zero-copy,
#                                            /dev/shm clean after shutdown)
#   benchmarks/perf_serve.py --quick         continuous-batching serve under
#                                            Poisson session churn (goodput
#                                            >= 1.5x the static baseline at
#                                            equal-or-better e2e p99, bit-
#                                            identical to the sequential
#                                            oracle, zero-copy ingest,
#                                            ServiceBusy backpressure on the
#                                            measured path, /dev/shm clean)
#   benchmarks/perf_coldpath.py --quick      cold-cache read engine (depth-
#                                            managed async submission >= 1.5x
#                                            blocking under the modeled PFS,
#                                            O_DIRECT end-to-end, QueueTuner
#                                            within 10% of the fixed grid
#                                            best, mincore-verified eviction
#                                            state stamped in the artifact;
#                                            hosts without eviction still run
#                                            — local legs record warm)
# Bench legs run under scripts/env.sh (tcmalloc LD_PRELOAD + quiet XLA env
# when available; silent degrade otherwise).
# Fault matrix: the seeded fault-injection tests replayed under several
# CKIO_FAULT_SEED values (tier-1 already runs the full recovery suite once
# under the default seed; the matrix re-derives the FaultPlan from each
# seed and must stay deterministic + green for all of them).
# Coverage floor: line coverage of src/repro/core + src/repro/data +
# src/repro/io + src/repro/ipc + src/repro/serve over the core/data-focused
# tests must stay >= the floor in scripts/coverage_floor.py (stdlib settrace
# fallback — no third-party deps required).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1 tests =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

# Bench legs run under the tuned environment (tcmalloc preload + quiet
# XLA logging when the host has them; scripts/env.sh degrades silently).
source scripts/env.sh

echo "== hot-path benchmark (smoke) =="
python benchmarks/perf_hotpath.py --quick

echo "== device-ingest benchmark (smoke, interpret check) =="
python benchmarks/perf_device_ingest.py --quick

echo "== streaming benchmark (smoke, overlap + equivalence) =="
python benchmarks/perf_streaming.py --quick

echo "== numa benchmark (smoke, cross-domain locality + equivalence) =="
python benchmarks/perf_numa.py --quick

echo "== shm / multi-process backend benchmark (smoke) =="
python benchmarks/perf_shm.py --quick

echo "== recovery benchmark (smoke, mid-drain SIGKILL) =="
python benchmarks/perf_recovery.py --quick

echo "== fileset benchmark (smoke, sharded sessions + staged-bytes ledger) =="
python benchmarks/perf_fileset.py --quick

echo "== reader-service benchmark (smoke, pooled re-arm vs spawn) =="
python benchmarks/perf_service.py --quick

echo "== serve benchmark (smoke, continuous batching under churn) =="
python benchmarks/perf_serve.py --quick

echo "== cold-path benchmark (smoke, depth-managed submission + O_DIRECT) =="
python benchmarks/perf_coldpath.py --quick

echo "== fault matrix (seeded deterministic replay) =="
for seed in 11 20260809 424242; do
  echo "-- CKIO_FAULT_SEED=$seed --"
  CKIO_FAULT_SEED=$seed PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -q tests/test_recovery.py \
    -k "fault_plan or replay or reissue or respawn"
done

echo "== fault matrix (pooled reader-service backend) =="
for seed in 11 20260809 424242; do
  echo "-- CKIO_FAULT_SEED=$seed (service) --"
  CKIO_FAULT_SEED=$seed PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -q tests/test_service.py \
    -k "fault_plan or respawn or sibling"
done

echo "== coverage floor (core + data + io + ipc + serve) =="
python scripts/coverage_floor.py

echo "== ci OK =="
