#!/usr/bin/env python
"""Line-coverage floor for the CkIO core + data + io + ipc + serve packages.

Runs the core/data-focused test files and fails if line coverage of
``src/repro/core`` + ``src/repro/data`` + ``src/repro/io`` +
``src/repro/ipc`` + ``src/repro/serve`` drops below the floor — so new
paths in the I/O/pipeline/serving subsystem can't land untested. (``ipc`` worker-process code is covered by
running ``worker_main`` inline in the test process; lines executed only
inside spawned children are invisible to the collectors.)

Uses the ``coverage`` package when installed; otherwise falls back to a
stdlib ``sys.settrace`` collector (no third-party deps — the container
constraint). Executable lines are derived from compiled code objects
(``co_lines``), so docstrings/blank lines don't dilute the percentage.

Usage:
    python scripts/coverage_floor.py [--min PCT] [--verbose]
"""
from __future__ import annotations

import argparse
import os
import sys
import threading
from collections import defaultdict

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
TARGETS = [
    os.path.join(REPO, "src", "repro", "core"),
    os.path.join(REPO, "src", "repro", "data"),
    os.path.join(REPO, "src", "repro", "io"),
    os.path.join(REPO, "src", "repro", "ipc"),
    os.path.join(REPO, "src", "repro", "serve"),
]
# Core/data-focused subset: exercises every module under the targets without
# dragging in the (slow, jax-heavy) kernel/model sweeps.
TEST_FILES = [
    "tests/test_ckio_core.py",
    "tests/test_layout.py",
    "tests/test_scheduler.py",
    "tests/test_data_pipeline.py",
    "tests/test_hotpath.py",
    "tests/test_device_ingest.py",
    "tests/test_streaming.py",
    "tests/test_perf_levers.py",
    "tests/test_numa.py",
    "tests/test_ipc.py",
    "tests/test_recovery.py",
    "tests/test_fileset.py",
    "tests/test_submit.py",
    "tests/test_service.py",
    "tests/test_serve.py",
]
DEFAULT_MIN = 85.0     # measured 89.4% at PR 2 (core+data); io added PR 3
#                        (io/numa.py + placement topology covered by PR 4's
#                        tests/test_numa.py); ipc added PR 5 (worker_main
#                        exercised INLINE by tests/test_ipc.py — code run
#                        only inside spawned worker processes is invisible
#                        to both the settrace and coverage-pkg collectors)


def executable_lines(path: str) -> set:
    """All line numbers the compiler can attribute bytecode to."""
    with open(path, "r") as f:
        src = f.read()
    lines: set = set()

    def walk(code) -> None:
        for _, _, ln in code.co_lines():
            if ln is not None:
                lines.add(ln)
        for const in code.co_consts:
            if hasattr(const, "co_lines"):
                walk(const)

    try:
        walk(compile(src, path, "exec"))
    except SyntaxError:
        pass
    # def/class/decorator headers execute only at import; keep them — they
    # are in co_lines of the enclosing code object already.
    return lines


def target_files() -> list:
    out = []
    for root in TARGETS:
        for dirpath, _, names in os.walk(root):
            out.extend(
                os.path.join(dirpath, n) for n in names if n.endswith(".py")
            )
    return sorted(out)


def run_with_coverage_pkg(files):
    import coverage

    cov = coverage.Coverage(source=TARGETS, messages=False)
    cov.start()
    rc = run_pytest()
    cov.stop()
    hit = {}
    for f in files:
        try:
            _, executable, _, missing, _ = cov.analysis2(f)
        except Exception:
            executable, missing = [], []
        hit[f] = (set(executable) - set(missing), set(executable))
    return rc, hit


def run_with_settrace(files):
    prefixes = tuple(TARGETS)
    executed = defaultdict(set)
    # co_filename can be unnormalized (e.g. ``tests/../src/...`` from path
    # inserts); cache the normalization decision per raw filename.
    norm_cache: dict = {}

    def resolve(fn: str):
        hit = norm_cache.get(fn)
        if hit is None:
            norm = os.path.normpath(os.path.abspath(fn))
            hit = norm_cache[fn] = norm if norm.startswith(prefixes) else ""
        return hit

    def global_trace(frame, event, arg):
        if not resolve(frame.f_code.co_filename):
            return None
        return local_trace

    def local_trace(frame, event, arg):
        if event == "line":
            executed[resolve(frame.f_code.co_filename)].add(frame.f_lineno)
        return local_trace

    threading.settrace(global_trace)
    sys.settrace(global_trace)
    try:
        rc = run_pytest()
    finally:
        sys.settrace(None)
        threading.settrace(None)
    hit = {}
    for f in files:
        ex = executable_lines(f)
        hit[f] = (executed.get(f, set()) & ex, ex)
    return rc, hit


def run_pytest() -> int:
    import pytest

    return pytest.main(["-q", "-p", "no:cacheprovider", *TEST_FILES])


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--min", type=float, default=DEFAULT_MIN,
                    help=f"coverage floor in percent (default {DEFAULT_MIN})")
    ap.add_argument("--verbose", action="store_true",
                    help="per-file coverage table")
    args = ap.parse_args()

    os.chdir(REPO)
    sys.path.insert(0, os.path.join(REPO, "src"))
    sys.path.insert(0, os.path.join(REPO, "tests"))

    files = target_files()
    try:
        import coverage  # noqa: F401
        rc, hit = run_with_coverage_pkg(files)
        mode = "coverage-pkg"
    except ImportError:
        rc, hit = run_with_settrace(files)
        mode = "settrace"
    if rc != 0:
        print(f"coverage_floor: test run failed (rc={rc})")
        return rc

    tot_hit = tot_ex = 0
    rows = []
    for f in files:
        h, ex = hit[f]
        tot_hit += len(h)
        tot_ex += len(ex)
        pct = 100.0 * len(h) / len(ex) if ex else 100.0
        rows.append((pct, len(h), len(ex), os.path.relpath(f, REPO)))
    pct_total = 100.0 * tot_hit / tot_ex if tot_ex else 100.0

    if args.verbose:
        for pct, h, ex, rel in sorted(rows):
            print(f"{pct:6.1f}%  {h:4d}/{ex:<4d}  {rel}")
    print(f"coverage[{mode}] src/repro/core+data+io+ipc+serve: "
          f"{pct_total:.1f}% ({tot_hit}/{tot_ex} lines), floor {args.min}%")
    if pct_total < args.min:
        print("coverage_floor: FAIL — below floor")
        return 1
    print("coverage_floor: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
