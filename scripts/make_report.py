"""Generate EXPERIMENTS.md §Dry-run and §Roofline markdown from the JSONL.

Usage: PYTHONPATH=src python scripts/make_report.py [dryrun.jsonl]
Prints the two sections to stdout (pasted into EXPERIMENTS.md).
"""
import json
import sys

sys.path.insert(0, "src")

from repro.launch.roofline import (  # noqa: E402
    HBM_PER_CHIP,
    analyze_record,
    latest_by_cell,
    load_records,
)

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def hbm_gib(rec):
    if "temp_size_in_bytes" not in rec:
        return None
    return (rec.get("argument_size_in_bytes", 0)
            + rec.get("temp_size_in_bytes", 0)) / 2**30


def coll_total(rec):
    c = rec.get("collectives") or rec.get("scanned_collectives") or {}
    return sum(v for k, v in c.items() if k != "count")


def what_to_do(r, rec) -> str:
    """One sentence per cell: what moves the dominant term down (wording
    reflects the MEASURED §Perf findings, not just priors)."""
    arch, shape, dom = r.arch, r.shape, r.dominant
    fam_ssm = arch in ("falcon-mamba-7b",)
    fam_moe = arch in ("qwen2-moe-a2.7b", "olmoe-1b-7b")
    odd_heads = arch in ("phi3-medium-14b", "phi4-mini-3.8b", "qwen2-vl-2b")
    if r.shape == "train_4k":
        if dom == "memory":
            if fam_ssm:
                return ("fuse per-chunk SSM discretization so (B,S,d_i,n) "
                        "never materializes — measured −69% (§Perf A)")
            return ("pre-fusion bytes dominated by attention/GLU "
                    "intermediates + gathered logits; Pallas flash kernel "
                    "keeps softmax in VMEM, one-hot xent avoids the logits "
                    "gather (measured −71% on phi4)")
        if dom == "collective":
            base = ("TP activation psums (2/layer/microbatch) + ZeRO param "
                    "gathers; Megatron-style sequence parallelism would "
                    "halve them")
            if odd_heads:
                base += ("; head padding removes the hd-shard score psums "
                         "(measured −69% total, §Perf B)")
            return base
    if r.shape == "prefill_32k":
        if dom == "memory":
            return ("attention score/prob traffic: the Pallas flash kernel "
                    "keeps the online softmax in VMEM (reads q/k/v once)")
        if dom == "collective":
            return ("per-layer TP activation psums at 32k tokens; "
                    "sequence-parallel (ring) attention amortizes them")
    if r.shape in ("decode_32k", "long_500k"):
        if dom == "collective" and odd_heads:
            return ("hd-shard score psums in decode — head padding (§Perf "
                    "B) removes them")
        if fam_moe:
            return ("resident expert weights dominate: only top-k shards "
                    "are touched per token — int8 weights or expert "
                    "caching cut traffic")
        return ("one pass over KV cache + weights is the floor; larger "
                "decode batch or int8 KV cache raises tokens/s")
    return "—"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "benchmarks/results/dryrun.jsonl"
    recs = load_records(path)
    base = latest_by_cell(recs, tag="")

    # ---------- §Dry-run table ----------
    print("### Dry-run results (production config per cell)\n")
    print("| arch | shape | mesh | compile | HBM/chip (GiB) | fit<16 | "
          "collective B/dev (method) |")
    print("|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), rec in sorted(
        base.items(), key=lambda kv: (kv[0][0], SHAPE_ORDER.get(kv[0][1], 9),
                                      kv[0][2])
    ):
        if "error" in rec:
            print(f"| {arch} | {shape} | {mesh} | **FAIL** | — | — | "
                  f"{rec['error'][:60]} |")
            continue
        g = hbm_gib(rec)
        fit = "—" if g is None else ("✓" if g <= 16 else "**✗**")
        meth = rec.get("collectives_method", "scanned")
        meth = {"extrapolated(nb=2,4)": "extrap",
                "exact(unrolled)": "exact",
                "scanned(undercounted)": "scanned*"}.get(meth, meth)
        print(f"| {arch} | {shape} | {mesh} | ok "
              f"({rec.get('t_compile_s','-')}s) | "
              f"{'-' if g is None else f'{g:.1f}'} | {fit} | "
              f"{coll_total(rec):.2e} ({meth}) |")
    print()

    # ---------- §Roofline table ----------
    print("### Roofline (single-pod 16×16, 256 chips)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | dominant | "
          "MODEL/HLO | roof% | bottleneck action |")
    print("|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), rec in sorted(
        base.items(), key=lambda kv: (kv[0][0], SHAPE_ORDER.get(kv[0][1], 9))
    ):
        if mesh != "16x16" or "error" in rec:
            continue
        r = analyze_record(rec)
        if r is None:
            continue
        print(f"| {arch} | {shape} | {r.compute_s:.3g} | {r.memory_s:.3g} | "
              f"{r.collective_s:.3g} | **{r.dominant}** | "
              f"{r.useful_ratio:.2f} | {100*r.roofline_frac:.1f}% | "
              f"{what_to_do(r, rec)} |")
    print()

    # ---------- tagged (perf) records ----------
    tags = sorted({r.get("tag") for r in recs if r.get("tag")})
    if tags:
        print("### Tagged §Perf records\n")
        print("| tag | arch.shape | compute_s | memory_s | collective_s | "
              "HBM GiB | fit |")
        print("|---|---|---|---|---|---|---|")
        for tag in tags:
            cellmap = latest_by_cell(recs, tag=tag)
            for (arch, shape, mesh), rec in sorted(cellmap.items()):
                if "error" in rec:
                    print(f"| {tag} | {arch}.{shape}@{mesh} | FAIL | | | | |")
                    continue
                r = analyze_record(rec)
                g = hbm_gib(rec)
                if r is None:
                    print(f"| {tag} | {arch}.{shape}@{mesh} | — | — | — | "
                          f"{'-' if g is None else f'{g:.1f}'} | "
                          f"{'✓' if g and g <= 16 else '✗'} |")
                    continue
                print(f"| {tag} | {arch}.{shape}@{mesh} | {r.compute_s:.3g} | "
                      f"{r.memory_s:.3g} | {r.collective_s:.3g} | "
                      f"{'-' if g is None else f'{g:.1f}'} | "
                      f"{'✓' if g and g <= 16 else '✗'} |")


if __name__ == "__main__":
    main()
