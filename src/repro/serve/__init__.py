"""Serving substrate: prefill/decode steps, generation, request batching."""
from repro.serve.serve_step import greedy_generate, make_decode_step, make_prefill_step
from repro.serve.batching import BatchServer, Request

__all__ = [
    "greedy_generate",
    "make_decode_step",
    "make_prefill_step",
    "BatchServer",
    "Request",
]
