"""Serving subsystem: continuous-batching decode over per-request CkIO
sessions.

This package is the repo's "millions of users" scenario — the opposite
regime from the training pipeline's few long-lived sessions: thousands of
short-lived prompt-ingest sessions per second, fed through a shared
:class:`~repro.ipc.service.ReaderService`, driving a continuous-batching
decode loop with tail-latency accounting.

The contracts, briefly (full versions in each module's docstring):

**Session lifetime per request** (``ingest.py``): one CkIO read session per
request, open only from admission until the decode engine has consumed the
prompt — ``submit -> [queued] -> ingesting -> ready -> admitted`` (session
closes here) ``-> decoding -> done``.

**View lifetime vs slot eviction** (``ingest.py`` / ``engine.py``): the
prompt is delivered as a borrowed zero-copy view of the session arena and
is consumed *during* ``engine.admit``; ``RequestIngester.release`` then
drops every export and closes the session before decode continues. Slot
eviction (EOS/max-tokens) therefore never touches CkIO state, and no view
outlives its session — the service's arena segments recycle instead of
quarantining.

**When ``ServeOverloaded`` surfaces vs queues** (``ingest.py``): a
``ServiceBusy`` from the reader tier or a tripped inflight-ingest-byte
budget *queues* the request (bounded FIFO, retried every poll — admitted,
never dropped); only a submit that finds that queue already full is
rejected with :class:`~repro.serve.ingest.ServeOverloaded`. The decode loop
itself never blocks on a saturated reader tier.

Batching policies live in ``batching.py`` (continuous vs static over the
same engine, plus the legacy model-level ``BatchServer``); decode engines
in ``engine.py`` (a modeled-cost engine for churn benchmarks, a real
per-slot model engine, and the sequential oracle both are bit-identical
to); metrics in :class:`~repro.core.metrics.ServeMetrics` on the Director
observer path.
"""
from repro.serve.serve_step import greedy_generate, make_decode_step, make_prefill_step
from repro.serve.batching import (
    BatchServer,
    ContinuousBatcher,
    Request,
    StaticBatcher,
)
from repro.serve.engine import (
    ModeledEngine,
    ModelEngine,
    decode_one,
    sequential_oracle,
)
from repro.serve.ingest import RequestIngester, ServeOverloaded, ServeRequest

__all__ = [
    "greedy_generate",
    "make_decode_step",
    "make_prefill_step",
    "BatchServer",
    "Request",
    "ContinuousBatcher",
    "StaticBatcher",
    "ModeledEngine",
    "ModelEngine",
    "decode_one",
    "sequential_oracle",
    "RequestIngester",
    "ServeOverloaded",
    "ServeRequest",
]
