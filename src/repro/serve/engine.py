"""Slot-based decode engines for the continuous batcher.

A decode engine owns ``slots`` independent generation lanes:

  * ``admit(slot, prompt)``  prime a free slot from a prompt token sequence
    (the "prefill"). The prompt is consumed *during* the call — engines
    never retain a reference, so callers may hand in a borrowed arena view
    and close its session the moment ``admit`` returns.
  * ``step()``               generate one token on every occupied slot;
    returns ``{slot: token}``.
  * ``evict(slot)``          free the slot (EOS / max-tokens — decided by
    the batcher, engines are policy-free).

Slots are fully independent: a slot's token stream depends only on its own
prompt, never on which other slots are occupied or when neighbours were
admitted/evicted. That independence is what makes continuous batching
bit-identical to a sequential oracle (``decode_one`` below is the shared
completion rule both use).

Two implementations:

  * :class:`ModeledEngine` — a deterministic hash-fold "LM" with an
    explicit wall-clock cost model (``step_base_s + step_slot_s * occupied``
    per step). This is the churn-benchmark engine: it reproduces the
    economics of batched decode (per-step fixed cost amortized over
    occupied slots; static batches pay for stragglers) while running hot in
    CI, and its outputs are exactly reproducible for oracle comparison.
  * :class:`ModelEngine` — the real thing: wraps a ``model_zoo`` model with
    one B=1 decode state per slot (prefill = replaying the prompt through
    the jitted decode step, matching ``serve_step.greedy_generate``
    semantics token for token).
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence

_FOLD_MOD = (1 << 61) - 1
_FOLD_MUL = 1000003


class ModeledEngine:
    """Deterministic modeled decode engine (see module docstring).

    Token function: a slot's state is a running hash fold of everything it
    has seen (prompt then generated tokens); the next token is
    ``state % vocab``. Same prompt -> same stream, independent of slot
    index, admission time, or co-residents.
    """

    def __init__(
        self,
        slots: int,
        *,
        vocab: int = 256,
        step_base_s: float = 0.0,
        step_slot_s: float = 0.0,
        prefill_token_s: float = 0.0,
    ):
        if slots < 1:
            raise ValueError("need at least one decode slot")
        self.slots = slots
        self.vocab = vocab
        self.step_base_s = step_base_s
        self.step_slot_s = step_slot_s
        self.prefill_token_s = prefill_token_s
        self._h: List[Optional[int]] = [None] * slots
        self._pending: List[Optional[int]] = [None] * slots

    def occupied(self) -> List[int]:
        return [i for i, h in enumerate(self._h) if h is not None]

    def free_slots(self) -> List[int]:
        return [i for i, h in enumerate(self._h) if h is None]

    def admit(self, slot: int, prompt: Sequence[int]) -> None:
        if self._h[slot] is not None:
            raise RuntimeError(f"slot {slot} already occupied")
        h = 1
        for t in prompt:
            h = (h * _FOLD_MUL + int(t) + 1) % _FOLD_MOD
        if self.prefill_token_s:
            time.sleep(self.prefill_token_s * len(prompt))
        self._h[slot] = h
        self._pending[slot] = h % self.vocab

    def step(self) -> Dict[int, int]:
        occ = self.occupied()
        if not occ:
            return {}
        cost = self.step_base_s + self.step_slot_s * len(occ)
        if cost:
            time.sleep(cost)
        out: Dict[int, int] = {}
        for i in occ:
            tok = self._pending[i]
            out[i] = tok
            h = (self._h[i] * _FOLD_MUL + tok + 1) % _FOLD_MOD
            self._h[i] = h
            self._pending[i] = h % self.vocab
        return out

    def evict(self, slot: int) -> None:
        self._h[slot] = None
        self._pending[slot] = None


class ModelEngine:
    """Per-slot B=1 decode over a real ``model_zoo`` model.

    Greedy semantics match ``serve_step.greedy_generate`` exactly: prefill
    replays the prompt through the jitted decode step token by token
    (correct for state-carrying families — SSM / RG-LRU), the first
    generated token is the argmax over the prompt's final logits, and each
    ``step`` feeds the previous token back through decode. A continuous run
    is therefore bit-identical to calling ``greedy_generate`` on each
    request alone.
    """

    def __init__(
        self,
        model: Any,
        params: Any,
        slots: int,
        *,
        seq_budget: int = 256,
        frames: Optional[Any] = None,
    ):
        import jax.numpy as jnp
        from repro.serve.serve_step import make_decode_step

        if slots < 1:
            raise ValueError("need at least one decode slot")
        self._jnp = jnp
        self.model = model
        self.params = params
        self.slots = slots
        self.seq_budget = seq_budget
        self.frames = frames
        self._decode = make_decode_step(model)
        self._state: List[Optional[Any]] = [None] * slots
        self._pending: List[Optional[int]] = [None] * slots

    def occupied(self) -> List[int]:
        return [i for i, s in enumerate(self._state) if s is not None]

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._state) if s is None]

    def _tok_batch(self, tok: int):
        return {"tokens": self._jnp.asarray([[int(tok)]], self._jnp.int32)}

    def admit(self, slot: int, prompt: Sequence[int]) -> None:
        if self._state[slot] is not None:
            raise RuntimeError(f"slot {slot} already occupied")
        state = self.model.init_decode_state(
            self.params, 1, self.seq_budget, frames=self.frames)
        logits = None
        for t in prompt:
            logits, state = self._decode(self.params, state, self._tok_batch(t))
        if logits is None:
            raise ValueError("empty prompt")
        self._state[slot] = state
        self._pending[slot] = int(
            self._jnp.argmax(logits[:, -1], axis=-1)[0])

    def step(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for i in self.occupied():
            tok = self._pending[i]
            out[i] = tok
            logits, state = self._decode(
                self.params, self._state[i], self._tok_batch(tok))
            self._state[i] = state
            self._pending[i] = int(
                self._jnp.argmax(logits[:, -1], axis=-1)[0])
        return out

    def evict(self, slot: int) -> None:
        self._state[slot] = None
        self._pending[slot] = None


def decode_one(
    engine: Any,
    slot: int,
    prompt: Sequence[int],
    max_new_tokens: int,
    eos_id: Optional[int] = None,
) -> List[int]:
    """The completion rule, shared by batchers and the oracle: generate
    until ``max_new_tokens`` tokens or EOS (EOS token included)."""
    engine.admit(slot, prompt)
    out: List[int] = []
    while True:
        tok = engine.step()[slot]
        out.append(tok)
        if len(out) >= max_new_tokens or (eos_id is not None
                                          and tok == eos_id):
            break
    engine.evict(slot)
    return out


def sequential_oracle(
    engine: Any,
    prompts: Sequence[Sequence[int]],
    max_new_tokens: Sequence[int],
    eos_id: Optional[int] = None,
) -> List[List[int]]:
    """Decode each request *alone*, in order, on slot 0 of ``engine`` —
    the ground truth any batched schedule must be bit-identical to."""
    return [
        decode_one(engine, 0, p, int(m), eos_id)
        for p, m in zip(prompts, max_new_tokens)
    ]
