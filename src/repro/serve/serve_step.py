"""Serving steps: jitted prefill + decode, greedy generation loop."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model_zoo import Model


def make_prefill_step(model: Model):
    @jax.jit
    def prefill(params, batch):
        return model.prefill_logits(params, batch)

    return prefill


def make_decode_step(model: Model):
    @jax.jit
    def decode(params, state, batch):
        return model.decode(params, state, batch)

    return decode


def greedy_generate(
    model: Model,
    params: Any,
    prompt: jax.Array,                # (B, S) int32
    max_new_tokens: int,
    *,
    seq_budget: Optional[int] = None,
    eos_id: Optional[int] = None,
    frames: Optional[jax.Array] = None,
) -> jax.Array:
    """Static-batch greedy decoding (uniform prompt lengths).

    Prefill primes the decode state by replaying the prompt through
    ``decode_step`` token by token (correct for every family incl. SSM /
    RG-LRU state carrying), then greedily samples ``max_new_tokens``.
    """
    B, S = prompt.shape
    budget = seq_budget or (S + max_new_tokens)
    state = model.init_decode_state(params, B, budget, frames=frames)
    decode = make_decode_step(model)

    logits = None
    for t in range(S):
        logits, state = decode(params, state, {"tokens": prompt[:, t : t + 1]})
    outs = []
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    done = jnp.zeros((B,), bool)
    for _ in range(max_new_tokens):
        outs.append(tok)
        if eos_id is not None:
            done = done | (tok[:, 0] == eos_id)
            if bool(done.all()):
                break
        logits, state = decode(params, state, {"tokens": tok})
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    return jnp.concatenate(outs, axis=1)
