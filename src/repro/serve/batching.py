"""Request batching for the serving example.

Static batching with padding-to-bucket: requests are grouped into batches of
``batch_size`` with uniform (bucketed) prompt length, each group is prefix-
replayed then decoded greedily. Input for the request prompts flows through
a CkIO read session (requests file = one more "single large file read by a
collection of tasks").
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import Model
from repro.serve.serve_step import greedy_generate


@dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (S,) int32
    max_new_tokens: int = 16
    result: Optional[np.ndarray] = None
    latency_s: float = 0.0


@dataclass
class BatchServer:
    model: Model
    params: Any
    batch_size: int = 4
    bucket: int = 32               # prompts padded up to a multiple of this
    stats: Dict[str, float] = field(default_factory=dict)

    def serve(self, requests: List[Request]) -> List[Request]:
        # bucket by padded length so every batch is uniform
        by_len: Dict[int, List[Request]] = {}
        for r in requests:
            L = max(self.bucket, (len(r.prompt) + self.bucket - 1)
                    // self.bucket * self.bucket)
            by_len.setdefault(L, []).append(r)
        t_all = time.perf_counter()
        for L, group in sorted(by_len.items()):
            for i in range(0, len(group), self.batch_size):
                chunk = group[i : i + self.batch_size]
                t0 = time.perf_counter()
                prompts = np.zeros((len(chunk), L), np.int32)
                for j, r in enumerate(chunk):
                    prompts[j, L - len(r.prompt):] = r.prompt  # left-pad
                max_new = max(r.max_new_tokens for r in chunk)
                out = greedy_generate(
                    self.model, self.params, jnp.asarray(prompts), max_new
                )
                out = np.asarray(out)
                dt = time.perf_counter() - t0
                for j, r in enumerate(chunk):
                    r.result = out[j, : r.max_new_tokens]
                    r.latency_s = dt
        self.stats["total_s"] = time.perf_counter() - t_all
        self.stats["requests"] = float(len(requests))
        return requests
