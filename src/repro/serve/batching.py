"""Request batching: continuous batching under churn + the static baseline.

Three servers over two substrates:

  * :class:`ContinuousBatcher` — the serving subsystem's decode loop: each
    tick polls the :class:`~repro.serve.ingest.RequestIngester`, admits
    ready requests into free engine slots, steps every occupied slot one
    token, and evicts on EOS/max-tokens. No batch formation wait, no
    padding waste: a slot frees the moment its request finishes and the
    next request takes it mid-decode.
  * :class:`StaticBatcher` — the honest baseline on the SAME engine and
    ingester: wait for a full batch (or end of stream), decode until every
    member finishes (finished members keep burning their slot — padding
    waste), return all results at batch end (batch-formation + straggler
    wait land in every member's latency).
  * :class:`BatchServer` — the legacy model-level static server
    (pad-to-bucket + ``greedy_generate``), kept as the example's default
    path. Latency is measured from request *arrival* (``Request.arrival_t``),
    split into ``queue_wait_s`` (arrival -> its batch starts) and
    ``service_s`` (the batch's decode time) — not from batch start, which
    silently hid the queueing component.

Both engine-based batchers follow the shared completion rule of
``serve/engine.py`` (``decode_one``), so their outputs are bit-identical to
the sequential oracle regardless of arrival order, slot assignment, or
co-residency.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.metrics import ServeMetrics
from repro.models.model_zoo import Model
from repro.serve.ingest import RequestIngester, ServeRequest
from repro.serve.serve_step import greedy_generate


@dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (S,) int32
    max_new_tokens: int = 16
    arrival_t: Optional[float] = None   # perf_counter stamp; None = at serve()
    result: Optional[np.ndarray] = None
    latency_s: float = 0.0         # arrival -> response (queueing + service)
    queue_wait_s: float = 0.0      # arrival -> its batch started decoding
    service_s: float = 0.0         # the batch's own decode time


@dataclass
class BatchServer:
    model: Model
    params: Any
    batch_size: int = 4
    bucket: int = 32               # prompts padded up to a multiple of this
    stats: Dict[str, float] = field(default_factory=dict)

    def serve(self, requests: List[Request]) -> List[Request]:
        # bucket by padded length so every batch is uniform
        by_len: Dict[int, List[Request]] = {}
        for r in requests:
            L = max(self.bucket, (len(r.prompt) + self.bucket - 1)
                    // self.bucket * self.bucket)
            by_len.setdefault(L, []).append(r)
        t_all = time.perf_counter()
        for r in requests:
            if r.arrival_t is None:      # legacy callers: arrival = serve()
                r.arrival_t = t_all
        for L, group in sorted(by_len.items()):
            for i in range(0, len(group), self.batch_size):
                chunk = group[i : i + self.batch_size]
                t0 = time.perf_counter()
                prompts = np.zeros((len(chunk), L), np.int32)
                for j, r in enumerate(chunk):
                    prompts[j, L - len(r.prompt):] = r.prompt  # left-pad
                max_new = max(r.max_new_tokens for r in chunk)
                out = greedy_generate(
                    self.model, self.params, jnp.asarray(prompts), max_new
                )
                out = np.asarray(out)
                t_end = time.perf_counter()
                for j, r in enumerate(chunk):
                    r.result = out[j, : r.max_new_tokens]
                    r.queue_wait_s = t0 - r.arrival_t
                    r.service_s = t_end - t0
                    r.latency_s = t_end - r.arrival_t
        self.stats["total_s"] = time.perf_counter() - t_all
        self.stats["requests"] = float(len(requests))
        return requests


def _finished(req: ServeRequest, tok: int) -> bool:
    """The shared completion rule (mirrors ``engine.decode_one``)."""
    return (len(req.result) >= req.max_new_tokens
            or (req.eos_id is not None and tok == req.eos_id))


class ContinuousBatcher:
    """Continuous-batching decode loop (module docstring)."""

    def __init__(
        self,
        engine: Any,
        ingester: RequestIngester,
        metrics: Optional[ServeMetrics] = None,
        *,
        idle_sleep_s: float = 2e-4,
    ):
        self.engine = engine
        self.ingester = ingester
        self.metrics = metrics if metrics is not None else ingester.metrics
        self.metrics.slots = engine.slots
        self.idle_sleep_s = idle_sleep_s
        self._ready: Deque[ServeRequest] = deque()
        self._active: Dict[int, ServeRequest] = {}
        self.completed: List[ServeRequest] = []

    def _admit(self, slot: int, req: ServeRequest) -> None:
        self.engine.admit(slot, req.prompt)
        req.result = []
        req.status = "decoding"
        self._active[slot] = req
        # prompt consumed by the prefill above; drop the borrowed view and
        # hand the session's arena back before decode continues
        self.ingester.release(req)
        self.metrics.record_admission()

    def tick(self) -> bool:
        """One loop iteration: poll ingest, fill free slots, step once,
        evict finished. Returns False when no slot was stepped (idle)."""
        self._ready.extend(self.ingester.poll())
        for slot in range(self.engine.slots):
            if not self._ready:
                break
            if slot not in self._active:
                self._admit(slot, self._ready.popleft())
        if not self._active:
            return False
        toks = self.engine.step()
        self.metrics.record_step(len(toks))
        now = time.perf_counter()
        for slot, tok in toks.items():
            req = self._active[slot]
            if req.t_first_token == 0.0:
                req.t_first_token = now
                self.metrics.record_first_token(now - req.arrival_t)
            req.result.append(int(tok))
            if _finished(req, int(tok)):
                self.engine.evict(slot)
                del self._active[slot]
                req.status = "done"
                req.t_done = now
                self.metrics.record_eviction()
                self.metrics.record_completed(
                    now - req.arrival_t, len(req.result), now)
                self.completed.append(req)
        return True

    def run(
        self,
        pump: Optional[Callable[[], bool]] = None,
        timeout_s: float = 300.0,
    ) -> List[ServeRequest]:
        """Drive ticks until every admitted request completes. ``pump`` is
        the load generator's hook — called once per tick to submit due
        arrivals; it returns True while more arrivals are still to come."""
        deadline = time.perf_counter() + timeout_s
        while True:
            more = bool(pump()) if pump is not None else False
            stepped = self.tick()
            if (not more and not stepped and not self._ready
                    and self.ingester.inflight() == 0):
                break
            if not stepped:
                time.sleep(self.idle_sleep_s)   # waiting on arrivals / I/O
            if time.perf_counter() > deadline:
                raise RuntimeError(
                    f"continuous serve stalled: {len(self._active)} active, "
                    f"{len(self._ready)} ready, "
                    f"{self.ingester.inflight()} in ingest after "
                    f"{timeout_s}s")
        self.ingester.drain_closes()
        return self.completed


class StaticBatcher:
    """Static-batch baseline over the same engine + ingester (module
    docstring): results return only at batch end, finished members keep
    burning their slot until the batch's straggler finishes."""

    def __init__(
        self,
        engine: Any,
        ingester: RequestIngester,
        metrics: Optional[ServeMetrics] = None,
        *,
        batch_size: Optional[int] = None,
        idle_sleep_s: float = 2e-4,
    ):
        self.engine = engine
        self.ingester = ingester
        self.metrics = metrics if metrics is not None else ingester.metrics
        self.metrics.slots = engine.slots
        self.batch_size = batch_size or engine.slots
        self.idle_sleep_s = idle_sleep_s
        self._ready: Deque[ServeRequest] = deque()
        self.completed: List[ServeRequest] = []

    def _fill(self, pump, deadline) -> bool:
        """Batch formation: block until ``batch_size`` requests are ready
        or the stream ends. Returns False when the stream is exhausted."""
        while True:
            more = bool(pump()) if pump is not None else False
            self._ready.extend(self.ingester.poll())
            if len(self._ready) >= self.batch_size:
                return True
            if not more and self.ingester.inflight() == 0:
                return bool(self._ready)
            time.sleep(self.idle_sleep_s)
            if time.perf_counter() > deadline:
                raise RuntimeError("static batch formation stalled")

    def run(
        self,
        pump: Optional[Callable[[], bool]] = None,
        timeout_s: float = 300.0,
    ) -> List[ServeRequest]:
        deadline = time.perf_counter() + timeout_s
        while self._fill(pump, deadline):
            chunk = [self._ready.popleft()
                     for _ in range(min(self.batch_size, len(self._ready)))]
            batch: Dict[int, ServeRequest] = {}
            for slot, req in enumerate(chunk):
                self.engine.admit(slot, req.prompt)
                req.result = []
                req.status = "decoding"
                self.ingester.release(req)
                self.metrics.record_admission()
                batch[slot] = req
            done: set = set()
            while len(done) < len(batch):
                toks = self.engine.step()
                self.metrics.record_step(len(toks))
                now = time.perf_counter()
                for slot, tok in toks.items():
                    if slot in done:
                        continue      # padding waste: slot burns to batch end
                    req = batch[slot]
                    if req.t_first_token == 0.0:
                        req.t_first_token = now
                        self.metrics.record_first_token(
                            now - req.arrival_t)
                    req.result.append(int(tok))
                    if _finished(req, int(tok)):
                        done.add(slot)
                if time.perf_counter() > deadline:
                    raise RuntimeError("static batch decode stalled")
            now = time.perf_counter()
            for slot, req in batch.items():
                self.engine.evict(slot)
                req.status = "done"
                req.t_done = now      # static: results return at batch end
                self.metrics.record_eviction()
                self.metrics.record_completed(
                    now - req.arrival_t, len(req.result), now)
                self.completed.append(req)
        self.ingester.drain_closes()
        return self.completed
