"""Request ingest under session churn: one short-lived CkIO session per
request, with explicit backpressure.

The :class:`RequestIngester` is the serving front door. Each submitted
:class:`ServeRequest` names a prompt span (rows of a token file / FileSet);
the ingester opens a read session for exactly that span, issues one
zero-copy ``read_view``, and surfaces the request as *ready* once the
borrowed view has landed — the millions-of-users regime of the paper's
consumer/reader decoupling: session lifetime shrinks from "the whole
training run" to "one request's queueing time".

Everything is poll-driven and single-threaded (the split-phase idiom):
``submit`` never blocks on I/O, ``poll`` pumps the scheduler, advances
per-request state machines, and returns newly ready requests. The decode
loop calls ``poll`` between steps, so ingest overlaps decode the same way
the paper overlaps read with compute.

Session lifetime per request
----------------------------
    submit -> (queued) -> session open + read_view issued   [ingesting]
           -> view delivered                                 [ready]
           -> decode engine consumes the prompt at admission; the borrowed
              view dies HERE (``release``: refs dropped, session closed,
              arena back to the service pool)                [decoding]
           -> EOS / max-tokens eviction                      [done]

The borrowed prompt view is session-lifetime, NOT slot-lifetime: it is
consumed during ``engine.admit`` and released before decode continues, so
slot eviction never touches CkIO state and a session is open only while
its bytes are actually needed (keeping churn high and arena-pool pressure
low). Nothing may retain ``req.prompt`` past admission — a pinned export
would force the service to quarantine the arena segment instead of
recycling it.

Backpressure: when ``ServeOverloaded`` surfaces vs queues
---------------------------------------------------------
Two triggers, one bounded queue, never a stall of the decode loop:

  * the shared :class:`~repro.ipc.service.ReaderService` raises
    ``ServiceBusy`` (admission caps hit), or
  * inflight ingest bytes (open prompt sessions) would exceed
    ``max_inflight_bytes``.

Either trigger moves the ingester ``open -> queueing``: new submits join a
bounded FIFO (depth ``max_pending``) and are retried on every poll — a
queued request IS admitted and is never dropped. Only when that queue is
full does a *new* submit fail fast with a descriptive
:class:`ServeOverloaded` (``queueing -> shedding``); the caller sees the
rejection synchronously and the decode loop never waits on a saturated
reader tier. Draining the queue walks the states back down
(``shedding -> queueing -> open``); every transition is counted in
:class:`~repro.core.metrics.ServeMetrics`.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, List, Optional, Tuple

import numpy as np

from repro.core.futures import CkFuture
from repro.core.metrics import ServeMetrics
from repro.ipc.service import ServiceBusy


class ServeOverloaded(RuntimeError):
    """The ingest queue is full on top of a saturated reader tier; the
    submit was rejected (NOT admitted). Retry later or scale the service."""


@dataclass
class ServeRequest:
    """One serving request: a prompt span plus decode limits.

    ``file`` optionally overrides the ingester's default handle (e.g. a
    handle opened with fault injection or different recovery options);
    ``arrival_t`` may be preset by a load generator replaying a trace.
    """

    rid: int
    row_start: int
    num_rows: int
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    file: Optional[Any] = None

    # -- runtime (owned by the ingester / batcher) ----------------------------
    status: str = "new"          # new|queued|ingesting|ready|decoding|done|failed
    prompt: Optional[np.ndarray] = None   # borrowed view; dies at admission
    result: Optional[List[int]] = None
    error: Optional[BaseException] = None
    arrival_t: float = 0.0
    t_ingested: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    _offset: int = 0
    _nbytes: int = 0
    _session: Any = field(default=None, repr=False)
    _view_fut: Optional[CkFuture] = field(default=None, repr=False)


class RequestIngester:
    """Admit a stream of requests through short-lived CkIO sessions (module
    docstring has the lifecycle and backpressure contracts)."""

    def __init__(
        self,
        ck: Any,
        file: Any,
        meta: Any,                       # TokenFileMeta / FileSet surface
        metrics: Optional[ServeMetrics] = None,
        *,
        max_pending: int = 64,
        max_inflight_bytes: int = 256 << 20,
        service: Any = None,
        start_timeout_s: float = 60.0,
    ):
        self.ck = ck
        self.file = file
        self.meta = meta
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.max_pending = max_pending
        self.max_inflight_bytes = max_inflight_bytes
        self.start_timeout_s = start_timeout_s
        self._queued: Deque[ServeRequest] = deque()
        self._ingesting: List[ServeRequest] = []
        self._closing: List[Tuple[CkFuture, int]] = []
        self._inflight_bytes = 0
        self.failed: List[ServeRequest] = []
        self._service = service
        if service is not None:
            import threading

            self.capacity_event = threading.Event()
            service.add_capacity_listener(self.capacity_event.set)
        else:
            self.capacity_event = None

    # -- admission -------------------------------------------------------------
    def submit(self, req: ServeRequest) -> ServeRequest:
        """Admit ``req`` (start its ingest session now, or queue it under
        backpressure). Raises :class:`ServeOverloaded` — and does NOT admit
        — when the bounded queue is already full."""
        now = time.perf_counter()
        if req.arrival_t == 0.0:
            req.arrival_t = now
        self.metrics.record_submitted(now)
        req._offset, req._nbytes = self.meta.byte_range_for_rows(
            req.row_start, req.num_rows)
        # FIFO fairness: never let a fresh submit overtake the queue.
        if not self._queued and self._try_start(req):
            self.metrics.record_accepted()
            return req
        if len(self._queued) >= self.max_pending:
            self.metrics.record_shed()
            self.metrics.set_state("shedding")
            raise ServeOverloaded(
                f"request {req.rid} shed: ingest queue full at "
                f"{self.max_pending} on top of a saturated reader tier "
                f"({self._inflight_bytes} inflight ingest bytes, budget "
                f"{self.max_inflight_bytes}); retry later, raise "
                f"max_pending/max_inflight_bytes, or scale the service")
        req.status = "queued"
        self._queued.append(req)
        self.metrics.record_accepted()
        self.metrics.record_queue_depth(len(self._queued))
        self.metrics.set_state(
            "shedding" if len(self._queued) >= self.max_pending
            else "queueing")
        return req

    def _try_start(self, req: ServeRequest) -> bool:
        """Open ``req``'s session + issue its zero-copy read. ``False`` =
        backpressured (budget or ServiceBusy) — the caller queues/keeps it."""
        if self._inflight_bytes + req._nbytes > self.max_inflight_bytes:
            self.metrics.record_over_budget()
            return False
        if self._service is not None:
            # only start sessions the service can RUN immediately: a start
            # that lands in the service's own wait queue blocks the sync
            # call (and this poll loop) until some other session ends —
            # the ingester's bounded queue is the one waiting room
            snap = self._service.admission_snapshot()
            if snap["inflight"] >= snap["max_sessions"]:
                self.metrics.record_busy()
                return False
        fh = req.file if req.file is not None else self.file
        try:
            sess = self.ck.start_read_session_sync(
                fh, req._nbytes, req._offset, timeout=self.start_timeout_s)
        except ServiceBusy:
            self.metrics.record_busy()
            return False
        req._session = sess
        req._view_fut = self.ck.read_view_future(
            sess, req._nbytes, req._offset)
        req.status = "ingesting"
        self._ingesting.append(req)
        self._inflight_bytes += req._nbytes
        self.metrics.record_inflight_bytes(self._inflight_bytes)
        return True

    # -- the poll loop ---------------------------------------------------------
    def poll(self) -> List[ServeRequest]:
        """Advance every in-flight ingest; returns newly *ready* requests
        (prompt view delivered). Non-blocking."""
        while self._queued:
            if not self._try_start(self._queued[0]):
                break
            self._queued.popleft()
        self.ck.pump()
        ready: List[ServeRequest] = []
        still: List[ServeRequest] = []
        for req in self._ingesting:
            fut = req._view_fut
            if not fut.done:
                still.append(req)
                continue
            try:
                msg = fut.value()
            except BaseException as e:  # terminal (recovery already ran/off)
                req.status = "failed"
                req.error = e
                self.metrics.record_failed()
                self.failed.append(req)
                self.release(req)
                continue
            req.prompt = np.frombuffer(msg.data, dtype=self.meta.dtype)
            req.status = "ready"
            req.t_ingested = time.perf_counter()
            self.metrics.record_ingested(req.t_ingested - req.arrival_t)
            ready.append(req)
        self._ingesting = still
        self._closing = [c for c in self._closing if not self._reap_close(c)]
        # walk the backpressure state back down as the queue drains
        if self._queued:
            self.metrics.set_state(
                "shedding" if len(self._queued) >= self.max_pending
                else "queueing")
        else:
            self.metrics.set_state("open")
        return ready

    def _reap_close(self, entry: Tuple[CkFuture, int]) -> bool:
        fut, nbytes = entry
        if not fut.done:
            return False
        try:
            fut.value()
        except BaseException:
            pass                     # close errors already surfaced elsewhere
        self._inflight_bytes -= nbytes
        return True

    # -- hand-off --------------------------------------------------------------
    def release(self, req: ServeRequest) -> None:
        """Drop the request's borrowed view and close its session (async;
        the arena returns to the pool un-quarantined because no export
        outlives this call). Idempotent."""
        req.prompt = None            # the only live export of the view
        req._view_fut = None
        sess, req._session = req._session, None
        if sess is None:
            return
        f: CkFuture = CkFuture()
        self.ck.close_read_session(sess, f)
        self._closing.append((f, req._nbytes))

    # -- draining --------------------------------------------------------------
    def inflight(self) -> int:
        """Requests admitted but not yet handed off (queued + ingesting)."""
        return len(self._queued) + len(self._ingesting)

    def drain_closes(self, timeout: float = 30.0) -> None:
        """Pump until every async session close has retired (shutdown path:
        nothing may be left holding a pooled arena)."""
        deadline = time.perf_counter() + timeout
        while self._closing and time.perf_counter() < deadline:
            self.ck.pump()
            self._closing = [
                c for c in self._closing if not self._reap_close(c)]
