"""CkIO-backed training input pipeline — the "ChaNGa integration" analog.

Over-decomposed consumers (feeder clients, many per PE) collectively read each
training step's token window through a CkIO read session, while the device
runs the previous step: a double-buffered, split-phase pipeline that
implements the paper's compute/input overlap at the training-loop level.

Key structural mirror of the paper:
  * consumer count (`num_consumers`) is chosen by the *application* (here:
    microbatch×prefetch structure), completely decoupled from `num_readers`
    (chosen for the file system) — paper §III-B.
  * one read session per step window, prefetched greedily (paper §III-A:
    "read the file chunk-by-chunk (one chunk per session)").
  * consumers are migratable; `resize()` implements elastic scaling by
    re-registering consumers, leaving the reader layer untouched; shrunk
    consumers are deregistered from the location manager (no leaked ids).

Delivery modes:
  * ``zero_copy=True`` (default): consumer reads ride the borrowed-view path
    (``read(dest=None)``) and ``get_batch`` materializes the step's tokens as
    a NumPy array *aliasing the session arena* — zero host copies between the
    preadv into the arena and ``device_put``.
  * ``zero_copy=False``: consumer reads land directly in a per-step NumPy
    arena (one copy, session arena → step arena), with no lifetime caveat.

Device ingest (``get_batch_device``) and its lifetime contract
--------------------------------------------------------------
``get_batch_device(step)`` replaces the host tail of the pipeline: the
borrowed **whole-window arena view** is handed to ``jax.device_put`` exactly
once (the step's only host→device transfer), and batch-major ``(inputs,
labels)`` — including the label shift-by-one and remainder-window padding —
are produced **on device** by the ``kernels/reassemble.py`` gather kernels
(the paper's phase-2 data permutation, moved to where bandwidth is
cheapest). Per step, host code touches file *metadata* only; the
``ingest`` counters (``core.metrics.IngestMetrics``) prove it:
``host_permute_bytes`` stays 0 and ``h2d_transfers`` advances by exactly 1.
(With ``zero_copy=False`` the session→step-arena copy still happens and is
counted as host bytes — only the zero-copy default earns the 0.)

Streamed staging (``streaming=True``)
-------------------------------------
With ``streaming=True`` the device path goes **event-driven**: the pipeline
subscribes to each step session's per-splinter completion stream
(``CkIO.read_stream``) and ships splinters host→device *as they arrive* —
**one ``device_put`` per splinter** (uniform splinter sizes keep the chunk
shapes, and with them the fused consume executable's signature, stable
across steps and arrival permutations; ``h2d_transfers`` advances once per
splinter). ``stage_chunk_bytes`` only batches event-task wakeups: staging
work runs once at least that many bytes are pending (0 = ship on every
event). Transfers respect a bounded in-flight budget
(``max_inflight_stage_bytes``: before exceeding it, the oldest outstanding
transfer — from whichever step stream issued it — is awaited).
``get_batch_device`` then only stages the tail and
reassembles **on device** in one fused dispatch: the arrival-order→
file-order permutation is applied to the chunk *handles* (each splinter is
its own device buffer, so reordering the argument list is free host
metadata work), and ``ops.ingest_chunks_window`` fuses the concatenate with
the batch-major window kernel. A contiguous arrival-ordered staging buffer
— the multi-host/TPU layout — keeps its on-device gather path:
``ops.ingest_chunks_block`` / ``ops.device_ingest`` over the
``data/packing.py`` index maps. Reads for step N+1, H2D staging for step N's tail,
and compute on step N-1 genuinely overlap; ``StreamMetrics``
(``pipe.stream``) proves it — per-splinter arrival→staged latency,
in-flight high-water mark, and the overlap fraction. ``host_permute_bytes``
stays 0 (every staged byte goes straight from the session arena into
``device_put``); ``h2d_transfers`` counts one per chunk. Completeness never
depends on the stream: splinters whose events were dropped (a delivery
racing ``resize()`` — dropped and counted, never rerouted to a reused
consumer slot) are staged from the authoritative event log at finalize.
Batches are bit-identical to the ``streaming=False`` whole-window path.

Sharded staging (constructor ``sharding=``)
-------------------------------------------
A **constructor** ``sharding=`` (any ``jax.sharding.Sharding``) composes
with both device paths instead of fighting them: the sharding's device
blocks over the ``(global_batch, seq_len+1)`` window grid are resolved
ONCE into contiguous flat-token spans (``device_token_spans`` — batch-dim
shardings only; a sharding that splits the sequence dimension raises at
construction). With ``streaming=True`` every arriving splinter is then
routed to its destination device(s) at stage time by pure interval
intersection: each addressable sub-slice is ``device_put`` straight from
the borrowed arena view onto its device (``host_permute_bytes`` stays 0),
and spans owned by another host's devices are *counted*
(``ShardMetrics.cross_host``) and skipped — each host stages exactly its
addressable slice of the window, never the whole window.
``get_batch_device`` then proves coverage from the event log, pads the
remainder tail on-device, binds the per-device row blocks into one global
array with ``jax.make_array_from_single_device_arrays`` (metadata only —
no restage) and applies the label shift under ``jit``. Batches are
bit-identical to the unsharded paths. ``streaming=False`` +
constructor sharding runs the same per-device slicing over the resident
whole-window view (one ``device_put`` per addressable device) through the
same assembly code. A **per-call** ``sharding`` without a constructor
sharding keeps the legacy behaviour: it forces that call onto the
whole-window path — streamed chunks are placed before the call-site
sharding is known, so they cannot satisfy it — and the fallback is
explicit: the first sharded call on a streaming pipeline emits a
``RuntimeWarning`` (once per pipeline) because it forfeits the read/stage
overlap on every sharded step; a run that passes a sharding each step
should pass it at construction time (or construct ``streaming=False``).
``ShardMetrics`` (``pipe.ck.director.shards``) carries both sides of the
ledger: the *read* side (per-shard physical bytes, fed through the
Director's session-close observer) and the *stage* side
(``record_stage``/``record_window``/``record_cross_host`` written here) —
``addressable_bytes < window_bytes`` with ``cross_host_placements > 0``
is the multi-host proof that no host staged bytes it cannot address.

Multi-file corpora (``data/fileset.py`` ``FileSet``)
----------------------------------------------------
Passing a ``FileSet`` manifest as ``path`` opens the whole shard list as
ONE logical byte space (``CkIO.open_fileset``): global row/byte
addressing concatenates the shards' data regions (header pages excluded),
and interior shard starts become hard stripe bounds in every session plan
— no stripe, splinter, or single ``preadv`` ever spans two files. Every
delivery contract in this docstring holds unchanged over a FileSet:

  * **view lifetimes**: a borrowed view or streamed chunk view aliases
    bytes read from exactly one shard (splinters never cross shards) but
    lives in the one session arena, so the lifetime rules are untouched —
    valid until the step retires at the next ``get_batch*``/``close``,
    then ``ValueError`` on access;
  * **process backend**: each ``WorkerSpec`` ships the shard segment
    table and the worker rebuilds its OWN ``ShardedFile`` — one fresh fd
    per shard path, nothing inherited (the same fd-hygiene contract as
    single files);
  * **recovery**: if the worker owning one shard's stripes dies mid-drain
    under ``recovery="respawn"``/``"reissue"``, the standard machinery
    re-reads exactly the unfinished splinters — all within that shard —
    and ``RecoveryMetrics.reissued_bytes_by_shard`` attributes the
    re-read bytes to it (exact, not sampled, because splinters never span
    shards). Completion stays bit-identical; terminal failures behave
    exactly as the single-file contract above;
  * **sharded streaming composes**: per-shard physical reads land in the
    same global window token space, so chunk→device routing and the
    staged-bytes ledger are file-count agnostic.
Note on ``FileOptions(adaptive_splinters=True)``: each splinter-size
change changes the chunk count/shape signature and retraces the fused
consume executable once; the sizer EMA-smooths and 256 KiB-quantizes its
suggestions so sizes converge after the first few sessions, but a
latency-critical run should pin ``splinter_bytes`` statically.

Persistent reader service (constructor ``service=``)
----------------------------------------------------
Passing a ``repro.ipc.service.ReaderService`` attaches it to this
pipeline's Director: every ``backend="process"`` step session then checks
its workers out of the service's persistent pool and its arena out of the
recycled-arena pool instead of spawning processes and creating a fresh
shm segment per step — the per-step session setup drops from worker-spawn
cost (~0.5 s/worker) to one mailbox write + attach barrier
(``benchmarks/perf_service.py`` gates the ratio at >= 5x). Every delivery
contract above holds unchanged (the pooled arena is the same kind of
mapped segment, so zero-copy borrowed views, streamed chunk staging and
``bytes_copied == 0`` are untouched), with these service-specific
amendments:

  * **View lifetime across arena recycling**: borrowed views still die at
    step retirement (``ValueError`` on access), but the pages behind them
    now outlive the session — the segment returns to the pool and is
    recycled into a later session. A view that survives invalidation via
    a live buffer export (an ``np.frombuffer`` array you kept) therefore
    QUARANTINES the segment: the service unlinks it instead of recycling,
    so the export can never silently alias a later step's bytes. Code
    that caches views across sessions can re-validate explicitly with
    ``SharedArena.check_generation(gen)`` (raises ``StaleArenaView``);
    the generation a session ran under is
    ``session.metrics.summary()["service_epoch"]``-adjacent bookkeeping
    on the reader set (``ServiceReaderSet.arena_generation``).
  * **When ``ServiceBusy`` is raised**: admission rejects a session only
    when BOTH the inflight cap (``ServiceOptions.max_sessions``) and the
    FIFO queue (``max_queue``) are full. With ``FileOptions.use_service``
    left at auto (``None``) the Director catches it and falls back to the
    legacy per-session spawn path — the step still runs, it just pays the
    spawn; ``use_service=True`` pins the step to the pool and surfaces
    ``ServiceBusy`` out of the step's futures instead. ``use_service=
    False`` (or simply not attaching a service) keeps the legacy path
    unconditionally.
  * **Degraded fallback to spawn** is per session and non-sticky —
    unlike the ``fallback_backend="thread"`` downgrade, a later step
    re-tries the pool as soon as admission has room.
  * **Failure containment**: a pooled worker crash evicts that worker
    only; the affected step recovers per its own ``FileOptions.recovery``
    (or fails alone) and concurrently running steps/pipelines sharing the
    pool are untouched.
  * **Ownership**: the pipeline never shuts the service down — call
    ``service.shutdown()`` after the last pipeline using it closes
    (``/dev/shm`` is clean only after that).

Serving ingest (``repro.serve`` — the other session-lifetime regime)
--------------------------------------------------------------------
This pipeline is the paper's TRAINING shape: a handful of long-lived
sessions, each spanning a whole step window. The serving subsystem
(``src/repro/serve/``) drives the same CkIO surface from the opposite
end: thousands of short-lived sessions per second, one per request,
each covering only that request's prompt rows of the corpus/FileSet.
The contracts compose rather than fork:

  * **session lifetime per request**: a request's session is opened by
    the ``RequestIngester`` at admission, carries exactly one zero-copy
    ``read_view``, and closes the moment the decode engine has consumed
    the prompt (``engine.admit``) — it never lives past batching, so the
    arena-pool pressure of N inflight requests is N prompt spans, not N
    windows. The borrowed-view lifetime rule is identical to this
    pipeline's: no export may outlive the session, or the pooled segment
    quarantines instead of recycling.
  * **slot eviction is not a CkIO event**: by the time a request decodes
    in a slot its session is already closed; EOS/max-token eviction
    (``ContinuousBatcher``) touches engine state only.
  * **backpressure replaces fallback**: where a training step under
    ``use_service`` auto mode degrades a ``ServiceBusy`` to per-session
    spawn, the serving path *queues* the request (bounded FIFO in the
    ingester) and — only when that queue is also full — rejects the
    submit with ``ServeOverloaded``. An admitted request is never
    dropped; see ``serve/ingest.py`` for the state machine and
    ``core.metrics.ServeMetrics`` (on the same Director observer path as
    every sink above) for the histograms that prove the tail.

Cold-cache reads (``direct_io`` / ``queue_depth`` — io/submit.py)
-----------------------------------------------------------------
First-epoch corpora are COLD: nothing below survives in the page cache,
and the blocking one-pread-per-splinter loop leaves the device idle
between requests. Two ``FileOptions`` knobs change the read engine under
this pipeline without touching any delivery contract above:

  * ``queue_depth >= 2`` keeps that many splinter reads in flight per
    reader (io_uring where the kernel allows, else a preadv pool with
    WILLNEED pipelining; ``readahead_bytes`` advises ahead of the
    submission frontier). Splinters complete — and stream, under
    ``streaming=True`` — in completion order, which the event-driven
    staging path was built for; borrowed views, bit-identity, retry
    accounting and fault hooks are unchanged.
  * ``direct_io=True`` opens the corpus O_DIRECT: reads DMA straight into
    the session arena, bypassing the page cache (the right mode when the
    corpus is read once and would only pollute it). The contract is
    *fail-fast, never fall back silently*: session windows, splinter grid
    and arena must sit on the probed FS block grid (the Director plans
    with ``align=block_size`` automatically; odd session offsets raise
    ``DirectIOError`` at start), sub-block tails go through the buffered
    fd and are counted (``RecoveryMetrics.direct_tail_reads``), and a
    FileSet needs block-aligned shard data regions — an odd-sized
    interior shard is rejected at open, by name.
``adaptive_queue=True`` hands both knobs to the Director's QueueTuner
(core/autotune.py), which hill-climbs (depth, readahead) from observed
session throughput across steps; the explicit fields seed session one.

Topology-aware reader runtime (``FileOptions.topology`` / ``numa_pin``)
-----------------------------------------------------------------------
Passing a ``core.placement.Topology`` in ``file_opts`` turns on the NUMA
levers under this pipeline (``launch/train.py`` exposes them as
``--topology`` — ``auto`` detects the host's NUMA nodes from sysfs, an
integer gives domains-per-node — and ``--numa-pin``):

* **reader placement** sees memory domains: ``placement="near_consumers"``
  spreads readers over the PEs of the consumers' NUMA domains (this
  pipeline passes its consumers' PEs to every session), and
  ``placement="domain_spread"`` puts one reader per domain before doubling
  up. ``consumer_pes=[...]`` pins this pipeline's consumer clients to
  specific PEs (default: round-robin over all PEs) — the lever for
  skewed-consumer locality studies.
* **first-touch arena contract**: with a topology, ``prefault_arena=True``
  no longer zero-fills the session arena up front — instead each reader
  I/O thread faults exactly its own stripe's pages (one byte per page) on
  its own thread before its first read, with ``numa_pin=True`` pinning
  that thread to its domain's host CPUs first. Under Linux first-touch,
  every stripe's memory therefore lands on the domain that reads and
  serves it; the ``np.empty`` arena stays non-zero-filled (no memset pass
  on the session-start critical path), and stolen splinters land in
  already-placed pages. Zero-copy delivery is unchanged: borrowed views
  alias the same arena; ``bytes_copied`` stays 0.
* **accounting**: pieces coalesce per NUMA domain and every delivered
  piece is classified same- vs cross-domain in ``LocalityMetrics``
  (per-session, merged into ``pipe.ck.director.locality`` as step sessions
  close) — ``benchmarks/perf_numa.py`` gates on cross-domain bytes
  dropping under NUMA-aware placement with bit-identical batches.

Multi-process reader backend (``FileOptions(backend="process")``)
-----------------------------------------------------------------
With ``backend="process"`` each step session's arena is a **shared-memory
segment** (``src/repro/ipc/shm.py``) filled by real reader worker
processes (``preadv`` directly into the mapping) and consumed here through
the very same borrowed-view machinery — every mode above (host zero-copy,
device ingest, streamed staging) works unchanged, with splinter events
arriving over cross-process rings instead of in-process callbacks.
``bytes_copied`` stays 0 *in this consumer process*: the views ``np``
arrays and staged chunks alias are the mapped segment itself.

Shm view lifetime and failure-semantics contract (the cross-process
sharpening of the rules below):

  * a borrowed view into the shm arena is valid until **its session
    closes**, exactly like the thread backend — session close releases
    the view and unmaps the segment (pages a staged transfer still pins
    survive until that exporter is dropped at the next ``get_batch*``);
  * worker processes never inherit fds: each opens the data file and the
    shm segments by name (``io/posix.py`` fd-hygiene notes).

Worker death now splits into **recoverable** and **terminal** (see
``FileOptions.recovery`` and ``core.buffers.ProcessReaderSet``):

  * **recoverable** (``recovery="respawn"`` or ``"reissue"``, post-gate
    crash/hang within budget): the failure is *invisible* at this layer.
    A replacement worker attaches to the **same arena mapping** (respawn)
    or the supervisor re-reads the unfinished tail in-process (reissue),
    so the session completes bit-identically: borrowed views and staged
    chunks handed out before the crash stay valid (same pages),
    ``bytes_copied`` stays 0, and splinter subscriptions observe **each
    splinter exactly once** — arrival *order* may change (the recovered
    tail lands late) but replay/barrier semantics and the
    arrival-order→file-order device reassembly are order-agnostic by
    construction. Recovery is visible only in
    ``session.metrics.recovery`` (respawns, re-issued splinters/bytes,
    recovery latency);
  * **terminal** (default ``recovery="none"``, respawn budget exhausted,
    or an attach-phase death — the placement barrier cannot re-run): a
    descriptive ``WorkerCrashed`` is raised from every blocked
    ``read``/``get_batch*`` call within the supervisor's poll interval —
    no hang, no partial delivery. The failed session is unusable: its
    borrowed views die at session close as usual, staged chunks of the
    failed step are dropped when the pipeline retires it, and
    subscriptions receive no further events. A *new* session has a *new*
    mapping, so never hold views across a terminal failure — re-read
    through the new session (``train/fault.py`` StepSupervisor does
    exactly this: ``WorkerCrashed`` from the batch path counts as a step
    failure, the optional ``input_recover`` hook rebuilds the pipeline,
    and the step replays from the last checkpoint);
  * **degraded mode** (``fallback_backend="thread"``): a process-backend
    *setup* failure (spawn/shm errors) rebuilds the session on the
    in-process thread backend instead of raising — one ``RuntimeWarning``
    per FileOptions, ``metrics.recovery.degraded_mode`` set, every
    delivery contract above unchanged (the thread backend shares the
    borrowed-view machinery).

Lifetime rules:
  * the returned ``(inputs, labels)`` are ordinary JAX device arrays — they
    own their storage and stay valid as long as the caller holds them;
  * the *staged host view* (the borrowed arena view fed to ``device_put``)
    and its session stay alive until the **next** ``get_batch*``/``close``
    call. At that point the pipeline blocks on the staged transfer, drops
    its host references and retires the session — any access to the old
    borrowed view afterwards raises ``ValueError`` (never a silent read of
    recycled arena memory);
  * **streamed chunk views**: each staged chunk's borrowed arena view is
    pinned from the moment it is handed to ``device_put`` (mid-read, while
    the session is still filling) until its step retires — i.e. valid until
    staged, then until the next ``get_batch*``/``close`` call, at which
    point the pipeline blocks on the step's transfers and releases every
    chunk view along with the session (same use-after-retire ``ValueError``
    guarantee);
  * host-path ``get_batch`` keeps its PR-1 contract: the returned arrays
    alias the session arena and are valid until the next
    ``get_batch*``/``close`` call.
"""
from __future__ import annotations

import threading
import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core import CkIO, Client, FileOptions, Session, WorkerCrashed
from repro.core.buffers import SplinterEvent
from repro.core.futures import CkCallback, CkFuture
from repro.core.metrics import IngestMetrics, StreamMetrics
from repro.data.packing import batch_from_tokens, window_rows
from repro.data.tokenfile import read_meta


def device_token_spans(indices_map, global_batch: int, width: int) -> Dict:
    """Resolve a sharding's ``devices_indices_map`` over the ``(batch,
    width)`` window grid into contiguous flat-token spans.

    Returns ``{device: (tok_start, tok_end)}`` in the window's flat token
    space. Raises ``ValueError`` unless every device block is a contiguous
    row range × the FULL width — the only layouts whose blocks are
    contiguous token spans, which is what lets an arriving chunk be routed
    to its destination device(s) by pure interval intersection (no host
    permutation). Pure function of the plain ``{device: (row_slice,
    col_slice)}`` map — unit-testable with fake multi-device maps, no jax
    required.
    """
    spans: Dict = {}
    for dev, idx in indices_map.items():
        if len(idx) != 2:
            raise ValueError(
                f"sharded pipeline expects a 2-d (batch, seq+1) sharding; "
                f"device {dev} has a {len(idx)}-d index")
        rows, cols = idx
        r0, r1, rstep = rows.indices(global_batch)
        c0, c1, cstep = cols.indices(width)
        if rstep != 1 or cstep != 1:
            raise ValueError(
                f"sharded pipeline needs unit-stride device blocks; "
                f"device {dev} has strides ({rstep}, {cstep})")
        if (c0, c1) != (0, width):
            raise ValueError(
                f"sharding splits the sequence dimension (device {dev} "
                f"covers columns [{c0},{c1}) of {width}); only batch-dim "
                f"shardings map to contiguous token spans")
        spans[dev] = (r0 * width, max(r0, r1) * width)
    return spans


@dataclass
class _StreamState:
    """Per-step streamed-staging state (``streaming=True`` device path)."""

    session: Optional[Session] = None
    token: Optional[int] = None            # read_stream subscription token
    pending: List[SplinterEvent] = field(default_factory=list)
    events: List[SplinterEvent] = field(default_factory=list)  # staged order
    chunks: List[object] = field(default_factory=list)         # device arrays
    chunk_hosts: List[tuple] = field(default_factory=list)     # (np, view)
    t_first_stage: float = 0.0
    t_last_stage: float = 0.0
    stagers: int = 0                       # _stage_group calls in flight
    retired: bool = False
    # Constructor-sharding mode: chunks are routed per device span at stage
    # time; abs_off anchors event offsets in the window's token space and
    # dev_pieces collects {device: [(tok_start, device_chunk), ...]}.
    sharded: bool = False
    abs_off: int = 0
    dev_pieces: Dict = field(default_factory=dict)


@dataclass
class _StepBuffer:
    step: int
    abs_off: int = 0
    nbytes: int = 0
    num_rows: int = 0                  # actual rows (< full for remainder)
    session: Optional[Session] = None
    arena: Optional[np.ndarray] = None
    outstanding: int = 0
    stream: Optional[_StreamState] = None
    ready: CkFuture = field(default_factory=CkFuture)


@dataclass
class _StagedStep:
    """Host-side references pinning one device-ingested step (see module
    docstring lifetime rules): released by the next ``get_batch*``."""

    staged: object                     # jax.Array (whole-window tokens)
    host_tokens: object                # np view(s) aliasing the arena
    host_view: Optional[memoryview]    # the borrowed arena view


class CkIOPipeline:
    """Double-buffered LM batch pipeline over a flat token file."""

    def __init__(
        self,
        path,
        global_batch: int,
        seq_len: int,
        *,
        ckio: Optional[CkIO] = None,
        num_pes: int = 4,
        num_consumers: Optional[int] = None,
        consumer_pes: Optional[List[int]] = None,
        file_opts: Optional[FileOptions] = None,
        service=None,
        prefetch_depth: int = 2,
        start_step: int = 0,
        drop_remainder: bool = True,
        zero_copy: bool = True,
        streaming: bool = False,
        sharding=None,
        stage_chunk_bytes: int = 0,
        max_inflight_stage_bytes: int = 32 << 20,
        pad_id: int = 0,
    ):
        # ``path``: a filesystem path (single token file) or a
        # ``data.fileset.FileSet`` manifest (duck-typed — it carries the
        # same meta surface with ``data_offset == 0``, so every offset in
        # this pipeline is a global data-space byte either way).
        is_fileset = hasattr(path, "sharded_file")
        self.meta = path if is_fileset else read_meta(path)
        if len(self.meta.shape) != 1:
            raise ValueError("LM pipeline expects a flat token file")
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.ck = ckio or CkIO(num_pes=num_pes)
        self.file_opts = file_opts or FileOptions()
        # Persistent reader service (ipc/service.py): attach BEFORE any
        # step session starts so every process-backend session checks its
        # workers/arena out of the pool. The caller keeps ownership of the
        # service (and its shutdown) — pipelines, like sessions, come and
        # go faster than the pool they share.
        if service is not None:
            self.ck.director.attach_service(service)
        if is_fileset:
            self.file = self.ck.open_fileset_sync(path, self.file_opts)
        else:
            self.file = self.ck.open_sync(path, self.file_opts)
        self.prefetch_depth = max(1, prefetch_depth)
        self.drop_remainder = drop_remainder
        self.pad_id = pad_id
        rows_per_step = global_batch * (seq_len + 1)
        self.num_steps = self.meta.num_rows // rows_per_step
        if not drop_remainder and self.meta.num_rows % rows_per_step:
            self.num_steps += 1
        # Over-decomposition: consumers default to 4 per PE (paper: apps
        # commonly run 16+ objects/core; tunable independently of readers).
        self.num_consumers = num_consumers or 4 * self.ck.sched.num_pes
        # consumer_pes pins the consumer clients to specific PEs (cycled)
        # instead of round-robin over every PE — skewed-consumer layouts
        # for NUMA locality studies (near_consumers placement then keeps
        # readers on the consumers' memory domains).
        if consumer_pes:
            bad = [p for p in consumer_pes
                   if not 0 <= p < self.ck.sched.num_pes]
            if bad:
                raise ValueError(
                    f"consumer_pes {bad} out of range "
                    f"[0,{self.ck.sched.num_pes})")
            pe_of = lambda i: consumer_pes[i % len(consumer_pes)]  # noqa: E731
        else:
            pe_of = lambda i: i % self.ck.sched.num_pes            # noqa: E731
        self._consumer_pe_of = pe_of
        self.consumers: List[Client] = [
            self.ck.make_client(pe=pe_of(i))
            for i in range(self.num_consumers)
        ]
        self.zero_copy = zero_copy
        if streaming and not zero_copy:
            raise ValueError(
                "streaming=True stages borrowed arena views and requires "
                "zero_copy=True")
        if streaming and self.file_opts.splinter_bytes % self.meta.itemsize:
            # Fail fast: streamed staging views each splinter's bytes as
            # whole tokens; a misaligned size would otherwise surface as an
            # opaque np.frombuffer error inside a scheduler task. (The
            # whole-window path views the full window and doesn't care.)
            raise ValueError(
                f"streaming=True requires splinter_bytes "
                f"({self.file_opts.splinter_bytes}) to be a multiple of the "
                f"token itemsize ({self.meta.itemsize})")
        self.streaming = streaming
        # Constructor sharding: resolve the device blocks over the (B, S+1)
        # window grid into contiguous token spans ONCE (ValueError unless
        # the sharding is batch-dim only). Per-chunk routing at stage time
        # is then pure interval intersection against these spans.
        self.sharding = sharding
        self._dev_spans: Optional[Dict] = None
        self._addr_devices = frozenset()
        self._shift_fn = None
        if sharding is not None:
            self._dev_spans = device_token_spans(
                sharding.devices_indices_map((global_batch, seq_len + 1)),
                global_batch, seq_len + 1)
            self._addr_devices = frozenset(sharding.addressable_devices)
        # 0 (default) ships every splinter the moment its event lands —
        # maximum overlap; a larger threshold batches pending arrivals into
        # fewer staging tasks (the tail is always shipped at finalize).
        self.stage_chunk_bytes = max(1, stage_chunk_bytes)
        self.max_inflight_stage_bytes = max(
            self.stage_chunk_bytes, max_inflight_stage_bytes)
        self.ingest = IngestMetrics()
        self.stream = StreamMetrics()
        self._warned_stream_sharding = False
        self._t_last_step = time.perf_counter()
        self._bufs: Dict[int, _StepBuffer] = {}
        self._retired: List[Session] = []   # zero-copy sessions pending close
        self._staged: List[_StagedStep] = []  # device steps pending release
        # Staged-but-not-awaited transfers across *all* step streams
        # (st, chunk, nbytes): the in-flight budget is global (prefetched
        # steps stage concurrently), so eviction must be too.
        self._stage_outstanding: Deque[tuple] = deque()
        # Condition, not bare Lock: _finalize_stream waits on it for
        # concurrent _stage_group calls (multi-threaded pumps) to drain.
        self._lock = threading.Condition()
        self._next_step = start_step
        for s in range(start_step, min(start_step + self.prefetch_depth, self.num_steps)):
            self.start_step(s)

    # -- elastic scaling -------------------------------------------------------
    def resize(self, num_consumers: int) -> None:
        """Elastically change the consumer decomposition (readers untouched)."""
        cur = len(self.consumers)
        if num_consumers > cur:
            self.consumers.extend(
                self.ck.make_client(pe=self._consumer_pe_of(i))
                for i in range(cur, num_consumers)
            )
        else:
            # Deregister before dropping: a shrunk consumer must not stay in
            # the migration manager's table (shrink→grow cycles would leak
            # one registered id per dropped consumer).
            for c in self.consumers[num_consumers:]:
                c.deregister()
            del self.consumers[num_consumers:]
        self.num_consumers = num_consumers

    def migrate_consumer(self, idx: int, new_pe: int) -> None:
        self.consumers[idx].migrate(new_pe)

    def reset_stream_metrics(self) -> StreamMetrics:
        """Open a fresh ``StreamMetrics`` window (e.g. after benchmark
        warmup) and return the old one. The in-flight balance carries over:
        transfers already issued by subscribed prefetch streams will retire
        against the new object, so a plain ``pipe.stream = StreamMetrics()``
        swap would drive its ``inflight_bytes`` negative and understate the
        high-water mark. Also restarts the step-time clock."""
        with self._lock:
            old, new = self.stream, StreamMetrics()
            new.inflight_bytes = old.inflight_bytes
            new.inflight_bytes_hwm = old.inflight_bytes
            self.stream = new
            self._t_last_step = time.perf_counter()
        return old

    # -- split-phase step input --------------------------------------------------
    def start_step(self, step: int) -> None:
        """Kick off the read session + consumer reads for ``step`` (async)."""
        with self._lock:
            if step in self._bufs or step >= self.num_steps:
                return
            buf = _StepBuffer(step=step)
            self._bufs[step] = buf

        start_row, num_rows = window_rows(step, self.global_batch, self.seq_len)
        # Remainder final window (drop_remainder=False): clamp to the file.
        num_rows = min(num_rows, self.meta.num_rows - start_row)
        abs_off, nbytes = self.meta.byte_range_for_rows(start_row, num_rows)
        buf.abs_off, buf.nbytes, buf.num_rows = abs_off, nbytes, num_rows
        mv: Optional[memoryview] = None
        if not self.zero_copy:
            buf.arena = np.empty(num_rows, dtype=self.meta.dtype)
            mv = memoryview(buf.arena).cast("B")

        def on_session(session: Session) -> None:
            buf.session = session
            if self.streaming:
                # Event-driven mode: the splinter stream drives staging, and
                # completeness is one whole-window residency waiter — not a
                # per-consumer read fan-out (the last read releases a single
                # completion task instead of num_consumers of them; the
                # consumers still own the *event* routing, so migration and
                # drop-stale semantics are unchanged).
                self._subscribe_stream(buf, session)
                buf.outstanding = 1

                def window_resident(_msg) -> None:
                    with self._lock:
                        buf.outstanding = 0
                    buf.ready.set(buf)

                self.ck.read_notify(
                    session, nbytes, abs_off,
                    CkCallback(window_resident, pe=0),
                    # The splinter stream classifies this window's bytes
                    # per event (against the routed consumer's domain);
                    # the residency probe must not classify them again.
                    classify_locality=False)
                return
            # Consumers collectively read disjoint slices of the window.
            n = self.num_consumers
            per = (nbytes + n - 1) // n
            itemsize = self.meta.itemsize
            per -= per % itemsize  # keep element alignment
            per = max(per, itemsize)
            plans = []
            pos = 0
            while pos < nbytes:
                take = min(per, nbytes - pos)
                plans.append((pos, take))
                pos += take
            buf.outstanding = len(plans)

            def make_done():
                def done(_msg) -> None:
                    with self._lock:
                        buf.outstanding -= 1
                        if buf.outstanding == 0:
                            buf.ready.set(buf)

                return done

            for i, (rel_off, take) in enumerate(plans):
                client = self.consumers[i % len(self.consumers)]
                if mv is None:
                    # zero-copy mode: residency signal only — get_batch
                    # takes one whole-window arena view itself.
                    self.ck.read_notify(
                        session,
                        take,
                        abs_off + rel_off,
                        client.callback(make_done()),
                        client=client,
                    )
                else:
                    self.ck.read(
                        session,
                        take,
                        abs_off + rel_off,
                        mv[rel_off : rel_off + take],
                        client.callback(make_done()),
                        client=client,
                    )

        self.ck.start_read_session(
            self.file,
            nbytes,
            abs_off,
            CkCallback(on_session, inline=True),
            consumer_pes=[c.pe for c in self.consumers],
        )

    # -- streamed staging (the event-driven device path) ----------------------
    def _subscribe_stream(self, buf: _StepBuffer, session: Session) -> None:
        """Attach the per-splinter staging loop to ``session``'s stream."""
        st = _StreamState(session=session, sharded=self.sharding is not None,
                          abs_off=buf.abs_off)
        buf.stream = st

        def route(ev: SplinterEvent) -> Optional[Client]:
            # Deliver each event through a consumer's virtual proxy: the
            # staging task chases migrations, and an event addressed to a
            # consumer retired by resize() is dropped + counted (drop-stale
            # delivery), never rerouted to a reused slot. Copy the list —
            # resize() mutates self.consumers in place from another thread,
            # and this runs on the completing I/O thread (a stale Client
            # picked from the copy is exactly the drop-stale case).
            cons = list(self.consumers)
            return cons[ev.index % len(cons)] if cons else None

        def on_splinter(ev: SplinterEvent) -> None:
            self._on_stream_event(buf, st, ev)

        st.token = self.ck.read_stream(session, on_splinter, route=route)

    def _on_stream_event(
        self, buf: _StepBuffer, st: _StreamState, ev: SplinterEvent
    ) -> None:
        """Scheduler task per streamed splinter arrival: accumulate until a
        chunk's worth of bytes is pending, then ship it."""
        with self._lock:
            if st.retired:
                # Late event racing finalize/resize: drop and count — the
                # splinter was (or will be) staged from the authoritative
                # event log, never twice.
                self.stream.record_stale_event()
                self.ck.locations.count_stale()
                return
            st.pending.append(ev)
            if (sum(e.nbytes for e in st.pending) < self.stage_chunk_bytes
                    and not buf.ready.done):
                return                 # accumulate; tail staged at finalize
            group, st.pending = st.pending, []
            st.stagers += 1            # claimed: finalize must wait for us
        try:
            self._stage_group(st, group)
        finally:
            with self._lock:
                st.stagers -= 1
                self._lock.notify_all()

    def _stage_group(self, st: _StreamState, group: List[SplinterEvent]) -> None:
        """``device_put`` a group of arrived splinters, one chunk per
        splinter, respecting the in-flight staging budget. Runs on the
        pumping thread — while reader threads are still filling the rest of
        the session.

        One chunk per splinter is deliberate: splinter sizes within a plan
        are uniform (modulo stripe tails), so the staged chunk *shapes* —
        and with them the device concatenate/gather signatures — are stable
        across steps and arrival permutations, keeping every step on cached
        executables. Coalescing arrival runs would produce arrival-dependent
        chunk shapes and recompile the consume path each step."""
        import jax

        if not group:
            return
        if st.sharded:
            return self._stage_group_sharded(st, group)
        sess = st.session
        assert sess is not None
        for ev in group:
            self._evict_for(ev.nbytes)
            view = sess.readers.borrow_view(ev.offset, ev.nbytes)
            tokens = np.frombuffer(view, dtype=self.meta.dtype)
            if tokens.dtype == np.uint32:
                tokens = tokens.view(np.int32)
            t0 = time.perf_counter()
            self.stream.stage_inflight(ev.nbytes)
            try:
                chunk = jax.device_put(tokens)
            except BaseException:
                # A failed transfer never reaches _stage_outstanding, so
                # its budget charge must be rolled back here or
                # inflight_bytes stays inflated for the pipeline's life.
                self.stream.stage_inflight(-ev.nbytes)
                raise
            t1 = time.perf_counter()
            if st.t_first_stage == 0.0:
                st.t_first_stage = t0
            st.t_last_stage = t1
            with self._lock:
                st.chunks.append(chunk)
                st.chunk_hosts.append((tokens, view))
                st.events.append(ev)
                self._stage_outstanding.append((st, chunk, ev.nbytes))
            self.stream.record_chunk(
                ev.nbytes, 1, t1 - t0, [t1 - ev.t_arrival])

    def _evict_for(self, nbytes: int) -> None:
        """Bounded in-flight budget: make room for an ``nbytes`` transfer by
        awaiting the oldest outstanding transfer(s) — from whichever step
        stream issued them — before the caller issues another one."""
        while True:
            with self._lock:
                if (self.stream.inflight_bytes + nbytes
                        <= self.max_inflight_stage_bytes
                        or not self._stage_outstanding):
                    return
                _, old_chunk, old_n = self._stage_outstanding.popleft()
            old_chunk.block_until_ready()
            self.stream.stage_inflight(-old_n)

    def _stage_group_sharded(
        self, st: _StreamState, group: List[SplinterEvent]
    ) -> None:
        """Sharded streamed staging: route each arrived splinter's tokens to
        their destination device(s) by interval intersection against the
        resolved spans and ``device_put`` each *addressable* sub-slice onto
        its device. The sub-slices are numpy views of the session arena
        (zero host copies, ``host_permute_bytes`` stays 0); spans owned by
        another host's devices are counted (``ShardMetrics.cross_host``)
        and skipped — this host never stages bytes it cannot address."""
        import jax

        sess = st.session
        assert sess is not None
        itemsize = self.meta.itemsize
        shards = self.ck.director.shards
        for ev in group:
            view = sess.readers.borrow_view(ev.offset, ev.nbytes)
            tokens = np.frombuffer(view, dtype=self.meta.dtype)
            if tokens.dtype == np.uint32:
                tokens = tokens.view(np.int32)
            tok0 = (ev.offset - st.abs_off) // itemsize
            ntok = ev.nbytes // itemsize
            t0 = time.perf_counter()
            staged_bytes = 0
            npieces = 0
            for dev, (s0, s1) in self._dev_spans.items():
                lo, hi = max(tok0, s0), min(tok0 + ntok, s1)
                if lo >= hi:
                    continue
                nb = (hi - lo) * itemsize
                if dev not in self._addr_devices:
                    shards.record_cross_host(nb)
                    continue
                self._evict_for(nb)
                sub = tokens[lo - tok0: hi - tok0]
                self.stream.stage_inflight(nb)
                try:
                    chunk = jax.device_put(sub, dev)
                except BaseException:
                    self.stream.stage_inflight(-nb)
                    raise
                with self._lock:
                    st.dev_pieces.setdefault(dev, []).append((lo, chunk))
                    self._stage_outstanding.append((st, chunk, nb))
                shards.record_stage(str(dev), nb)
                staged_bytes += nb
                npieces += 1
            t1 = time.perf_counter()
            if st.t_first_stage == 0.0:
                st.t_first_stage = t0
            st.t_last_stage = t1
            with self._lock:
                # The event (and its pinning host refs) is recorded even if
                # every intersecting span was remote: the coverage proof at
                # finalize runs over the event log, not the staged pieces.
                st.chunk_hosts.append((tokens, view))
                st.events.append(ev)
            if npieces:
                self.stream.record_chunk(
                    staged_bytes, npieces, t1 - t0, [t1 - ev.t_arrival])

    def _finalize_stream(self, buf: _StepBuffer):
        """All reads are resident (``buf.ready``): stop the stream, stage the
        pending tail plus any splinters whose events were dropped, and return
        the arrival-order device chunks + their piece layout."""
        st = buf.stream
        assert st is not None and st.session is not None
        sess = st.session
        # No pipeline lock held here: end_stream takes the reader stream
        # lock (lock order is stream lock -> pipeline lock, never inverse).
        self.ck.end_stream(sess, st.token)
        with self._lock:
            # Retire FIRST: event tasks popped concurrently by another
            # pumping thread from here on drop + count instead of staging —
            # otherwise one could race the missing-scan below and stage its
            # splinter twice. Then drain stagers that already claimed a
            # group before the flip (their chunks must be in st.events
            # before the scan).
            st.retired = True
            group, st.pending = st.pending, []
            while st.stagers:
                self._lock.wait()
        self._stage_group(st, group)
        # Completeness: any splinter not staged (its event was dropped by
        # drop-stale routing mid-resize, or raced the retire flip) comes
        # from the authoritative event log — the session is complete, so
        # the log is too.
        with self._lock:
            seen = {e.index for e in st.events}
        missing = [ev for ev in sess.splinter_events if ev.index not in seen]
        self._stage_group(st, missing)
        with self._lock:
            own = [e for e in self._stage_outstanding if e[0] is st]
            self._stage_outstanding = deque(
                e for e in self._stage_outstanding if e[0] is not st)
        # The consuming gather forces every chunk; this stream's transfers
        # leave the in-flight budget (other steps' streams keep theirs).
        self.stream.stage_inflight(-sum(n for _, _, n in own))
        pieces = [(e.offset, e.nbytes) for e in st.events]
        return list(st.chunks), pieces, st

    def _abort_stream(self, buf: _StepBuffer) -> None:
        """Tear down a step's stream without consuming it (host-path fetch,
        per-call sharding override, or pipeline close)."""
        st = buf.stream
        if st is None:
            return
        buf.stream = None
        if st.session is not None and st.token is not None:
            self.ck.end_stream(st.session, st.token)
        with self._lock:
            st.retired = True
            st.pending = []
            while st.stagers:          # drain in-flight _stage_group calls
                self._lock.wait()
            chunks, st.chunks = list(st.chunks), []
            chunks.extend(c for ps in st.dev_pieces.values() for _, c in ps)
            st.dev_pieces = {}
            st.chunk_hosts = []
            own = [e for e in self._stage_outstanding if e[0] is st]
            self._stage_outstanding = deque(
                e for e in self._stage_outstanding if e[0] is not st)
        for chunk in chunks:
            # The arena must outlive the transfers; block before the chunk
            # views can be invalidated by the session retiring.
            chunk.block_until_ready()
        self.stream.stage_inflight(-sum(n for _, _, n in own))

    def _close_retired(self) -> None:
        with self._lock:
            retired, self._retired = self._retired, []
            staged, self._staged = self._staged, []
        if staged:
            import jax
        for st in staged:
            # The step's host→device transfer(s) may still be in flight;
            # the arena (and our host refs) must outlive them. Block, then
            # drop the references so the borrow(s) can actually be released.
            # (Streamed steps pin their outputs — blocking those forces
            # every chunk transfer they consumed.) A failed transfer
            # propagates (the device array is unusable and silence would
            # let ingest counters claim success); the host refs are dropped
            # either way — a failed transfer does not need the arena.
            try:
                jax.block_until_ready(st.staged)
            finally:
                st.host_tokens = None
                st.staged = None
        for sess in retired:
            # Invalidate borrows inline (idempotent — close_session repeats
            # it) so the lifetime contract is "valid until the next
            # get_batch*", not "until some later scheduler pump"; the
            # split-phase session close itself stays off the critical path.
            sess.readers.invalidate_borrows()
            self.ck.close_read_session(sess)

    def _wait_step(self, step: int, timeout: float) -> _StepBuffer:
        if step >= self.num_steps:
            raise IndexError(f"step {step} >= {self.num_steps}")
        self.start_step(step)  # no-op if already started
        buf = self._bufs[step]
        buf.ready.wait(self.ck.sched, timeout=timeout)
        # Launch the lookahead before handing the batch to the trainer.
        self.start_step(step + self.prefetch_depth)
        with self._lock:
            self._bufs.pop(step, None)
        return buf

    def _window_tokens(self, buf: _StepBuffer):
        """Whole-window tokens (and the borrowed arena view backing them,
        zero-copy mode only). Retires the *previous* step first."""
        if buf.stream is not None:
            # Host-path / whole-window fetch of a streamed step: the stream
            # state is torn down first (its chunks are never consumed).
            self._abort_stream(buf)
        view: Optional[memoryview] = None
        if self.zero_copy:
            # Previous step's batch has been consumed by now — retire its
            # session (which invalidates its borrowed views).
            self._close_retired()
            assert buf.session is not None
            view = buf.session.readers.borrow_view(buf.abs_off, buf.nbytes)
            tokens = np.frombuffer(view, dtype=self.meta.dtype)
            with self._lock:
                self._retired.append(buf.session)
        else:
            self._close_retired()     # release any pending device-step refs
            if buf.session is not None:
                self.ck.close_read_session(buf.session)
            tokens = buf.arena
            assert tokens is not None
        if tokens.dtype == np.uint32:
            tokens = tokens.view(np.int32)   # zero-copy reinterpret
        return tokens, view

    def get_batch(self, step: int, timeout: float = 300.0) -> Tuple[np.ndarray, np.ndarray]:
        """Blocking (scheduler-pumping) fetch of step ``step``; prefetches
        ``step + prefetch_depth`` before returning (the overlap).

        In zero-copy mode the returned arrays alias the step's session arena
        and remain valid until the next ``get_batch*``/``close`` call."""
        buf = self._wait_step(step, timeout)
        tokens, _ = self._window_tokens(buf)
        inputs, labels = batch_from_tokens(
            tokens, self.global_batch, self.seq_len,
            allow_partial=not self.drop_remainder, pad_id=self.pad_id,
        )
        # Host-side phase-2 permutation: the window passes through host
        # reshaping/marshalling on its way to the device.
        self.ingest.record_host_step(buf.nbytes)
        self._t_last_step = time.perf_counter()
        return inputs, labels

    def get_batch_device(
        self,
        step: int,
        sharding=None,
        *,
        use_pallas: Optional[bool] = None,
        timeout: float = 300.0,
    ):
        """Device-ingest fetch: one ``device_put`` of the whole-window arena
        view, then on-device batch-major reassembly (fused label shift +
        remainder padding). Returns JAX device arrays ``(inputs, labels)``.

        See the module docstring for the staged-buffer lifetime contract.
        ``sharding`` is forwarded to ``device_put`` for the staged window;
        ``use_pallas`` picks the gather backend (default: Pallas on TPU,
        XLA reference elsewhere).

        With ``streaming=True`` (and no per-call ``sharding``), the window
        was being staged chunk-by-chunk while its reads were in flight; this
        call only ships the tail, concatenates on device, and runs the
        arrival-order gather — see "Streamed staging" in the module
        docstring."""
        import jax

        from repro.kernels import ops

        buf = self._wait_step(step, timeout)
        if self.sharding is not None:
            # Constructor sharding owns the step: per-call shardings must
            # agree (the spans were resolved — and streamed chunks placed —
            # against the constructor's). use_pallas is moot here: the
            # sharded assembly is concat+reshape+shift, no gather kernel.
            if sharding is not None and sharding != self.sharding:
                raise ValueError(
                    "get_batch_device(sharding=...) differs from the "
                    "pipeline's constructor sharding; streamed chunks are "
                    "already placed against the constructor's spans")
            if buf.stream is not None:
                return self._get_batch_device_streamed_sharded(buf)
            return self._get_batch_device_window_sharded(buf)
        if buf.stream is not None and sharding is None:
            return self._get_batch_device_streamed(buf, use_pallas=use_pallas)
        if buf.stream is not None and not self._warned_stream_sharding:
            # Explicit, not silent: streamed chunks were device_put with
            # default placement while the reads were landing — before this
            # call-site sharding existed — so they cannot satisfy it. The
            # step falls back to the whole-window path (stage once WITH the
            # sharding, reassemble on device); the already-staged chunks
            # are discarded. Warn once per pipeline: per-call sharding on a
            # streaming pipeline forfeits the read/stage overlap every
            # step, which is almost never what a multi-host run wants —
            # construct the pipeline with streaming=False (or ship the
            # sharding at construction time) instead.
            self._warned_stream_sharding = True
            warnings.warn(
                "get_batch_device(sharding=...) on a streaming pipeline: "
                "streamed chunks are placed before a per-call sharding is "
                "known; falling back to the whole-window staging path "
                "(overlap lost) for every sharded call. Use "
                "streaming=False if every step passes a sharding.",
                RuntimeWarning, stacklevel=2)
        tokens, view = self._window_tokens(buf)
        itemsize = self.meta.itemsize
        valid_tokens = buf.nbytes // itemsize
        # The step's single host→device transfer (sharding=None → default
        # device placement).
        staged = jax.device_put(tokens, sharding)
        if self.zero_copy:
            with self._lock:
                # borrow_view in _window_tokens appended the session; the
                # staged refs pin arena + transfer until the next call.
                self._staged.append(_StagedStep(
                    staged=staged,
                    host_tokens=tokens,
                    host_view=view,
                ))
        inputs, labels = ops.device_ingest(
            staged,
            None,                       # arena view is file-order
            global_batch=self.global_batch,
            seq_len=self.seq_len,
            valid_tokens=valid_tokens,
            pad_id=self.pad_id,
            use_pallas=use_pallas,
        )
        # Copy mode still pays the session→step-arena host copy before
        # staging; only the zero-copy path truly has 0 host bytes.
        self.ingest.record_device_step(
            buf.nbytes, host_bytes=0 if self.zero_copy else buf.nbytes)
        self._t_last_step = time.perf_counter()
        return inputs, labels

    def _get_batch_device_streamed(
        self, buf: _StepBuffer, *, use_pallas: Optional[bool] = None
    ):
        """Streamed tail of ``get_batch_device``: finalize the step's chunk
        stream and reassemble on device in a single fused dispatch (concat +
        window kernel over file-order-sorted chunk handles)."""
        from repro.kernels import ops

        self._close_retired()          # release the previous step's refs
        chunks, pieces, st = self._finalize_stream(buf)
        sess = st.session
        itemsize = self.meta.itemsize
        valid_tokens = buf.nbytes // itemsize
        abs_off = buf.abs_off
        # The arrival-order→file-order permutation is applied to the chunk
        # *handles*: each splinter is its own device buffer, so reordering
        # the argument list (host metadata, O(#splinters log #splinters))
        # replaces the on-device gather a contiguous arrival-ordered staging
        # buffer would need (ops.ingest_chunks_block / device_ingest serve
        # that layout). Sorted order is also deterministic per plan, so the
        # fused executable's chunk-shape signature is identical across steps
        # whatever order the reads completed in.
        order = sorted(range(len(pieces)), key=lambda i: pieces[i][0])
        pieces = [pieces[i] for i in order]
        chunks = [chunks[i] for i in order]
        pos = abs_off
        for off, nb in pieces:        # exactly-once coverage, cheap to prove
            if off != pos:
                raise RuntimeError(
                    f"streamed pieces corrupt: expected offset {pos}, "
                    f"got {off}")
            pos += nb
        if pos != abs_off + buf.nbytes:
            raise RuntimeError("streamed pieces do not cover the window")
        inputs, labels = ops.ingest_chunks_window(
            chunks, global_batch=self.global_batch, seq_len=self.seq_len,
            valid_limit=valid_tokens, pad_id=self.pad_id,
            use_pallas=use_pallas)
        with self._lock:
            self._retired.append(sess)
            # Pin the chunk views + outputs until the next step: the
            # streamed analog of the whole-window staged refs (module
            # docstring, "streamed chunk views"); blocking on the outputs
            # forces every chunk transfer they consumed.
            self._staged.append(_StagedStep(
                staged=(inputs, labels),
                host_tokens=st.chunk_hosts,
                host_view=None,
            ))
            nchunks = len(st.chunks)
            st.chunks = []
            st.chunk_hosts = []
        buf.stream = None
        self.ingest.record_device_step(
            buf.nbytes, transfers=nchunks, host_bytes=0)
        now = time.perf_counter()
        self.stream.record_step(
            (sess.metrics.t_start, sess.metrics.t_last_read),
            (st.t_first_stage, st.t_last_stage),
            now - self._t_last_step,
        )
        self._t_last_step = now
        return inputs, labels

    # -- sharded device path (constructor sharding=) ---------------------------
    def _np_token_dtype(self):
        """Host-side token dtype after the zero-copy uint32→int32 view."""
        dt = np.dtype(self.meta.dtype)
        return np.dtype(np.int32) if dt == np.uint32 else dt

    def _shift(self, window):
        """Jitted label shift over the assembled sharded window: the
        ``(w[:, :-1], w[:, 1:])`` split of ``batch_from_tokens``, computed
        on device. Column slicing never crosses a batch-dim shard, so the
        outputs keep the window's sharding without any communication."""
        import jax

        if self._shift_fn is None:
            self._shift_fn = jax.jit(lambda w: (w[:, :-1], w[:, 1:]))
        return self._shift_fn(window)

    def _assemble_sharded_window(self, dev_pieces: Dict, valid_tokens: int):
        """Bind per-device token pieces (already resident on their
        destination devices) into the global sharded ``(B, S+1)`` window:
        per addressable device — sort by token offset, prove its span is
        exactly covered, pad the remainder tail on-device, reshape to the
        device's row block — then
        ``jax.make_array_from_single_device_arrays`` (metadata only, no
        further transfer)."""
        import jax
        import jax.numpy as jnp

        width = self.seq_len + 1
        np_dtype = self._np_token_dtype()
        # Deterministic block order (addressable_devices is a set).
        devs = sorted(self._addr_devices, key=lambda d: d.id)
        blocks = []
        for dev in devs:
            s0, s1 = self._dev_spans[dev]
            pieces = sorted(dev_pieces.get(dev, ()), key=lambda p: p[0])
            pos = s0
            for t0, c in pieces:
                if t0 != pos:
                    raise RuntimeError(
                        f"sharded pieces corrupt on {dev}: expected token "
                        f"{pos}, got {t0}")
                pos += c.size
            expected = max(0, min(s1, valid_tokens) - s0)
            if pos - s0 != expected:
                raise RuntimeError(
                    f"sharded pieces do not cover {dev}'s span: "
                    f"{pos - s0} of {expected} tokens")
            parts = [c for _, c in pieces]
            pad = (s1 - s0) - expected
            with jax.default_device(dev):
                if pad:
                    parts.append(jnp.full((pad,), self.pad_id,
                                          dtype=np_dtype))
                if not parts:          # empty span (more devices than rows)
                    parts = [jnp.zeros((0,), dtype=np_dtype)]
                block = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
                blocks.append(block.reshape((s1 - s0) // width, width))
        return jax.make_array_from_single_device_arrays(
            (self.global_batch, width), self.sharding, blocks)

    def _addressable_window_bytes(self, valid_tokens: int) -> int:
        """Bytes of this step's *valid* window owned by this host's
        devices (the staged-bytes ledger's addressable side)."""
        itemsize = self.meta.itemsize
        return sum(
            max(0, min(s1, valid_tokens) - min(s0, valid_tokens)) * itemsize
            for dev, (s0, s1) in self._dev_spans.items()
            if dev in self._addr_devices)

    def _get_batch_device_streamed_sharded(self, buf: _StepBuffer):
        """Sharded streamed tail: finalize the chunk stream (the per-device
        pieces were ``device_put`` as their splinters arrived), prove
        window coverage from the event log, assemble the global window and
        shift — no whole-window restage, no ``RuntimeWarning``."""
        self._close_retired()          # release the previous step's refs
        _, pieces, st = self._finalize_stream(buf)
        sess = st.session
        valid_tokens = buf.nbytes // self.meta.itemsize
        # Exactly-once coverage of the window, from the authoritative event
        # log (staged pieces can be a strict subset on multi-host runs).
        pos = buf.abs_off
        for off, nb in sorted(pieces):
            if off != pos:
                raise RuntimeError(
                    f"streamed pieces corrupt: expected offset {pos}, "
                    f"got {off}")
            pos += nb
        if pos != buf.abs_off + buf.nbytes:
            raise RuntimeError("streamed pieces do not cover the window")
        window = self._assemble_sharded_window(st.dev_pieces, valid_tokens)
        inputs, labels = self._shift(window)
        self.ck.director.shards.record_window(
            buf.nbytes, self._addressable_window_bytes(valid_tokens))
        with self._lock:
            self._retired.append(sess)
            self._staged.append(_StagedStep(
                staged=(inputs, labels),
                host_tokens=st.chunk_hosts,
                host_view=None,
            ))
            npieces = sum(len(v) for v in st.dev_pieces.values())
            st.dev_pieces = {}
            st.chunk_hosts = []
        buf.stream = None
        self.ingest.record_device_step(
            buf.nbytes, transfers=npieces, host_bytes=0)
        now = time.perf_counter()
        self.stream.record_step(
            (sess.metrics.t_start, sess.metrics.t_last_read),
            (st.t_first_stage, st.t_last_stage),
            now - self._t_last_step,
        )
        self._t_last_step = now
        return inputs, labels

    def _get_batch_device_window_sharded(self, buf: _StepBuffer):
        """Whole-window variant of the sharded path (``streaming=False``):
        slice the resident window's host tokens per addressable device span
        (numpy views — no host copy in zero-copy mode), one ``device_put``
        per addressable device, then the same assembly as the streamed
        path. Each host stages only its addressable slice."""
        import jax

        tokens, view = self._window_tokens(buf)
        itemsize = self.meta.itemsize
        valid_tokens = buf.nbytes // itemsize
        shards = self.ck.director.shards
        dev_pieces: Dict = {}
        npieces = 0
        for dev, (s0, s1) in self._dev_spans.items():
            lo, hi = min(s0, valid_tokens), min(s1, valid_tokens)
            if dev not in self._addr_devices:
                shards.record_cross_host((hi - lo) * itemsize)
                continue
            if hi > lo:
                chunk = jax.device_put(tokens[lo:hi], dev)
                dev_pieces[dev] = [(lo, chunk)]
                shards.record_stage(str(dev), (hi - lo) * itemsize)
                npieces += 1
        shards.record_window(
            buf.nbytes, self._addressable_window_bytes(valid_tokens))
        window = self._assemble_sharded_window(dev_pieces, valid_tokens)
        inputs, labels = self._shift(window)
        if self.zero_copy:
            with self._lock:
                # borrow_view in _window_tokens appended the session; the
                # staged refs pin arena + transfers until the next call.
                self._staged.append(_StagedStep(
                    staged=(inputs, labels),
                    host_tokens=tokens,
                    host_view=view,
                ))
        self.ingest.record_device_step(
            buf.nbytes, transfers=npieces,
            host_bytes=0 if self.zero_copy else buf.nbytes)
        self._t_last_step = time.perf_counter()
        return inputs, labels

    def idle(self, seconds: float) -> int:
        """Pump pipeline tasks for ``seconds`` (call while the device step
        runs) — the Charm++ idle-PE behaviour that makes prefetch overlap
        real. Returns tasks processed."""
        import time as _time

        return self.ck.sched.pump_until_deadline(_time.monotonic() + seconds)

    def __iter__(self):
        for s in range(self._next_step, self.num_steps):
            yield self.get_batch(s)

    # -- device hand-off ---------------------------------------------------------
    @staticmethod
    def to_device(inputs: np.ndarray, labels: np.ndarray, sharding=None):
        import jax

        if sharding is None:
            return jax.device_put(inputs), jax.device_put(labels)
        return jax.device_put(inputs, sharding), jax.device_put(labels, sharding)

    def close(self) -> None:
        # A crashed reader worker in a *prefetched* session surfaces as a
        # raising task the moment anything pumps the scheduler. Teardown
        # must still run to completion (sessions stopped, shm unmapped,
        # the file fd closed) — so close catches those here, finishes, and
        # re-raises the first one at the end instead of aborting half-way
        # with the fd leaked.
        surfaced: List[BaseException] = []

        def pump_all() -> None:
            while True:
                try:
                    self.ck.pump()
                    return
                except WorkerCrashed as e:   # finite: ≤1 task per session
                    surfaced.append(e)

        # Flush queued session starts BEFORE tearing down streams: a
        # prefetch session that only starts during this pump subscribes its
        # splinter stream then (and may stage chunks) — aborting first
        # would miss it and leak its in-flight accounting. The pump is also
        # what makes close deterministic: every reader thread of this file
        # is joined below before the fd goes away (an in-flight prefetch
        # session must not pread a closed file; shutdown is off the hot
        # path).
        pump_all()
        for buf in list(self._bufs.values()):
            if buf.stream is not None:
                self._abort_stream(buf)
        self._close_retired()
        stopped = True
        for sess in list(self.ck.director.sessions.values()):
            if sess.file is self.file:
                stopped &= sess.readers.stop()
        for buf in list(self._bufs.values()):
            if buf.session is not None:
                self.ck.close_read_session(buf.session)
        if not stopped:
            # A straggling reader may still pread this fd; closing it now
            # risks EBADF or — after fd reuse — reading the wrong file.
            # Leak the fd and fail loud instead.
            raise RuntimeError(
                "pipeline close: reader thread(s) still running after stop "
                "timeout; file left open")
        while True:
            try:
                self.ck.close_sync(self.file)
                break
            except WorkerCrashed as e:
                surfaced.append(e)
        if surfaced:
            raise surfaced[0]
