"""CkIO-backed training input pipeline — the "ChaNGa integration" analog.

Over-decomposed consumers (feeder clients, many per PE) collectively read each
training step's token window through a CkIO read session, while the device
runs the previous step: a double-buffered, split-phase pipeline that
implements the paper's compute/input overlap at the training-loop level.

Key structural mirror of the paper:
  * consumer count (`num_consumers`) is chosen by the *application* (here:
    microbatch×prefetch structure), completely decoupled from `num_readers`
    (chosen for the file system) — paper §III-B.
  * one read session per step window, prefetched greedily (paper §III-A:
    "read the file chunk-by-chunk (one chunk per session)").
  * consumers are migratable; `resize()` implements elastic scaling by
    re-registering consumers, leaving the reader layer untouched.

Delivery modes:
  * ``zero_copy=True`` (default): consumer reads ride the borrowed-view path
    (``read(dest=None)``) and ``get_batch`` materializes the step's tokens as
    a NumPy array *aliasing the session arena* — zero host copies between the
    preadv into the arena and ``device_put``. The batch arrays are valid
    until the **next** ``get_batch``/``close`` call (the session is retired
    lazily); every call-site here consumes a batch before fetching the next.
  * ``zero_copy=False``: consumer reads land directly in a per-step NumPy
    arena (one copy, session arena → step arena), with no lifetime caveat.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import CkIO, Client, FileOptions, Session
from repro.core.futures import CkCallback, CkFuture
from repro.data.packing import batch_from_tokens, window_rows
from repro.data.tokenfile import read_meta


@dataclass
class _StepBuffer:
    step: int
    abs_off: int = 0
    nbytes: int = 0
    session: Optional[Session] = None
    arena: Optional[np.ndarray] = None
    outstanding: int = 0
    ready: CkFuture = field(default_factory=CkFuture)


class CkIOPipeline:
    """Double-buffered LM batch pipeline over a flat token file."""

    def __init__(
        self,
        path: str,
        global_batch: int,
        seq_len: int,
        *,
        ckio: Optional[CkIO] = None,
        num_pes: int = 4,
        num_consumers: Optional[int] = None,
        file_opts: Optional[FileOptions] = None,
        prefetch_depth: int = 2,
        start_step: int = 0,
        drop_remainder: bool = True,
        zero_copy: bool = True,
    ):
        self.meta = read_meta(path)
        if len(self.meta.shape) != 1:
            raise ValueError("LM pipeline expects a flat token file")
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.ck = ckio or CkIO(num_pes=num_pes)
        self.file_opts = file_opts or FileOptions()
        self.file = self.ck.open_sync(path, self.file_opts)
        self.prefetch_depth = max(1, prefetch_depth)
        rows_per_step = global_batch * (seq_len + 1)
        self.num_steps = self.meta.num_rows // rows_per_step
        if not drop_remainder and self.meta.num_rows % rows_per_step:
            self.num_steps += 1
        # Over-decomposition: consumers default to 4 per PE (paper: apps
        # commonly run 16+ objects/core; tunable independently of readers).
        self.num_consumers = num_consumers or 4 * self.ck.sched.num_pes
        self.consumers: List[Client] = [
            self.ck.make_client(pe=i % self.ck.sched.num_pes)
            for i in range(self.num_consumers)
        ]
        self.zero_copy = zero_copy
        self._bufs: Dict[int, _StepBuffer] = {}
        self._retired: List[Session] = []   # zero-copy sessions pending close
        self._lock = threading.Lock()
        self._next_step = start_step
        for s in range(start_step, min(start_step + self.prefetch_depth, self.num_steps)):
            self.start_step(s)

    # -- elastic scaling -------------------------------------------------------
    def resize(self, num_consumers: int) -> None:
        """Elastically change the consumer decomposition (readers untouched)."""
        cur = len(self.consumers)
        if num_consumers > cur:
            self.consumers.extend(
                self.ck.make_client(pe=i % self.ck.sched.num_pes)
                for i in range(cur, num_consumers)
            )
        else:
            del self.consumers[num_consumers:]
        self.num_consumers = num_consumers

    def migrate_consumer(self, idx: int, new_pe: int) -> None:
        self.consumers[idx].migrate(new_pe)

    # -- split-phase step input --------------------------------------------------
    def start_step(self, step: int) -> None:
        """Kick off the read session + consumer reads for ``step`` (async)."""
        with self._lock:
            if step in self._bufs or step >= self.num_steps:
                return
            buf = _StepBuffer(step=step)
            self._bufs[step] = buf

        start_row, num_rows = window_rows(step, self.global_batch, self.seq_len)
        abs_off, nbytes = self.meta.byte_range_for_rows(start_row, num_rows)
        buf.abs_off, buf.nbytes = abs_off, nbytes
        mv: Optional[memoryview] = None
        if not self.zero_copy:
            buf.arena = np.empty(num_rows, dtype=self.meta.dtype)
            mv = memoryview(buf.arena).cast("B")

        def on_session(session: Session) -> None:
            buf.session = session
            # Consumers collectively read disjoint slices of the window.
            n = self.num_consumers
            per = (nbytes + n - 1) // n
            itemsize = self.meta.itemsize
            per -= per % itemsize  # keep element alignment
            per = max(per, itemsize)
            plans = []
            pos = 0
            while pos < nbytes:
                take = min(per, nbytes - pos)
                plans.append((pos, take))
                pos += take
            buf.outstanding = len(plans)

            def make_done():
                def done(_msg) -> None:
                    with self._lock:
                        buf.outstanding -= 1
                        if buf.outstanding == 0:
                            buf.ready.set(buf)

                return done

            for i, (rel_off, take) in enumerate(plans):
                client = self.consumers[i % len(self.consumers)]
                if mv is None:
                    # zero-copy mode: residency signal only — get_batch
                    # takes one whole-window arena view itself.
                    self.ck.read_notify(
                        session,
                        take,
                        abs_off + rel_off,
                        client.callback(make_done()),
                        client=client,
                    )
                else:
                    self.ck.read(
                        session,
                        take,
                        abs_off + rel_off,
                        mv[rel_off : rel_off + take],
                        client.callback(make_done()),
                        client=client,
                    )

        self.ck.start_read_session(
            self.file,
            nbytes,
            abs_off,
            CkCallback(on_session, inline=True),
            consumer_pes=[c.pe for c in self.consumers],
        )

    def _close_retired(self) -> None:
        with self._lock:
            retired, self._retired = self._retired, []
        for sess in retired:
            self.ck.close_read_session(sess)

    def get_batch(self, step: int, timeout: float = 300.0) -> Tuple[np.ndarray, np.ndarray]:
        """Blocking (scheduler-pumping) fetch of step ``step``; prefetches
        ``step + prefetch_depth`` before returning (the overlap).

        In zero-copy mode the returned arrays alias the step's session arena
        and remain valid until the next ``get_batch``/``close`` call."""
        if step >= self.num_steps:
            raise IndexError(f"step {step} >= {self.num_steps}")
        self.start_step(step)  # no-op if already started
        buf = self._bufs[step]
        buf.ready.wait(self.ck.sched, timeout=timeout)
        # Launch the lookahead before handing the batch to the trainer.
        self.start_step(step + self.prefetch_depth)
        with self._lock:
            self._bufs.pop(step, None)
        if self.zero_copy:
            # Previous step's batch has been consumed by now — retire its
            # session (which invalidates its borrowed views).
            self._close_retired()
            assert buf.session is not None
            view = buf.session.readers.borrow_view(buf.abs_off, buf.nbytes)
            tokens = np.frombuffer(view, dtype=self.meta.dtype)
            with self._lock:
                self._retired.append(buf.session)
        else:
            if buf.session is not None:
                self.ck.close_read_session(buf.session)
            tokens = buf.arena
            assert tokens is not None
        if tokens.dtype == np.uint32:
            tokens = tokens.view(np.int32)   # zero-copy reinterpret
        inputs, labels = batch_from_tokens(
            tokens, self.global_batch, self.seq_len
        )
        return inputs, labels

    def idle(self, seconds: float) -> int:
        """Pump pipeline tasks for ``seconds`` (call while the device step
        runs) — the Charm++ idle-PE behaviour that makes prefetch overlap
        real. Returns tasks processed."""
        import time as _time

        return self.ck.sched.pump_until_deadline(_time.monotonic() + seconds)

    def __iter__(self):
        for s in range(self._next_step, self.num_steps):
            yield self.get_batch(s)

    # -- device hand-off ---------------------------------------------------------
    @staticmethod
    def to_device(inputs: np.ndarray, labels: np.ndarray, sharding=None):
        import jax

        if sharding is None:
            return jax.device_put(inputs), jax.device_put(labels)
        return jax.device_put(inputs, sharding), jax.device_put(labels, sharding)

    def close(self) -> None:
        self._close_retired()
        for buf in list(self._bufs.values()):
            if buf.session is not None:
                self.ck.close_read_session(buf.session)
        self.ck.close_sync(self.file)
