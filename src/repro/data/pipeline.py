"""CkIO-backed training input pipeline — the "ChaNGa integration" analog.

Over-decomposed consumers (feeder clients, many per PE) collectively read each
training step's token window through a CkIO read session, while the device
runs the previous step: a double-buffered, split-phase pipeline that
implements the paper's compute/input overlap at the training-loop level.

Key structural mirror of the paper:
  * consumer count (`num_consumers`) is chosen by the *application* (here:
    microbatch×prefetch structure), completely decoupled from `num_readers`
    (chosen for the file system) — paper §III-B.
  * one read session per step window, prefetched greedily (paper §III-A:
    "read the file chunk-by-chunk (one chunk per session)").
  * consumers are migratable; `resize()` implements elastic scaling by
    re-registering consumers, leaving the reader layer untouched; shrunk
    consumers are deregistered from the location manager (no leaked ids).

Delivery modes:
  * ``zero_copy=True`` (default): consumer reads ride the borrowed-view path
    (``read(dest=None)``) and ``get_batch`` materializes the step's tokens as
    a NumPy array *aliasing the session arena* — zero host copies between the
    preadv into the arena and ``device_put``.
  * ``zero_copy=False``: consumer reads land directly in a per-step NumPy
    arena (one copy, session arena → step arena), with no lifetime caveat.

Device ingest (``get_batch_device``) and its lifetime contract
--------------------------------------------------------------
``get_batch_device(step)`` replaces the host tail of the pipeline: the
borrowed **whole-window arena view** is handed to ``jax.device_put`` exactly
once (the step's only host→device transfer), and batch-major ``(inputs,
labels)`` — including the label shift-by-one and remainder-window padding —
are produced **on device** by the ``kernels/reassemble.py`` gather kernels
(the paper's phase-2 data permutation, moved to where bandwidth is
cheapest). Per step, host code touches file *metadata* only; the
``ingest`` counters (``core.metrics.IngestMetrics``) prove it:
``host_permute_bytes`` stays 0 and ``h2d_transfers`` advances by exactly 1.
(With ``zero_copy=False`` the session→step-arena copy still happens and is
counted as host bytes — only the zero-copy default earns the 0.)

Lifetime rules:
  * the returned ``(inputs, labels)`` are ordinary JAX device arrays — they
    own their storage and stay valid as long as the caller holds them;
  * the *staged host view* (the borrowed arena view fed to ``device_put``)
    and its session stay alive until the **next** ``get_batch*``/``close``
    call. At that point the pipeline blocks on the staged transfer, drops
    its host references and retires the session — any access to the old
    borrowed view afterwards raises ``ValueError`` (never a silent read of
    recycled arena memory);
  * host-path ``get_batch`` keeps its PR-1 contract: the returned arrays
    alias the session arena and are valid until the next
    ``get_batch*``/``close`` call.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import CkIO, Client, FileOptions, Session
from repro.core.futures import CkCallback, CkFuture
from repro.core.metrics import IngestMetrics
from repro.data.packing import batch_from_tokens, window_rows
from repro.data.tokenfile import read_meta


@dataclass
class _StepBuffer:
    step: int
    abs_off: int = 0
    nbytes: int = 0
    num_rows: int = 0                  # actual rows (< full for remainder)
    session: Optional[Session] = None
    arena: Optional[np.ndarray] = None
    outstanding: int = 0
    ready: CkFuture = field(default_factory=CkFuture)


@dataclass
class _StagedStep:
    """Host-side references pinning one device-ingested step (see module
    docstring lifetime rules): released by the next ``get_batch*``."""

    staged: object                     # jax.Array (whole-window tokens)
    host_tokens: Optional[np.ndarray]  # np view aliasing the arena
    host_view: Optional[memoryview]    # the borrowed arena view


class CkIOPipeline:
    """Double-buffered LM batch pipeline over a flat token file."""

    def __init__(
        self,
        path: str,
        global_batch: int,
        seq_len: int,
        *,
        ckio: Optional[CkIO] = None,
        num_pes: int = 4,
        num_consumers: Optional[int] = None,
        file_opts: Optional[FileOptions] = None,
        prefetch_depth: int = 2,
        start_step: int = 0,
        drop_remainder: bool = True,
        zero_copy: bool = True,
        pad_id: int = 0,
    ):
        self.meta = read_meta(path)
        if len(self.meta.shape) != 1:
            raise ValueError("LM pipeline expects a flat token file")
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.ck = ckio or CkIO(num_pes=num_pes)
        self.file_opts = file_opts or FileOptions()
        self.file = self.ck.open_sync(path, self.file_opts)
        self.prefetch_depth = max(1, prefetch_depth)
        self.drop_remainder = drop_remainder
        self.pad_id = pad_id
        rows_per_step = global_batch * (seq_len + 1)
        self.num_steps = self.meta.num_rows // rows_per_step
        if not drop_remainder and self.meta.num_rows % rows_per_step:
            self.num_steps += 1
        # Over-decomposition: consumers default to 4 per PE (paper: apps
        # commonly run 16+ objects/core; tunable independently of readers).
        self.num_consumers = num_consumers or 4 * self.ck.sched.num_pes
        self.consumers: List[Client] = [
            self.ck.make_client(pe=i % self.ck.sched.num_pes)
            for i in range(self.num_consumers)
        ]
        self.zero_copy = zero_copy
        self.ingest = IngestMetrics()
        self._bufs: Dict[int, _StepBuffer] = {}
        self._retired: List[Session] = []   # zero-copy sessions pending close
        self._staged: List[_StagedStep] = []  # device steps pending release
        self._lock = threading.Lock()
        self._next_step = start_step
        for s in range(start_step, min(start_step + self.prefetch_depth, self.num_steps)):
            self.start_step(s)

    # -- elastic scaling -------------------------------------------------------
    def resize(self, num_consumers: int) -> None:
        """Elastically change the consumer decomposition (readers untouched)."""
        cur = len(self.consumers)
        if num_consumers > cur:
            self.consumers.extend(
                self.ck.make_client(pe=i % self.ck.sched.num_pes)
                for i in range(cur, num_consumers)
            )
        else:
            # Deregister before dropping: a shrunk consumer must not stay in
            # the migration manager's table (shrink→grow cycles would leak
            # one registered id per dropped consumer).
            for c in self.consumers[num_consumers:]:
                c.deregister()
            del self.consumers[num_consumers:]
        self.num_consumers = num_consumers

    def migrate_consumer(self, idx: int, new_pe: int) -> None:
        self.consumers[idx].migrate(new_pe)

    # -- split-phase step input --------------------------------------------------
    def start_step(self, step: int) -> None:
        """Kick off the read session + consumer reads for ``step`` (async)."""
        with self._lock:
            if step in self._bufs or step >= self.num_steps:
                return
            buf = _StepBuffer(step=step)
            self._bufs[step] = buf

        start_row, num_rows = window_rows(step, self.global_batch, self.seq_len)
        # Remainder final window (drop_remainder=False): clamp to the file.
        num_rows = min(num_rows, self.meta.num_rows - start_row)
        abs_off, nbytes = self.meta.byte_range_for_rows(start_row, num_rows)
        buf.abs_off, buf.nbytes, buf.num_rows = abs_off, nbytes, num_rows
        mv: Optional[memoryview] = None
        if not self.zero_copy:
            buf.arena = np.empty(num_rows, dtype=self.meta.dtype)
            mv = memoryview(buf.arena).cast("B")

        def on_session(session: Session) -> None:
            buf.session = session
            # Consumers collectively read disjoint slices of the window.
            n = self.num_consumers
            per = (nbytes + n - 1) // n
            itemsize = self.meta.itemsize
            per -= per % itemsize  # keep element alignment
            per = max(per, itemsize)
            plans = []
            pos = 0
            while pos < nbytes:
                take = min(per, nbytes - pos)
                plans.append((pos, take))
                pos += take
            buf.outstanding = len(plans)

            def make_done():
                def done(_msg) -> None:
                    with self._lock:
                        buf.outstanding -= 1
                        if buf.outstanding == 0:
                            buf.ready.set(buf)

                return done

            for i, (rel_off, take) in enumerate(plans):
                client = self.consumers[i % len(self.consumers)]
                if mv is None:
                    # zero-copy mode: residency signal only — get_batch
                    # takes one whole-window arena view itself.
                    self.ck.read_notify(
                        session,
                        take,
                        abs_off + rel_off,
                        client.callback(make_done()),
                        client=client,
                    )
                else:
                    self.ck.read(
                        session,
                        take,
                        abs_off + rel_off,
                        mv[rel_off : rel_off + take],
                        client.callback(make_done()),
                        client=client,
                    )

        self.ck.start_read_session(
            self.file,
            nbytes,
            abs_off,
            CkCallback(on_session, inline=True),
            consumer_pes=[c.pe for c in self.consumers],
        )

    def _close_retired(self) -> None:
        with self._lock:
            retired, self._retired = self._retired, []
            staged, self._staged = self._staged, []
        for st in staged:
            # The step's one host→device transfer may still be in flight;
            # the arena (and our host refs) must outlive it. Block, then
            # drop the references so the borrow can actually be released.
            # A failed transfer propagates (the device array is unusable
            # and silence would let ingest counters claim success); the
            # host refs are dropped either way — a failed transfer does
            # not need the arena.
            try:
                st.staged.block_until_ready()
            finally:
                st.host_tokens = None
                st.staged = None
        for sess in retired:
            # Invalidate borrows inline (idempotent — close_session repeats
            # it) so the lifetime contract is "valid until the next
            # get_batch*", not "until some later scheduler pump"; the
            # split-phase session close itself stays off the critical path.
            sess.readers.invalidate_borrows()
            self.ck.close_read_session(sess)

    def _wait_step(self, step: int, timeout: float) -> _StepBuffer:
        if step >= self.num_steps:
            raise IndexError(f"step {step} >= {self.num_steps}")
        self.start_step(step)  # no-op if already started
        buf = self._bufs[step]
        buf.ready.wait(self.ck.sched, timeout=timeout)
        # Launch the lookahead before handing the batch to the trainer.
        self.start_step(step + self.prefetch_depth)
        with self._lock:
            self._bufs.pop(step, None)
        return buf

    def _window_tokens(self, buf: _StepBuffer):
        """Whole-window tokens (and the borrowed arena view backing them,
        zero-copy mode only). Retires the *previous* step first."""
        view: Optional[memoryview] = None
        if self.zero_copy:
            # Previous step's batch has been consumed by now — retire its
            # session (which invalidates its borrowed views).
            self._close_retired()
            assert buf.session is not None
            view = buf.session.readers.borrow_view(buf.abs_off, buf.nbytes)
            tokens = np.frombuffer(view, dtype=self.meta.dtype)
            with self._lock:
                self._retired.append(buf.session)
        else:
            self._close_retired()     # release any pending device-step refs
            if buf.session is not None:
                self.ck.close_read_session(buf.session)
            tokens = buf.arena
            assert tokens is not None
        if tokens.dtype == np.uint32:
            tokens = tokens.view(np.int32)   # zero-copy reinterpret
        return tokens, view

    def get_batch(self, step: int, timeout: float = 300.0) -> Tuple[np.ndarray, np.ndarray]:
        """Blocking (scheduler-pumping) fetch of step ``step``; prefetches
        ``step + prefetch_depth`` before returning (the overlap).

        In zero-copy mode the returned arrays alias the step's session arena
        and remain valid until the next ``get_batch*``/``close`` call."""
        buf = self._wait_step(step, timeout)
        tokens, _ = self._window_tokens(buf)
        inputs, labels = batch_from_tokens(
            tokens, self.global_batch, self.seq_len,
            allow_partial=not self.drop_remainder, pad_id=self.pad_id,
        )
        # Host-side phase-2 permutation: the window passes through host
        # reshaping/marshalling on its way to the device.
        self.ingest.record_host_step(buf.nbytes)
        return inputs, labels

    def get_batch_device(
        self,
        step: int,
        sharding=None,
        *,
        use_pallas: Optional[bool] = None,
        timeout: float = 300.0,
    ):
        """Device-ingest fetch: one ``device_put`` of the whole-window arena
        view, then on-device batch-major reassembly (fused label shift +
        remainder padding). Returns JAX device arrays ``(inputs, labels)``.

        See the module docstring for the staged-buffer lifetime contract.
        ``sharding`` is forwarded to ``device_put`` for the staged window;
        ``use_pallas`` picks the gather backend (default: Pallas on TPU,
        XLA reference elsewhere)."""
        import jax

        from repro.kernels import ops

        buf = self._wait_step(step, timeout)
        tokens, view = self._window_tokens(buf)
        itemsize = self.meta.itemsize
        valid_tokens = buf.nbytes // itemsize
        # The step's single host→device transfer (sharding=None → default
        # device placement).
        staged = jax.device_put(tokens, sharding)
        if self.zero_copy:
            with self._lock:
                # borrow_view in _window_tokens appended the session; the
                # staged refs pin arena + transfer until the next call.
                self._staged.append(_StagedStep(
                    staged=staged,
                    host_tokens=tokens,
                    host_view=view,
                ))
        inputs, labels = ops.device_ingest(
            staged,
            None,                       # arena view is file-order
            global_batch=self.global_batch,
            seq_len=self.seq_len,
            valid_tokens=valid_tokens,
            pad_id=self.pad_id,
            use_pallas=use_pallas,
        )
        # Copy mode still pays the session→step-arena host copy before
        # staging; only the zero-copy path truly has 0 host bytes.
        self.ingest.record_device_step(
            buf.nbytes, host_bytes=0 if self.zero_copy else buf.nbytes)
        return inputs, labels

    def idle(self, seconds: float) -> int:
        """Pump pipeline tasks for ``seconds`` (call while the device step
        runs) — the Charm++ idle-PE behaviour that makes prefetch overlap
        real. Returns tasks processed."""
        import time as _time

        return self.ck.sched.pump_until_deadline(_time.monotonic() + seconds)

    def __iter__(self):
        for s in range(self._next_step, self.num_steps):
            yield self.get_batch(s)

    # -- device hand-off ---------------------------------------------------------
    @staticmethod
    def to_device(inputs: np.ndarray, labels: np.ndarray, sharding=None):
        import jax

        if sharding is None:
            return jax.device_put(inputs), jax.device_put(labels)
        return jax.device_put(inputs, sharding), jax.device_put(labels, sharding)

    def close(self) -> None:
        self._close_retired()
        # Flush queued session starts, then join every reader thread of this
        # file before the fd goes away — an in-flight prefetch session must
        # not pread a closed file (shutdown is off the hot path; the pump
        # here is what makes close deterministic).
        self.ck.pump()
        stopped = True
        for sess in list(self.ck.director.sessions.values()):
            if sess.file is self.file:
                stopped &= sess.readers.stop()
        for buf in list(self._bufs.values()):
            if buf.session is not None:
                self.ck.close_read_session(buf.session)
        if not stopped:
            # A straggling reader may still pread this fd; closing it now
            # risks EBADF or — after fd reuse — reading the wrong file.
            # Leak the fd and fail loud instead.
            raise RuntimeError(
                "pipeline close: reader thread(s) still running after stop "
                "timeout; file left open")
        self.ck.close_sync(self.file)
