"""Binary token / embedding file format.

One header page (4096 B, JSON + padding) followed by raw row-major array
bytes. Sequential data layout, as the paper assumes ("a sequential
organization of data in the file, which is typical for ... computational
astronomy and graph algorithms") — here: flat token streams for LMs and flat
frame/patch embedding matrices for the audio/VLM frontend stubs.

The format is deliberately seek-friendly: element i lives at
``DATA_OFFSET + i * itemsize`` so read sessions can map element ranges to
byte ranges with pure arithmetic.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Tuple

import numpy as np

MAGIC = "CKIO-TOKENS-v1"
HEADER_BYTES = 4096


@dataclass(frozen=True)
class TokenFileMeta:
    dtype: np.dtype
    shape: Tuple[int, ...]

    @property
    def itemsize(self) -> int:
        return self.dtype.itemsize

    @property
    def row_bytes(self) -> int:
        """Bytes per leading-dim element (token or embedding row)."""
        inner = int(np.prod(self.shape[1:], dtype=np.int64)) if len(self.shape) > 1 else 1
        return inner * self.itemsize

    @property
    def num_rows(self) -> int:
        return self.shape[0]

    @property
    def data_offset(self) -> int:
        return HEADER_BYTES

    @property
    def data_bytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * self.itemsize

    def byte_range_for_rows(self, start_row: int, num_rows: int) -> Tuple[int, int]:
        """(absolute_offset, nbytes) covering rows [start_row, start_row+num_rows)."""
        if start_row < 0 or start_row + num_rows > self.num_rows:
            raise ValueError(
                f"rows [{start_row}, {start_row+num_rows}) out of bounds "
                f"(file has {self.num_rows})"
            )
        return (
            self.data_offset + start_row * self.row_bytes,
            num_rows * self.row_bytes,
        )


def write_token_file(path: str, array: np.ndarray) -> TokenFileMeta:
    meta = {
        "magic": MAGIC,
        "dtype": array.dtype.str,
        "shape": list(array.shape),
    }
    blob = json.dumps(meta).encode()
    if len(blob) > HEADER_BYTES - 1:
        raise ValueError("header too large")
    header = blob + b"\x00" * (HEADER_BYTES - len(blob))
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "wb") as f:
        f.write(header)
        f.write(np.ascontiguousarray(array).tobytes())
    return TokenFileMeta(dtype=array.dtype, shape=tuple(array.shape))


def read_meta(path: str) -> TokenFileMeta:
    """Parse the 4096-byte header page; every corruption mode raises a
    descriptive ``ValueError`` naming the path (a torn header must not
    surface as a raw ``json``/``KeyError`` deep inside a session open)."""
    with open(path, "rb") as f:
        head = f.read(HEADER_BYTES)
    if len(head) < HEADER_BYTES:
        raise ValueError(
            f"{path}: truncated token-file header "
            f"({len(head)} of {HEADER_BYTES} bytes)")
    blob = head.split(b"\x00", 1)[0]
    try:
        meta = json.loads(blob)
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ValueError(
            f"{path}: corrupt token-file header (not parseable JSON: {e})"
        ) from e
    if not isinstance(meta, dict) or meta.get("magic") != MAGIC:
        raise ValueError(f"{path}: not a {MAGIC} file")
    try:
        dtype = np.dtype(meta["dtype"])
        shape = tuple(int(d) for d in meta["shape"])
    except (KeyError, TypeError, ValueError) as e:
        raise ValueError(
            f"{path}: corrupt token-file header (bad dtype/shape field: {e})"
        ) from e
    if not shape or any(d < 0 for d in shape):
        raise ValueError(f"{path}: corrupt token-file header (shape {shape})")
    return TokenFileMeta(dtype=dtype, shape=shape)


def decode_rows(meta: TokenFileMeta, buf, start_row: int, num_rows: int) -> np.ndarray:
    """Reinterpret raw session bytes as rows (zero-copy ``np.frombuffer``)."""
    arr = np.frombuffer(buf, dtype=meta.dtype, count=num_rows * (meta.row_bytes // meta.itemsize))
    if len(meta.shape) > 1:
        arr = arr.reshape((num_rows,) + meta.shape[1:])
    return arr
