"""Multi-file sharded corpora: the ``FileSet`` manifest.

Production corpora are thousands of token-file shards, not one large file.
A ``FileSet`` is an ordered manifest over N ``tokenfile.py`` shards that
presents them as ONE logical token file with **global row addressing**:

* the global *row* space is the concatenation of the shards' leading
  dimensions (shard k's rows follow shard k-1's);
* the global *byte* space is the concatenation of the shards' data regions
  — header pages excluded — starting at offset 0. Because manifest
  validation pins one dtype and one inner shape across every shard, a row
  is ``row_bytes`` everywhere and global byte offset = row * row_bytes with
  no per-shard arithmetic. Windows freely straddle shard boundaries;
  :meth:`FileSet.shard_ranges_for_rows` resolves them to per-shard file
  ranges (the NumPy-concat oracle the property tests check against).

The byte space is made physical by ``io/posix.py``'s ``ShardedFile`` (built
via :meth:`FileSet.sharded_file`): a ``PosixFile``-compatible handle whose
``pread`` dispatches global offsets to the right shard fd. Everything above
— stripe planning (with ``hard_bounds`` pinned to shard starts so no stripe
spans a shard), buffer readers, borrowed views, the shm worker drain —
works unchanged; ``CkIO.open_fileset`` / ``CkIOPipeline(FileSet(...))`` are
the entry points.

Validation happens at manifest build time, not at first read: mismatched
dtype or inner shape, a torn header (``read_meta`` raises a descriptive
``ValueError`` naming the path) and a truncated shard *body* (file shorter
than header + data bytes) all fail ``FileSet.build`` immediately. Empty
shards (zero rows) are legal and occupy no byte space.
"""
from __future__ import annotations

import os
from bisect import bisect_right
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.data.tokenfile import (
    HEADER_BYTES,
    TokenFileMeta,
    read_meta,
    write_token_file,
)
from repro.io.posix import ShardedFile


@dataclass(frozen=True)
class ShardInfo:
    """One shard's position in the global row / byte spaces."""

    index: int            # position in the manifest (stable shard id)
    path: str
    meta: TokenFileMeta
    row_start: int        # first global row this shard holds
    byte_start: int       # first global *data* byte this shard holds

    @property
    def num_rows(self) -> int:
        return self.meta.num_rows

    @property
    def row_end(self) -> int:
        return self.row_start + self.meta.num_rows

    @property
    def data_bytes(self) -> int:
        return self.meta.data_bytes

    @property
    def byte_end(self) -> int:
        return self.byte_start + self.meta.data_bytes


class FileSet:
    """Ordered manifest over N token-file shards, addressable as one file.

    Exposes the ``TokenFileMeta`` surface (``dtype``, ``shape``,
    ``itemsize``, ``row_bytes``, ``num_rows``, ``data_bytes``,
    ``byte_range_for_rows``) so callers like ``CkIOPipeline`` treat a
    FileSet exactly like a single file's meta — except offsets live in the
    global data byte space (``data_offset == 0``; there is no header page
    in the logical file).
    """

    def __init__(self, shards: Sequence[ShardInfo]):
        if not shards:
            raise ValueError("FileSet needs at least one shard")
        self.shards: Tuple[ShardInfo, ...] = tuple(shards)
        first = self.shards[0].meta
        self._dtype = first.dtype
        self._inner = tuple(first.shape[1:])
        self._row_starts = tuple(s.row_start for s in self.shards)
        last = self.shards[-1]
        self._total_rows = last.row_end
        self._total_bytes = last.byte_end

    # -- construction ------------------------------------------------------
    @classmethod
    def build(cls, paths: Sequence[str]) -> "FileSet":
        """Read every shard's header, validate consistency, build the manifest.

        Raises ``ValueError`` naming the offending path on: torn/corrupt
        header (via ``read_meta``), dtype or inner-shape mismatch vs shard
        0, or a shard file too short to hold its declared data region.
        """
        if not paths:
            raise ValueError("FileSet.build: empty path list")
        shards: List[ShardInfo] = []
        row, byte = 0, 0
        ref: Optional[TokenFileMeta] = None
        for i, p in enumerate(paths):
            meta = read_meta(p)
            if ref is None:
                ref = meta
            else:
                if meta.dtype != ref.dtype:
                    raise ValueError(
                        f"{p}: shard dtype {meta.dtype} != fileset dtype "
                        f"{ref.dtype} (from {paths[0]})")
                if tuple(meta.shape[1:]) != tuple(ref.shape[1:]):
                    raise ValueError(
                        f"{p}: shard inner shape {tuple(meta.shape[1:])} != "
                        f"fileset inner shape {tuple(ref.shape[1:])} "
                        f"(from {paths[0]})")
            need = HEADER_BYTES + meta.data_bytes
            have = os.path.getsize(p)
            if have < need:
                raise ValueError(
                    f"{p}: truncated shard body ({have} bytes on disk, "
                    f"header declares {need})")
            shards.append(ShardInfo(
                index=i, path=str(p), meta=meta,
                row_start=row, byte_start=byte))
            row += meta.num_rows
            byte += meta.data_bytes
        return cls(shards)

    # -- TokenFileMeta-compatible surface ---------------------------------
    @property
    def dtype(self) -> np.dtype:
        return self._dtype

    @property
    def shape(self) -> Tuple[int, ...]:
        return (self._total_rows,) + self._inner

    @property
    def itemsize(self) -> int:
        return self._dtype.itemsize

    @property
    def row_bytes(self) -> int:
        inner = int(np.prod(self._inner, dtype=np.int64)) if self._inner else 1
        return inner * self.itemsize

    @property
    def num_rows(self) -> int:
        return self._total_rows

    @property
    def data_offset(self) -> int:
        """The logical file has no header page: global byte 0 is row 0."""
        return 0

    @property
    def data_bytes(self) -> int:
        return self._total_bytes

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def paths(self) -> Tuple[str, ...]:
        return tuple(s.path for s in self.shards)

    def byte_range_for_rows(self, start_row: int, num_rows: int) -> Tuple[int, int]:
        """(global_offset, nbytes) covering rows [start_row, start_row+num_rows).

        Offsets are in the global data byte space — uniform ``row_bytes``
        makes this pure arithmetic even across shard boundaries.
        """
        if start_row < 0 or start_row + num_rows > self._total_rows:
            raise ValueError(
                f"rows [{start_row}, {start_row + num_rows}) out of bounds "
                f"(fileset has {self._total_rows})")
        return (start_row * self.row_bytes, num_rows * self.row_bytes)

    # -- shard resolution --------------------------------------------------
    def shard_of_row(self, row: int) -> int:
        """Shard index holding global ``row`` (skips empty shards)."""
        if row < 0 or row >= self._total_rows:
            raise ValueError(f"row {row} out of bounds ({self._total_rows})")
        i = bisect_right(self._row_starts, row) - 1
        # row_starts repeat across empty shards; walk to the holder.
        while self.shards[i].num_rows == 0:
            i += 1
        return i

    def shard_of_byte(self, global_off: int) -> int:
        """Shard index holding global data byte ``global_off``."""
        if global_off < 0 or global_off >= self._total_bytes:
            raise ValueError(
                f"byte {global_off} out of bounds ({self._total_bytes})")
        return self.shard_of_row(global_off // self.row_bytes)

    def shard_ranges_for_rows(
        self, start_row: int, num_rows: int
    ) -> List[Tuple[int, int, int]]:
        """Resolve a (possibly shard-straddling) row window to per-shard
        file ranges: ``[(shard_index, file_offset, nbytes), ...]`` in global
        row order — what a reader actually preads from each shard file.
        """
        self.byte_range_for_rows(start_row, num_rows)   # bounds check
        out: List[Tuple[int, int, int]] = []
        row, end = start_row, start_row + num_rows
        while row < end:
            i = self.shard_of_row(row)
            sh = self.shards[i]
            take = min(end, sh.row_end) - row
            off, nb = sh.meta.byte_range_for_rows(row - sh.row_start, take)
            out.append((i, off, nb))
            row += take
        return out

    def shard_bounds_in(self, offset: int, nbytes: int) -> List[int]:
        """Interior shard-start byte offsets strictly inside
        ``(offset, offset + nbytes)`` of the global space."""
        end = offset + nbytes
        return [s.byte_start for s in self.shards[1:]
                if s.meta.num_rows and offset < s.byte_start < end]

    # -- physical handle ---------------------------------------------------
    def segments(self) -> Tuple[Tuple[str, int, int, int, int], ...]:
        """Picklable ``ShardedFile`` segment table (empty shards omitted,
        their indices reserved): (path, global_start, file_base, nbytes,
        shard_id)."""
        return tuple(
            (s.path, s.byte_start, HEADER_BYTES, s.data_bytes, s.index)
            for s in self.shards if s.data_bytes > 0)

    def sharded_file(self, *, direct_io: bool = False) -> ShardedFile:
        """Open one ``ShardedFile`` over the manifest's byte space.

        ``direct_io`` opens every shard O_DIRECT (io/posix.py: shard data
        regions must sit on the filesystem block grid or this raises
        ``DirectIOError`` naming the offenders — never a silent fallback)."""
        return ShardedFile(self.segments(), direct_io=direct_io)

    def describe(self) -> str:
        return (f"fileset[{self.num_shards} shards, {self._total_rows} rows, "
                f"{self._total_bytes} B]: {self.shards[0].path} .. "
                f"{self.shards[-1].path}")

    def __repr__(self) -> str:  # pragma: no cover - debugging sugar
        return f"FileSet({self.describe()})"


def write_token_shards(
    directory: str,
    array: np.ndarray,
    row_counts: Sequence[int],
    prefix: str = "shard",
) -> List[str]:
    """Split ``array`` row-wise into shard files (tests / benchmarks).

    ``row_counts`` must sum to ``len(array)``; zero counts produce legal
    empty shards. Returns the ordered shard paths.
    """
    if sum(int(c) for c in row_counts) != len(array):
        raise ValueError(
            f"row_counts sum {sum(row_counts)} != array rows {len(array)}")
    os.makedirs(directory, exist_ok=True)
    paths: List[str] = []
    row = 0
    for i, c in enumerate(int(c) for c in row_counts):
        p = os.path.join(directory, f"{prefix}_{i:05d}.bin")
        write_token_file(p, array[row: row + c])
        paths.append(p)
        row += c
    return paths
