"""Sequence packing / label construction for LM training batches.

Also home of the **device-ingest index maps**: the arrival-order →
consumer-order permutation the CkIO paper performs in host DRAM (phase 2,
§V-B) is described here as a NumPy index map built from ``io/layout.py``
piece plans, then *executed on device* by ``kernels/reassemble.py``. The map
construction is pure and property-tested; the hot path builds it once per
step from host metadata (never touching token bytes).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np


def window_rows(step: int, global_batch: int, seq_len: int) -> Tuple[int, int]:
    """Token rows needed for training step ``step``.

    Each step consumes ``global_batch`` sequences of ``seq_len + 1`` tokens
    (inputs + shifted labels share the window). Returns (start_row, num_rows).
    """
    rows_per_step = global_batch * (seq_len + 1)
    return step * rows_per_step, rows_per_step


def batch_from_tokens(
    tokens: np.ndarray,
    global_batch: int,
    seq_len: int,
    *,
    allow_partial: bool = False,
    pad_id: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Flat token window -> (inputs, labels), both (global_batch, seq_len).

    ``allow_partial=True`` pads a short final window with ``pad_id`` (one
    host copy, remainder windows only); the full-window path stays
    zero-copy.
    """
    need = global_batch * (seq_len + 1)
    if tokens.size < need:
        if not allow_partial:
            raise ValueError(f"window too small: {tokens.size} < {need}")
        padded = np.full(need, pad_id, dtype=tokens.dtype)
        padded[: tokens.size] = tokens
        tokens = padded
    seqs = tokens[:need].reshape(global_batch, seq_len + 1)
    # views, not copies: device_put handles strided arrays, and the extra
    # 2x window copies measurably serialize the host pipeline on weak hosts
    return seqs[:, :-1], seqs[:, 1:]


def token_gather_from_pieces(
    pieces: Sequence[Tuple[int, int]],
    session_abs_off: int,
    itemsize: int,
) -> np.ndarray:
    """Arrival-order→file-order token index map from a piece plan.

    ``pieces`` is ``[(abs_off, nbytes), ...]`` in **arrival (staged) order**
    — e.g. ``zip(plan.splinters, session.arrival_order)`` or coalesced
    pieces from ``pieces_for_range`` — jointly covering the session
    ``[session_abs_off, session_abs_off + sum(nbytes))`` exactly once. The
    staged buffer is their concatenation in that order.

    Returns ``g`` (int32, one entry per session token): ``g[p]`` is the
    staged position of file-order token ``p``, i.e. ``staged[g] ==
    session_tokens``. Raises ``ValueError`` on overlap, gaps, or byte
    ranges not aligned to ``itemsize``.
    """
    total = sum(nb for _, nb in pieces)
    if total % itemsize:
        raise ValueError(f"pieces cover {total} bytes, not a multiple of "
                         f"itemsize {itemsize}")
    num_tokens = total // itemsize
    g = np.full(num_tokens, -1, dtype=np.int64)
    staged_pos = 0
    for abs_off, nbytes in pieces:
        if abs_off % itemsize or nbytes % itemsize:
            raise ValueError(
                f"piece [{abs_off}, {abs_off + nbytes}) not aligned to "
                f"itemsize {itemsize}")
        t0 = (abs_off - session_abs_off) // itemsize
        nt = nbytes // itemsize
        if t0 < 0 or t0 + nt > num_tokens:
            raise ValueError(
                f"piece [{abs_off}, {abs_off + nbytes}) outside session")
        if np.any(g[t0 : t0 + nt] >= 0):
            raise ValueError("overlapping pieces in arrival plan")
        g[t0 : t0 + nt] = staged_pos + np.arange(nt, dtype=np.int64)
        staged_pos += nt
    if np.any(g < 0):  # pragma: no cover - overlap+total checks imply this
        raise ValueError("piece plan leaves session gaps")
    return g.astype(np.int32)


def as_block_permutation(
    g: np.ndarray, block_tokens: int
) -> Optional[np.ndarray]:
    """Recognize a token gather map as a uniform block permutation.

    If ``g`` (from ``token_gather_from_pieces``) satisfies
    ``g[p] = perm[p // T] * T + p % T`` for ``T = block_tokens`` — i.e. the
    staged buffer is a permutation of equal ``T``-token blocks — return
    ``perm`` (int32, file-order block → staged block), which is exactly the
    scalar-prefetch operand of the block-gather kernel. Return ``None``
    when the layout is not block-uniform (the token-level path applies).
    """
    n = g.shape[0]
    T = block_tokens
    if T <= 0 or n % T:
        return None
    blocks = g.reshape(n // T, T)
    base = blocks[:, 0]
    if np.any(base % T):
        return None
    if np.any(blocks != base[:, None] + np.arange(T, dtype=g.dtype)[None, :]):
        return None
    return (base // T).astype(np.int32)


def row_gather_index(
    g: np.ndarray,
    *,
    global_batch: int,
    seq_len: int,
    window_tok_off: int = 0,
    valid_tokens: Optional[int] = None,
) -> np.ndarray:
    """Per-row token index map for ``reassemble_tokens_pallas``.

    ``g`` maps file-order session tokens to staged positions; the window
    starts ``window_tok_off`` tokens into the session and holds
    ``valid_tokens`` real tokens (≤ ``global_batch * (seq_len + 1)``;
    remainder final windows). Returns ``(B, S+1)`` int32 — entry
    ``[b, j]`` is the staged position of window flat token
    ``b*(S+1) + j``, or ``-1`` where the window (or session) ends.
    Column ``S`` (the row's last token) only feeds the shifted labels.
    """
    B, S = global_batch, seq_len
    S1 = S + 1
    if valid_tokens is None:
        valid_tokens = B * S1
    flat = (window_tok_off
            + np.arange(B, dtype=np.int64)[:, None] * S1
            + np.arange(S1, dtype=np.int64)[None, :])
    ok = (flat < window_tok_off + valid_tokens) & (flat < g.shape[0])
    out = np.full(flat.shape, -1, dtype=np.int32)
    out[ok] = g[flat[ok]]
    return out


def pieces_in_arrival_order(
    splinters, arrival_order: Sequence[int]
) -> List[Tuple[int, int]]:
    """``(abs_off, nbytes)`` pieces for a session staged by splinter arrival.

    ``splinters`` is ``plan.splinters`` (file order, indexed by global
    splinter id); ``arrival_order`` is ``session.arrival_order`` — the
    completion order the reader layer records. The result feeds
    ``token_gather_from_pieces``.
    """
    by_index = {s.index: s for s in splinters}
    return [(by_index[i].offset, by_index[i].nbytes) for i in arrival_order]


def pack_documents(
    doc_tokens: list, seq_len: int, eos_id: int, pad_id: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Greedy document packing into fixed-length rows with segment ids.

    Returns (packed (N, seq_len), segment_ids (N, seq_len)). Segment ids
    let attention mask out cross-document positions; unused slots get
    segment id 0 (= padding).
    """
    rows, segs = [], []
    cur, cur_seg, seg_idx = [], [], 1
    for doc in doc_tokens:
        toks = list(doc) + [eos_id]
        while toks:
            space = seq_len - len(cur)
            take = toks[:space]
            cur.extend(take)
            cur_seg.extend([seg_idx] * len(take))
            toks = toks[space:]
            if len(cur) == seq_len:
                rows.append(cur)
                segs.append(cur_seg)
                cur, cur_seg = [], []
                seg_idx += 1 if toks else 0
        seg_idx += 1
    if cur:
        pad = seq_len - len(cur)
        rows.append(cur + [pad_id] * pad)
        segs.append(cur_seg + [0] * pad)
    return np.asarray(rows, dtype=np.int32), np.asarray(segs, dtype=np.int32)
