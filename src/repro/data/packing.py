"""Sequence packing / label construction for LM training batches."""
from __future__ import annotations

from typing import Tuple

import numpy as np


def window_rows(step: int, global_batch: int, seq_len: int) -> Tuple[int, int]:
    """Token rows needed for training step ``step``.

    Each step consumes ``global_batch`` sequences of ``seq_len + 1`` tokens
    (inputs + shifted labels share the window). Returns (start_row, num_rows).
    """
    rows_per_step = global_batch * (seq_len + 1)
    return step * rows_per_step, rows_per_step


def batch_from_tokens(
    tokens: np.ndarray, global_batch: int, seq_len: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Flat token window -> (inputs, labels), both (global_batch, seq_len)."""
    need = global_batch * (seq_len + 1)
    if tokens.size < need:
        raise ValueError(f"window too small: {tokens.size} < {need}")
    seqs = tokens[:need].reshape(global_batch, seq_len + 1)
    # views, not copies: device_put handles strided arrays, and the extra
    # 2x window copies measurably serialize the host pipeline on weak hosts
    return seqs[:, :-1], seqs[:, 1:]


def pack_documents(
    doc_tokens: list, seq_len: int, eos_id: int, pad_id: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Greedy document packing into fixed-length rows with segment ids.

    Returns (packed (N, seq_len), segment_ids (N, seq_len)). Segment ids
    let attention mask out cross-document positions; unused slots get
    segment id 0 (= padding).
    """
    rows, segs = [], []
    cur, cur_seg, seg_idx = [], [], 1
    for doc in doc_tokens:
        toks = list(doc) + [eos_id]
        while toks:
            space = seq_len - len(cur)
            take = toks[:space]
            cur.extend(take)
            cur_seg.extend([seg_idx] * len(take))
            toks = toks[space:]
            if len(cur) == seq_len:
                rows.append(cur)
                segs.append(cur_seg)
                cur, cur_seg = [], []
                seg_idx += 1 if toks else 0
        seg_idx += 1
    if cur:
        pad = seq_len - len(cur)
        rows.append(cur + [pad_id] * pad)
        segs.append(cur_seg + [0] * pad)
    return np.asarray(rows, dtype=np.int32), np.asarray(segs, dtype=np.int32)
