"""Synthetic dataset generators for examples, tests, and benchmarks."""
from __future__ import annotations

import numpy as np

from repro.data.tokenfile import TokenFileMeta, write_token_file


def make_token_file(
    path: str, num_tokens: int, vocab_size: int, seed: int = 0,
    dtype=np.uint32,
) -> TokenFileMeta:
    """Deterministic flat token stream (the LM training corpus)."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, vocab_size, size=(num_tokens,), dtype=np.uint32)
    return write_token_file(path, toks.astype(dtype))


def make_embedding_file(
    path: str, num_rows: int, d_model: int, seed: int = 0, dtype=np.float32
) -> TokenFileMeta:
    """Precomputed frame/patch embeddings (the VLM/audio frontend stubs)."""
    rng = np.random.default_rng(seed)
    emb = rng.standard_normal((num_rows, d_model)).astype(dtype) * 0.02
    return write_token_file(path, emb)


def make_opaque_file(path: str, nbytes: int, seed: int = 0) -> None:
    """Raw bytes for the I/O microbenchmarks (paper Figs. 1/2/4/7)."""
    rng = np.random.default_rng(seed)
    import os

    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    chunk = 16 * 1024 * 1024
    with open(path, "wb") as f:
        left = nbytes
        while left > 0:
            n = min(chunk, left)
            f.write(rng.integers(0, 256, size=n, dtype=np.uint8).tobytes())
            left -= n
