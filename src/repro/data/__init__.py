"""Training data pipeline built on CkIO read sessions."""
from repro.data.tokenfile import (
    TokenFileMeta,
    write_token_file,
    read_meta,
    decode_rows,
)
from repro.data.packing import (
    as_block_permutation,
    batch_from_tokens,
    pack_documents,
    pieces_in_arrival_order,
    row_gather_index,
    token_gather_from_pieces,
    window_rows,
)
from repro.data.fileset import FileSet, ShardInfo, write_token_shards
from repro.data.pipeline import CkIOPipeline, device_token_spans
from repro.data.synthetic import (
    make_embedding_file,
    make_opaque_file,
    make_token_file,
)

__all__ = [
    "TokenFileMeta",
    "write_token_file",
    "read_meta",
    "decode_rows",
    "as_block_permutation",
    "batch_from_tokens",
    "pack_documents",
    "pieces_in_arrival_order",
    "row_gather_index",
    "token_gather_from_pieces",
    "window_rows",
    "FileSet",
    "ShardInfo",
    "write_token_shards",
    "CkIOPipeline",
    "device_token_spans",
    "make_embedding_file",
    "make_opaque_file",
    "make_token_file",
]
