"""Split-phase primitives: CkCallback and CkFuture.

The paper's API is callback-centric (§III-D): every CkIO operation takes a
``CkCallback`` which the runtime *enqueues as a task* on a target PE when the
operation completes. ``CkCallback`` here supports three target kinds:

  * a fixed PE (paper: callback to a processor),
  * a *virtual proxy* (paper: callback to a migratable chare — resolved to the
    chare's **current** PE at delivery time, which is what makes reads survive
    migration, §IV-A.3),
  * inline (tests only).

``CkFuture`` is a thin completion handle built on CkCallback for pythonic
call-sites (examples, data pipeline); `.wait(sched)` pumps the scheduler, it
never blocks a PE.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Optional, TYPE_CHECKING

from repro.core.scheduler import TaskScheduler

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.migration import LocationManager, VirtualProxy


class CkCallback:
    """A continuation delivered as a scheduled task."""

    def __init__(
        self,
        fn: Callable[..., Any],
        *,
        pe: Optional[int] = None,
        proxy: Optional["VirtualProxy"] = None,
        inline: bool = False,
        drop_stale: bool = False,
    ):
        if sum(x is not None for x in (pe, proxy)) + int(inline) != 1:
            raise ValueError("exactly one of pe=, proxy=, inline=True required")
        if drop_stale and proxy is None:
            raise ValueError("drop_stale requires proxy routing")
        self.fn = fn
        self.pe = pe
        self.proxy = proxy
        self.inline = inline
        # Proxy-routed only: a deregistered target drops the delivery
        # (counted) instead of falling back to the home PE — the contract
        # for streamed splinter events, which must never chase a retired
        # consumer onto a reused slot.
        self.drop_stale = drop_stale

    def send(self, sched: TaskScheduler, *args: Any) -> None:
        """Deliver the callback (enqueue, never call inline unless asked)."""
        if self.inline:
            self.fn(*args)
            return
        if self.proxy is not None:
            # Late-bound: route to wherever the chare lives *now* (home-PE
            # fallback — or a counted drop for drop_stale callbacks — if it
            # was deregistered by an elastic shrink mid-read).
            if self.drop_stale:
                pe = self.proxy.delivery_pe_or_drop()
                if pe is None:
                    return
            else:
                pe = self.proxy.delivery_pe()
            sched.enqueue(pe, self.fn, *args, label="cb@proxy")
        else:
            sched.enqueue(self.pe, self.fn, *args, label="cb@pe")


class CkFuture:
    """Completion handle; thread-safe set(), scheduler-pumping wait()."""

    __slots__ = ("_event", "_value", "_error")

    def __init__(self) -> None:
        self._event = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None

    def set(self, value: Any = None) -> None:
        self._value = value
        self._event.set()

    def set_error(self, err: BaseException) -> None:
        self._error = err
        self._event.set()

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def value(self) -> Any:
        if not self._event.is_set():
            raise RuntimeError("future not ready")
        if self._error is not None:
            raise self._error
        return self._value

    def wait(self, sched: TaskScheduler, *, timeout: float = 60.0) -> Any:
        """Pump the scheduler until this future resolves."""
        sched.run_until(lambda: self._event.is_set(), timeout=timeout)
        return self.value()

    def as_callback(self) -> CkCallback:
        return CkCallback(lambda v=None: self.set(v), inline=True)
