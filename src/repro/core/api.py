"""CkIO public API — the paper's §III-D interface, adapted to Python/JAX.

The five split-phase operations mirror the paper exactly:

    ckio.open(name, opened_cb, opts)            Ck::IO::open
    ckio.start_read_session(file, bytes,
                            offset, ready_cb)   Ck::IO::startReadSession
    ckio.read(session, bytes, offset,
              data, after_read_cb)              Ck::IO::read
    ckio.close_read_session(session, cb)        Ck::IO::closeReadSession
    ckio.close(file, cb)                        Ck::IO::close

Every callback is *enqueued as a task* on its target PE (or routed through a
migratable client's virtual proxy) — no operation blocks a PE. Futures-based
sugar (``open_sync``, ``read_future``, ...) is provided for driver code and
tests; the futures pump the scheduler, preserving split-phase semantics.

Streaming (per-splinter completion events)
------------------------------------------
``read_stream(session, on_splinter, ...)`` subscribes to the session's
splinter completion stream: one callback per completed splinter read (with
arrival metadata), delivered as scheduler tasks — optionally routed through
a consumer's virtual proxy with drop-stale semantics. It is the primitive
under the pipeline's streamed host→device staging (``data/pipeline.py``,
``streaming=True``); ``end_stream`` unsubscribes.

Zero-copy reads (borrowed views)
--------------------------------
``read(..., data=None)`` / ``read_view(...)`` select the zero-copy delivery
path: ``after_read`` receives a **read-only memoryview into the session
arena** instead of a filled buffer (§III-C.4's zero-copy buffer→assembler
hand-off). Lifetime contract:

* the view is a *session-lifetime borrow* — it stays valid exactly until
  ``close_read_session`` on its session, at which point the library releases
  it and any later access raises ``ValueError`` (no silent reads of recycled
  memory);
* copy out (or ``jax.device_put``) anything needed past session close;
* the view is read-only; sub-views you slice off share the same lifetime by
  contract (slicing is not re-tracked — don't outlive the session).

The delivered-byte copy count is observable: ``session.metrics.bytes_copied``
stays 0 for view-path deliveries.

Tuning knobs (``FileOptions``)
------------------------------
* ``num_readers`` — parallel stripe readers (autotuned when ``None``);
* ``splinter_bytes`` — unit of physical I/O / early fulfilment (§VI-C);
* ``work_stealing`` — straggler mitigation between reader threads;
* ``placement`` — reader→PE mapping policy (``core/placement.py``);
* ``piece_timing_every`` — sample rate for per-piece delivery timing
  (0 = off, keeping instrumentation off the hot path);
* ``network`` — optional cross-node transfer model for locality studies.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional, Union

from repro.core.director import Director
from repro.core.futures import CkCallback, CkFuture
from repro.core.migration import Client, LocationManager
from repro.core.scheduler import TaskScheduler
from repro.core.session import FileHandle, FileOptions, Session


def _to_cb(cb: Union[CkCallback, CkFuture, None], default_pe: int = 0) -> CkCallback:
    if isinstance(cb, CkCallback):
        return cb
    if isinstance(cb, CkFuture):
        wrapped = CkCallback(lambda *a: cb.set(a[0] if a else None),
                             inline=True)
        # Error channel for the assembler: a session failure (process
        # backend worker crash) is routed to ``set_error`` on the future
        # itself, so ``wait`` raises the descriptive error instead of
        # timing out.
        wrapped.future = cb
        return wrapped
    if cb is None:
        return CkCallback(lambda *a: None, inline=True)
    raise TypeError(f"expected CkCallback/CkFuture/None, got {type(cb)}")


class CkIO:
    """Library facade: one instance per 'job' (owns scheduler + director)."""

    def __init__(
        self,
        num_pes: int = 1,
        pes_per_node: int = 1,
        sched: Optional[TaskScheduler] = None,
    ):
        self.sched = sched or TaskScheduler(num_pes, pes_per_node)
        self.director = Director(self.sched)
        self.locations = LocationManager(self.sched)

    # -- paper API (split-phase) ------------------------------------------------
    def open(
        self,
        name: str,
        opened: Union[CkCallback, CkFuture, None] = None,
        opts: Optional[FileOptions] = None,
    ) -> None:
        self.director.open_file(name, opts or FileOptions(), _to_cb(opened))

    def open_fileset(
        self,
        fileset,
        opened: Union[CkCallback, CkFuture, None] = None,
        opts: Optional[FileOptions] = None,
    ) -> None:
        """Open a multi-shard manifest (``repro.data.fileset.FileSet``) as
        ONE logical file. The returned ``FileHandle`` addresses the
        manifest's global data byte space (shard data regions concatenated,
        header pages excluded, byte 0 = row 0); sessions, ``read``/
        ``read_stream``/subscribe, zero-copy views and both reader backends
        work unchanged — stripe planning pins shard starts as hard bounds so
        no physical read spans a shard, and process-backend workers rebuild
        the shard table from paths (never inherited fds)."""
        self.director.open_fileset(fileset, opts or FileOptions(),
                                   _to_cb(opened))

    def start_read_session(
        self,
        file: FileHandle,
        nbytes: int,
        offset: int,
        ready: Union[CkCallback, CkFuture, None] = None,
        consumer_pes: Optional[List[int]] = None,
        sequenced: bool = False,
    ) -> None:
        self.director.start_session(
            file, nbytes, offset, _to_cb(ready), consumer_pes, sequenced
        )

    def read(
        self,
        session: Session,
        nbytes: int,
        offset: int,
        data: Any,
        after_read: Union[CkCallback, CkFuture, None],
        client: Optional[Client] = None,
    ) -> None:
        """Split-phase read of ``[offset, offset+nbytes)`` into ``data``.

        ``offset`` is absolute within the file (the paper's API takes offsets
        "with respect to the overall file the session corresponds to").
        If ``client`` is given, completion is routed through its virtual proxy
        (survives migration) and the request is assembled on the client's
        *current* PE.

        ``data=None`` selects the zero-copy borrowed-view path: the completion
        message's ``.data`` is a read-only memoryview into the session arena,
        valid until ``close_read_session`` (see module docstring for the full
        lifetime contract).
        """
        if session.closed:
            raise RuntimeError("read() on closed session")
        if not session.contains(offset, nbytes):
            raise ValueError(
                f"read [{offset}, {offset+nbytes}) outside session "
                f"[{session.offset}, {session.offset+session.nbytes})"
            )
        cb = _to_cb(after_read)
        if client is not None and cb.inline is False and cb.proxy is None:
            # prefer proxy routing when a client is identified
            cb = client.callback(cb.fn)
        pe = client.pe if client is not None else 0
        assembler = self.director.managers[pe].assembler
        assembler.submit(session, offset, nbytes, data, cb)

    def close_read_session(
        self,
        session: Session,
        after_end: Union[CkCallback, CkFuture, None] = None,
    ) -> None:
        self.director.close_session(session, _to_cb(after_end))

    def close(
        self, file: FileHandle, closed: Union[CkCallback, CkFuture, None] = None
    ) -> None:
        self.director.close_file(file, _to_cb(closed))

    # -- futures sugar ------------------------------------------------------------
    def open_sync(
        self, name: str, opts: Optional[FileOptions] = None, timeout: float = 60.0
    ) -> FileHandle:
        f: CkFuture = CkFuture()
        self.open(name, f, opts)
        return f.wait(self.sched, timeout=timeout)

    def open_fileset_sync(
        self, fileset, opts: Optional[FileOptions] = None,
        timeout: float = 60.0,
    ) -> FileHandle:
        f: CkFuture = CkFuture()
        self.open_fileset(fileset, f, opts)
        return f.wait(self.sched, timeout=timeout)

    def start_read_session_sync(
        self,
        file: FileHandle,
        nbytes: int,
        offset: int = 0,
        timeout: float = 60.0,
        **kw: Any,
    ) -> Session:
        f: CkFuture = CkFuture()
        self.start_read_session(file, nbytes, offset, f, **kw)
        return f.wait(self.sched, timeout=timeout)

    def read_view(
        self,
        session: Session,
        nbytes: int,
        offset: int,
        after_read: Union[CkCallback, CkFuture, None],
        client: Optional[Client] = None,
    ) -> None:
        """Zero-copy split-phase read: ``after_read`` gets a session-lifetime
        read-only view (sugar for ``read(..., data=None)``)."""
        self.read(session, nbytes, offset, None, after_read, client=client)

    def read_notify(
        self,
        session: Session,
        nbytes: int,
        offset: int,
        after_read: Union[CkCallback, CkFuture, None],
        client: Optional[Client] = None,
        classify_locality: bool = True,
    ) -> None:
        """Residency signal only: like ``read_view`` but the completion
        message carries ``data=None`` and no borrow is created — for callers
        that will take their own arena view later (e.g. once per batch
        rather than once per consumer). ``classify_locality=False`` keeps
        this request out of the same-/cross-domain byte accounting (for
        callers whose bytes are classified on another path — see
        ``ReadAssembler.submit``)."""
        if session.closed:
            raise RuntimeError("read_notify() on closed session")
        if not session.contains(offset, nbytes):
            raise ValueError(
                f"read [{offset}, {offset+nbytes}) outside session "
                f"[{session.offset}, {session.offset+session.nbytes})"
            )
        cb = _to_cb(after_read)
        if client is not None and cb.inline is False and cb.proxy is None:
            cb = client.callback(cb.fn)
        pe = client.pe if client is not None else 0
        self.director.managers[pe].assembler.submit(
            session, offset, nbytes, None, cb, materialize_view=False,
            classify_locality=classify_locality,
        )

    def read_stream(
        self,
        session: Session,
        on_splinter: Callable,
        *,
        client: Optional[Client] = None,
        route: Optional[Callable] = None,
        pe: int = 0,
        on_complete: Optional[Callable[[], None]] = None,
        replay: bool = True,
    ) -> int:
        """Subscribe to ``session``'s per-splinter completion stream.

        The event-driven counterpart of ``read``: instead of waiting for a
        byte range, the caller is invoked once per **splinter** as its read
        completes, with a ``SplinterEvent`` (splinter id, owning reader,
        absolute offset, size, arena offset, arrival timestamp). This is the
        primitive a streaming consumer (e.g. the pipeline's host→device
        stager) builds on: data can be shipped onward while the rest of the
        session is still being read.

        Split-phase like everything else: ``on_splinter`` is *enqueued as a
        task*, never run on the I/O thread. Routing, in precedence order:

        * ``route`` — callable ``SplinterEvent -> Optional[Client]``; the
          event is delivered through the returned client's virtual proxy
          with **drop-stale** semantics (a retired/deregistered consumer's
          events are dropped and counted in
          ``locations.stale_deliveries``, never rerouted to a reused
          slot); ``route`` returning ``None`` falls back to ``pe``.
        * ``client`` — fixed client, same drop-stale proxy delivery.
        * ``pe`` — fixed PE (default 0).

        With ``replay=True`` splinters that completed before the call are
        delivered first (in arrival order) — subscribing after the greedy
        prefetch started misses nothing. ``on_complete`` (optional) is
        enqueued on ``pe`` after the last splinter's delivery has been
        issued; it requires ``replay=True`` (without replay, splinters that
        completed before the subscription are never delivered, so the count
        could never reach the total and the callback would silently never
        fire). Returns a token for ``end_stream``.
        """
        if session.closed:
            raise RuntimeError("read_stream() on closed session")
        if on_complete is not None and not replay:
            raise ValueError("on_complete requires replay=True (completions "
                             "before the subscription would never be counted)")
        total = len(session.plan.splinters)
        state = {"n": 0}
        lock = threading.Lock()
        topo = session.opts.topology

        def deliver(ev) -> None:
            target = route(ev) if route is not None else client
            if topo is not None:
                # Streamed counterpart of the assembler's per-piece
                # classification: streamed bytes are classified against
                # the domain of the consumer each event is routed to (the
                # pipeline's whole-window residency probe opts out with
                # classify_locality=False, so nothing is counted twice).
                # Classified at issue time (a drop-stale discard later
                # still counts as routed bytes).
                dest_pe = target.pe if target is not None else pe
                session.readers.locality.record_delivery(
                    ev.nbytes,
                    session.readers.reader_domain(ev.reader)
                    == topo.domain_of(dest_pe))
            if target is not None:
                target.callback(on_splinter, drop_stale=True).send(
                    self.sched, ev)
            else:
                self.sched.enqueue(pe, on_splinter, ev, label="ckio-stream")
            if on_complete is not None:
                with lock:
                    state["n"] += 1
                    last = state["n"] == total
                if last:
                    self.sched.enqueue(pe, on_complete,
                                       label="ckio-stream-end")

        return session.subscribe_splinters(deliver, replay=replay)

    def end_stream(self, session: Session, token: int) -> None:
        """Unsubscribe a ``read_stream`` token (barrier: no further
        deliveries are *issued* once this returns; tasks already enqueued
        still run — guard the consumer, see the pipeline's retired check)."""
        session.unsubscribe_splinters(token)

    def read_future(
        self,
        session: Session,
        nbytes: int,
        offset: int,
        data: Optional[Any] = None,
        client: Optional[Client] = None,
    ) -> CkFuture:
        if data is None:
            data = bytearray(nbytes)
        f: CkFuture = CkFuture()
        self.read(session, nbytes, offset, data, f, client=client)
        return f

    def read_view_future(
        self,
        session: Session,
        nbytes: int,
        offset: int,
        client: Optional[Client] = None,
    ) -> CkFuture:
        f: CkFuture = CkFuture()
        self.read_view(session, nbytes, offset, f, client=client)
        return f

    def read_view_sync(
        self,
        session: Session,
        nbytes: int,
        offset: int,
        client: Optional[Client] = None,
        timeout: float = 120.0,
    ) -> memoryview:
        """Blocking zero-copy read; the returned view dies with the session."""
        f = self.read_view_future(session, nbytes, offset, client)
        return f.wait(self.sched, timeout=timeout).data

    def read_sync(
        self,
        session: Session,
        nbytes: int,
        offset: int,
        data: Optional[Any] = None,
        client: Optional[Client] = None,
        timeout: float = 120.0,
    ) -> Any:
        f = self.read_future(session, nbytes, offset, data, client)
        return f.wait(self.sched, timeout=timeout).data

    def session_arrival_order(self, session: Session):
        """Per-session piece (splinter) arrival order — the completion order
        the reader layer observed. Feeds the device-ingest index-map
        construction (``data.packing.pieces_in_arrival_order``); a snapshot,
        stable once the session's reads are complete."""
        return session.arrival_order

    def close_read_session_sync(self, session: Session, timeout: float = 60.0) -> None:
        f: CkFuture = CkFuture()
        self.close_read_session(session, f)
        f.wait(self.sched, timeout=timeout)

    def close_sync(self, file: FileHandle, timeout: float = 60.0) -> None:
        f: CkFuture = CkFuture()
        self.close(file, f)
        f.wait(self.sched, timeout=timeout)

    # -- clients ------------------------------------------------------------------
    def make_client(self, pe: int = 0) -> Client:
        return Client(self.locations, pe)

    # -- scheduler passthrough ------------------------------------------------------
    def pump(self, max_tasks: Optional[int] = None) -> int:
        return self.sched.pump(max_tasks)

    def run_until(self, predicate, *, timeout: float = 60.0) -> None:
        self.sched.run_until(predicate, timeout=timeout)
