"""Cooperative task scheduler — the Charm++ RTS analog.

Charm++ schedules asynchronous method invocations on per-PE user-space
queues; no task may block its PE. We reproduce that execution model with
logical PEs hosted in one process: tasks are run-to-completion callables
bound to a PE, executed cooperatively by whichever thread pumps the
scheduler, while *I/O helper threads* (the paper's per-buffer-chare
pthreads) enqueue completion tasks from outside.

Properties preserved from the paper's model (and tested):
  * split-phase: an I/O call never executes user continuations inline; it
    only enqueues them (paper §III-D: "the system only enqueues the
    corresponding method invocation as a task").
  * message-driven: no ordering guarantee between tasks on different PEs;
    round-robin draining gives fair interleave of I/O completions and
    background work.
  * quiescence: ``run_until`` parks on a condition variable when all queues
    are empty, to be woken by I/O threads — the "PE" is idle but never
    spinning inside a read.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, Iterable, List, Optional, Tuple


@dataclass
class _Task:
    pe: int
    fn: Callable[..., Any]
    args: tuple
    label: str = ""


class QuiescenceTimeout(RuntimeError):
    pass


class TaskScheduler:
    """Per-PE task queues + cooperative pump.

    ``num_pes`` is the number of *logical* processors ("PEs"). This container
    has one physical core; logical PEs model placement (which node/PE a chare
    lives on) exactly as the paper's experiments vary nodes×PEs.
    """

    def __init__(self, num_pes: int = 1, pes_per_node: int = 1):
        if num_pes < 1:
            raise ValueError("num_pes must be >= 1")
        self.num_pes = num_pes
        self.pes_per_node = max(1, pes_per_node)
        self._queues: List[Deque[_Task]] = [deque() for _ in range(num_pes)]
        self._cv = threading.Condition()
        self._pending = 0           # tasks enqueued but not yet executed
        self._executed = 0
        # O(1) dispatch: deque of PEs with non-empty queues (round-robin by
        # rotation) + membership flags, instead of scanning all num_pes
        # queues per pop — per-task dispatch cost no longer grows with the
        # PE count (TASIO: runtime overhead per completion bounds task-based
        # I/O at scale).
        self._ready: Deque[int] = deque()
        self._in_ready: List[bool] = [False] * num_pes
        self._tl = threading.local()   # per-thread enqueue batch buffer
        self.stats: Dict[str, int] = {"enqueued": 0, "executed": 0}

    # -- topology -----------------------------------------------------------
    def node_of(self, pe: int) -> int:
        return pe // self.pes_per_node

    @property
    def num_nodes(self) -> int:
        return (self.num_pes + self.pes_per_node - 1) // self.pes_per_node

    # -- enqueue (thread-safe; callable from I/O helper threads) -------------
    def _push_locked(self, t: _Task) -> None:
        """Append a task; caller holds ``self._cv``."""
        self._queues[t.pe].append(t)
        if not self._in_ready[t.pe]:
            self._in_ready[t.pe] = True
            self._ready.append(t.pe)
        self._pending += 1
        self.stats["enqueued"] += 1

    def enqueue(self, pe: int, fn: Callable[..., Any], *args: Any,
                label: str = "") -> None:
        if not (0 <= pe < self.num_pes):
            raise ValueError(f"PE {pe} out of range [0,{self.num_pes})")
        t = _Task(pe, fn, args, label)
        buf = getattr(self._tl, "buf", None)
        if buf is not None:          # inside batch(): defer lock + notify
            buf.append(t)
            return
        with self._cv:
            self._push_locked(t)
            # Exactly one pumper consumes a given task; waking every parked
            # thread per enqueue (notify_all) is pure overhead on the hot
            # completion path.
            self._cv.notify()

    def enqueue_many(
        self, tasks: Iterable[Tuple[int, Callable[..., Any]]], label: str = ""
    ) -> int:
        """Enqueue a batch of ``(pe, fn)`` or ``(pe, fn, args)`` tasks with a
        single lock acquisition and a single wake-up — one completion batch
        (e.g. a splinter landing and releasing many waiters, or a session
        broadcast to every PE) costs one synchronization, not one per task."""
        staged = []
        for item in tasks:
            pe, fn = item[0], item[1]
            args = item[2] if len(item) > 2 else ()
            if not (0 <= pe < self.num_pes):
                raise ValueError(f"PE {pe} out of range [0,{self.num_pes})")
            staged.append(_Task(pe, fn, tuple(args), label))
        if not staged:
            return 0
        buf = getattr(self._tl, "buf", None)
        if buf is not None:
            buf.extend(staged)
            return len(staged)
        self._flush(staged)
        return len(staged)

    def _flush(self, staged: List[_Task]) -> None:
        """Push a staged batch: one lock acquisition, one wake-up round."""
        with self._cv:
            for t in staged:
                self._push_locked(t)
            self._cv.notify(len(staged))

    @contextmanager
    def batch(self):
        """Context manager deferring ``enqueue`` calls made by this thread
        into one ``enqueue_many`` flush on exit (nesting flushes once, at the
        outermost level). Lets completion fan-out — N waiters fired by one
        splinter — take the scheduler lock once."""
        if getattr(self._tl, "buf", None) is not None:
            yield                    # already batching (nested)
            return
        self._tl.buf = []
        try:
            yield
        finally:
            staged, self._tl.buf = self._tl.buf, None
            if staged:
                self._flush(staged)

    # -- pump ----------------------------------------------------------------
    def _pop_next(self) -> Optional[_Task]:
        with self._cv:
            while self._ready:
                pe = self._ready.popleft()
                q = self._queues[pe]
                if not q:            # pragma: no cover - defensive
                    self._in_ready[pe] = False
                    continue
                t = q.popleft()
                if q:
                    self._ready.append(pe)   # rotate: fair round-robin
                else:
                    self._in_ready[pe] = False
                self._pending -= 1
                return t
        return None

    def step(self) -> bool:
        """Execute at most one task. Returns False if all queues were empty."""
        t = self._pop_next()
        if t is None:
            return False
        t.fn(*t.args)
        with self._cv:
            self._executed += 1
            self.stats["executed"] += 1
        return True

    def pump(self, max_tasks: Optional[int] = None) -> int:
        """Drain ready tasks (without waiting). Returns #tasks executed."""
        n = 0
        while (max_tasks is None or n < max_tasks) and self.step():
            n += 1
        return n

    def run_until(self, predicate: Callable[[], bool], *,
                  timeout: float = 60.0) -> None:
        """Pump tasks until ``predicate()`` holds.

        When no task is ready and the predicate is still false, park on the
        condition variable — I/O helper threads wake us by enqueueing
        completions. Raises ``QuiescenceTimeout`` on deadline.
        """
        deadline = time.monotonic() + timeout
        while not predicate():
            if self.step():
                continue
            with self._cv:
                if self._pending == 0 and not predicate():
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise QuiescenceTimeout(
                            f"predicate still false after {timeout}s "
                            f"(executed={self._executed})"
                        )
                    self._cv.wait(min(remaining, 0.1))
            if time.monotonic() > deadline:
                raise QuiescenceTimeout(
                    f"predicate still false after {timeout}s "
                    f"(executed={self._executed})"
                )

    def pump_until_deadline(self, deadline: float) -> int:
        """Process tasks until ``time.monotonic() >= deadline`` — the
        Charm++ idle loop: a PE waiting on an external event (the device
        step) keeps executing ready tasks (prefetch I/O completions)."""
        n = 0
        while True:
            now = time.monotonic()
            if now >= deadline:
                return n
            if self.step():
                n += 1
                continue
            with self._cv:
                if self._pending == 0:
                    self._cv.wait(min(deadline - now, 0.005))

    def run_to_quiescence(self, *, timeout: float = 60.0,
                          settle: float = 0.0) -> int:
        """Pump until all queues are empty (and stay empty for ``settle`` s)."""
        start = self._executed
        deadline = time.monotonic() + timeout
        while True:
            self.pump()
            with self._cv:
                if self._pending == 0:
                    if settle <= 0:
                        return self._executed - start
                    woken = self._cv.wait(settle)
                    if not woken and self._pending == 0:
                        return self._executed - start
            if time.monotonic() > deadline:
                raise QuiescenceTimeout(f"not quiescent after {timeout}s")


class BackgroundWorker:
    """A self-re-enqueueing chare for compute/I/O overlap (paper Figs. 8–9).

    Each invocation performs ~``grain_us`` microseconds of host compute, then
    *yields to the scheduler* by re-enqueueing itself — exactly the paper's
    benchmark structure ("at the end of every iteration, each chare yields
    control to the Charm scheduler").
    """

    def __init__(self, sched: TaskScheduler, pe: int, grain_us: float = 10.0):
        self.sched = sched
        self.pe = pe
        self.grain_us = grain_us
        self.iterations = 0
        self.busy_s = 0.0
        self.stopped = False

    def start(self) -> None:
        self.sched.enqueue(self.pe, self._iter, label="bg")

    def stop(self) -> None:
        self.stopped = True

    def _iter(self) -> None:
        if self.stopped:
            return
        t0 = time.perf_counter()
        # Spin-compute for ~grain_us: a deterministic arithmetic loop.
        acc = 0
        target = t0 + self.grain_us * 1e-6
        while time.perf_counter() < target:
            acc += 1
        self.busy_s += time.perf_counter() - t0
        self.iterations += 1
        self.sched.enqueue(self.pe, self._iter, label="bg")
