"""Buffer readers: greedy striped prefetch with splintered I/O + work stealing.

This is the paper's *buffer chare* layer (§III-C.4): a configurable set of
reader agents, each owning a disjoint stripe of the session, reading
asynchronously on helper I/O threads so the PEs stay available for
application tasks. Two extensions from the paper's §VI future-work are
implemented as first-class features:

* **Splintered I/O** (§VI-C): stripes are read in ``splinter_bytes`` units and
  client requests are fulfilled as soon as *their* splinters land, rather than
  after the whole stripe.
* **Work stealing / straggler mitigation**: an I/O thread that drains its own
  stripe steals unread splinters from the most-backlogged reader. On a
  1000+-node system slow readers (failing disks, contended OSTs) are the norm;
  stealing bounds session completion at roughly max(splinter) rather than
  max(stripe). A ``delay_model`` hook lets tests/benchmarks inject stragglers
  deterministically.

A ``NetworkModel`` optionally models the buffer→client transfer cost for
cross-"node" deliveries (used by the migration-locality benchmark, paper
Fig. 12); by default delivery is an immediate zero-copy memoryview hand-off.
"""
from __future__ import annotations

import heapq
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.metrics import LocalityMetrics, SessionMetrics
from repro.core.placement import Topology
from repro.core.scheduler import TaskScheduler
from repro.io.layout import StripePlan, Splinter, splinters_covering
from repro.io.numa import first_touch, pin_thread_to_cpus
from repro.io.posix import PosixFile


@dataclass
class ReaderOptions:
    """Tunables for the reader layer (the knobs the paper exposes + §VI)."""

    splinter_bytes: int = 8 * 1024 * 1024
    work_stealing: bool = True
    max_io_threads: int = 64
    # test/bench hook: seconds of injected delay before reading a splinter
    delay_model: Optional[Callable[[int, Splinter], float]] = None
    # optional cross-node transfer model (None = immediate hand-off)
    network: Optional["NetworkModel"] = None
    # per-piece delivery timing sample rate (0 = off; N = every Nth piece)
    piece_timing_every: int = 0
    # PE -> NUMA-domain model (core/placement.py). Enables domain-coalesced
    # pieces, cross-domain delivery accounting, and — with prefault_arena —
    # per-stripe first-touch on the owning reader's thread.
    topology: Optional[Topology] = None
    # Pin each reader I/O thread to the host CPUs of its stripe's NUMA
    # domain (requires a topology with a CPU map, e.g. Topology.detect).
    # Best-effort; outcomes are counted in LocalityMetrics.
    numa_pin: bool = False
    # Arena prefault policy. Without a topology this reproduces the seed's
    # up-front zero-fill (a full memset on the start critical path — used by
    # benchmarks as the legacy "before"). WITH a topology it becomes the
    # NUMA first-touch hook instead: each reader thread faults its own
    # stripe's pages (one byte per page, on its own — optionally pinned —
    # thread) before reading, so first-touch places every stripe on its
    # reader's domain without defeating the non-zero-filled np.empty arena.
    prefault_arena: bool = False


class NetworkModel:
    """Deterministic cross-node delivery model (single timer thread).

    ``deliver`` fires ``fn`` after bytes/bw + latency when the transfer
    crosses nodes, immediately otherwise. Used only where a benchmark needs
    to expose locality (everything runs in one address space here, so the
    physical copy cost does not differ by "node" — the model supplies the
    difference and is documented wherever used).
    """

    def __init__(self, bw_bytes_per_s: float = 25e9, latency_s: float = 2e-6):
        self.bw = bw_bytes_per_s
        self.latency = latency_s
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._lock = threading.Condition()
        self._seq = 0
        self._stop = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def transfer_time(self, nbytes: int) -> float:
        return self.latency + nbytes / self.bw

    def deliver(self, nbytes: int, same_node: bool, fn: Callable[[], None]) -> None:
        if same_node:
            fn()
            return
        due = time.monotonic() + self.transfer_time(nbytes)
        with self._lock:
            heapq.heappush(self._heap, (due, self._seq, fn))
            self._seq += 1
            self._lock.notify()

    def _run(self) -> None:
        while True:
            with self._lock:
                while not self._heap and not self._stop:
                    self._lock.wait(0.05)
                if self._stop:
                    return
                due, _, fn = self._heap[0]
                now = time.monotonic()
                if due > now:
                    self._lock.wait(min(due - now, 0.05))
                    continue
                heapq.heappop(self._heap)
            fn()

    def shutdown(self) -> None:
        with self._lock:
            self._stop = True
            self._lock.notify()


@dataclass
class _Waiter:
    remaining: int
    fire: Callable[[], None]


@dataclass(frozen=True)
class SplinterEvent:
    """One splinter-read completion, as seen by stream subscribers.

    Carries everything a streamed consumer needs to act on the arrival
    without touching the reader set again: identity (global splinter id +
    owning reader), location (absolute file offset and the offset of the
    bytes inside the session arena), size, and the ``perf_counter``
    timestamp of the completion — the anchor for arrival→staged latency.
    """

    index: int          # global splinter id within the session
    reader: int         # owning reader (post-steal: the planned owner)
    offset: int         # absolute file offset
    nbytes: int
    arena_off: int      # byte offset into the session arena
    t_arrival: float    # time.perf_counter() at read completion


class BufferReaderSet:
    """The buffer-chare collective for one read session."""

    def __init__(
        self,
        file: PosixFile,
        plan: StripePlan,
        sched: TaskScheduler,
        reader_pes: List[int],
        opts: ReaderOptions,
        metrics: Optional[SessionMetrics] = None,
    ):
        assert len(reader_pes) >= plan.num_readers
        self.file = file
        self.plan = plan
        self.sched = sched
        self.reader_pes = reader_pes[: plan.num_readers]
        self.opts = opts
        self.metrics = metrics or SessionMetrics()
        if opts.piece_timing_every:
            self.metrics.piece_timing_every = opts.piece_timing_every

        # Session storage: stripes are slices of one arena. Readers fill it;
        # clients get zero-copy memoryviews out of it. np.empty skips the
        # memset a bytearray would do — every byte is overwritten by preadv
        # anyway, and for multi-GB sessions the zero-fill pass dominated
        # session start (it sat on the critical path of the first request).
        self._arena: np.ndarray = np.empty(plan.nbytes, dtype=np.uint8)
        self.locality = LocalityMetrics()
        if opts.prefault_arena and opts.topology is None:
            # Legacy (topology-blind) prefault — explicit memset: np.zeros
            # would calloc lazily-zeroed pages without touching them —
            # fill() actually faults every page in and reproduces the
            # seed's bytearray zero-fill. With a topology, prefault happens
            # per stripe on the reader threads instead (_thread_setup).
            self._arena.fill(0)
        self._base = plan.offset

        self._lock = threading.Lock()
        self._done = [False] * len(plan.splinters)
        self._ndone = 0
        # Global splinter ids in completion order — the staging order a
        # streamed (per-splinter) host→device path would see; consumed by
        # the device-ingest index-map construction (data/packing.py).
        self._arrival: List[int] = []
        # Per-splinter completion stream: recorded events (for subscriber
        # replay) + live subscribers. ``_stream_lock`` serializes deliveries
        # so each subscriber sees events exactly once, in arrival order, and
        # ``unsubscribe`` is a barrier (no callback runs after it returns).
        self._events: List[SplinterEvent] = []
        self._subs: Dict[int, Callable[[SplinterEvent], None]] = {}
        self._next_sub = 0
        self._stream_lock = threading.Lock()
        self._waiters_by_splinter: Dict[int, List[_Waiter]] = {}
        # per-reader deque of unread splinters (lists popped from index 0 /
        # stolen from the end)
        self._pending: List[List[Splinter]] = [
            list(plan.splinters_for_reader(r)) for r in range(plan.num_readers)
        ]
        self._threads: List[threading.Thread] = []
        # NUMA setup gate: count of reader threads whose _thread_setup has
        # not finished. While nonzero, work STEALING is disabled — a steal
        # is the only cross-thread read, and a stolen splinter read before
        # its owner's page-stride first-touch would be corrupted by the
        # touch landing afterwards. Own-stripe reads are always safe (each
        # thread touches its stripes before its first read), so this gate
        # closes the hazard without a start barrier: no timeout, no
        # broken-barrier window, regardless of thread scheduling.
        self._setup_pending = 0
        self._cancelled = False
        self._complete_evt = threading.Event()
        if not plan.splinters:
            self._complete_evt.set()
        self.started = False
        # Borrowed read-only views handed to zero-copy clients; released
        # (invalidated) when the session closes.
        self._borrows: List[memoryview] = []

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        """Begin greedy prefetch: every reader starts reading immediately
        (paper Fig. 5: "Buffer Chares begin reading on session instantiation,
        without waiting for client requests")."""
        if self.started:
            return
        self.started = True
        nthreads = min(
            max(1, self.plan.num_readers), max(1, self.opts.max_io_threads)
        )
        if self.opts.topology is not None and (
                self.opts.prefault_arena or self.opts.numa_pin):
            # Defer stealing until every thread's pin+first-touch setup is
            # done (see _setup_pending). Setup is microseconds (a syscall
            # + strided writes), so the gate lifts as soon as the last
            # thread is scheduled.
            self._setup_pending = nthreads
        self.metrics.session_started(self.plan.nbytes, self.plan.num_readers)
        if self.plan.nbytes:
            # Kick kernel readahead for the whole session before the first
            # pread lands (greedy prefetch starts now anyway).
            self.file.advise_sequential(self.plan.offset, self.plan.nbytes)
        for t in range(nthreads):
            th = threading.Thread(
                target=self._reader_main, args=(t, nthreads), daemon=True
            )
            self._threads.append(th)
            th.start()

    def cancel(self) -> None:
        self._cancelled = True

    def stop(self, timeout: float = 10.0) -> bool:
        """Cancel and join the reader threads (file-close barrier).

        Returns True when every thread exited — only then is it safe to
        close the underlying file. False means a straggler survived the
        per-thread join timeout (e.g. a pread stalled on a dying FS) and
        may still touch the fd; the caller must not close it."""
        self._cancelled = True
        ok = True
        for th in self._threads:
            if th.is_alive():
                th.join(timeout)
                ok &= not th.is_alive()
        return ok

    def join(self, timeout: float = 120.0) -> bool:
        """Wait for all splinters to be resident (bench/driver use only —
        application code uses `when_available`/callbacks instead)."""
        return self._complete_evt.wait(timeout)

    @property
    def complete(self) -> bool:
        return self._complete_evt.is_set()

    def progress(self) -> Tuple[int, int]:
        with self._lock:
            return self._ndone, len(self._done)

    def arrival_order(self) -> Tuple[int, ...]:
        """Global splinter ids in the order their reads completed (snapshot).

        A permutation of ``range(len(plan.splinters))`` once the session is
        complete; work stealing and stragglers make it differ from file
        order, which is exactly what the device-side reassembly index maps
        (``data/packing.py``) consume."""
        with self._lock:
            return tuple(self._arrival)

    # -- reader threads -------------------------------------------------------
    def _next_splinter(self, tid: int, nthreads: int) -> Optional[Splinter]:
        """Pop own work first; steal from the most-backlogged reader if idle."""
        with self._lock:
            # own readers: reader indices congruent to tid (thread pool may be
            # smaller than the reader count)
            for r in range(tid, self.plan.num_readers, nthreads):
                if self._pending[r]:
                    return self._pending[r].pop(0)
            if self.opts.work_stealing and self._setup_pending == 0:
                victim = max(
                    range(self.plan.num_readers),
                    key=lambda r: len(self._pending[r]),
                    default=None,
                )
                if victim is not None and self._pending[victim]:
                    self.metrics.record_steal(victim)
                    return self._pending[victim].pop()  # steal from the tail
        return None

    def _thread_setup(self, tid: int, nthreads: int) -> None:
        """Per-I/O-thread NUMA placement, before the first read.

        With a topology: first-touch-fault the pages of every stripe this
        thread owns (``prefault_arena``) — with ``numa_pin``, pinned to
        *that stripe's* domain CPUs while touching it (a thread can own
        stripes in several domains when the pool is smaller than the
        reader count; re-pinning per domain is a cheap syscall and it is
        the touch-time affinity that decides first-touch placement), then
        settle on the primary stripe's domain for the read loop. Under
        Linux first-touch each stripe's memory thus lands on its own
        domain, one byte written per page, never a whole-arena zero-fill.
        Stolen splinters later read into already-placed pages, so
        straggler stealing cannot scatter a stripe across domains.
        """
        topo = self.opts.topology
        if topo is None:
            return
        owned = range(tid, self.plan.num_readers, nthreads)
        if not len(owned):
            return
        pinned_dom = [None]
        pin_outcomes: List[bool] = []

        def pin_to(dom: int) -> None:
            if not self.opts.numa_pin or dom == pinned_dom[0]:
                return
            cpus = topo.cpus_of_domain(dom)
            pin_outcomes.append(bool(cpus) and pin_thread_to_cpus(cpus))
            pinned_dom[0] = dom
        if self.opts.prefault_arena:
            for r in owned:
                lo, hi = self.plan.stripe_bounds[r]
                if hi > lo:
                    pin_to(self.reader_domain(r))
                    pages = first_touch(
                        self._arena[lo - self._base: hi - self._base])
                    self.locality.record_prefault(pages)
        pin_to(self.reader_domain(owned[0]))   # read-loop affinity
        if pin_outcomes:
            # One record per THREAD (the counter's name and the verify
            # docs read it as a thread count): success only if every
            # re-pin along the way (one per owned domain) succeeded.
            self.locality.record_pin(all(pin_outcomes))

    def _reader_main(self, tid: int, nthreads: int) -> None:
        gated = self._setup_pending > 0     # set before threads start
        if gated:
            try:
                self._thread_setup(tid, nthreads)
            finally:
                with self._lock:
                    self._setup_pending -= 1
        while not self._cancelled:
            sp = self._next_splinter(tid, nthreads)
            if sp is None:
                if not self.opts.work_stealing:
                    return            # own stripes drained; nothing to steal
                with self._lock:
                    has_work = any(self._pending)
                    gated = self._setup_pending > 0
                if not has_work:
                    return
                # Unclaimed splinters remain. Either stealing is still
                # setup-gated (spin briefly — the gate lifts within
                # microseconds of the last thread being scheduled) or the
                # gate lifted between our failed pop and this check —
                # retry immediately rather than exiting and silently
                # leaving the session without a thief.
                if gated:
                    time.sleep(0.0005)
                continue
            if self.opts.delay_model is not None:
                d = self.opts.delay_model(sp.reader, sp)
                if d > 0:
                    time.sleep(d)
            t0 = time.perf_counter()
            lo = sp.offset - self._base
            view = memoryview(self._arena)[lo : lo + sp.nbytes]
            n = self.file.pread_into(sp.offset, view)
            dt = time.perf_counter() - t0
            if n != sp.nbytes and not self._cancelled:
                raise IOError(
                    f"short read: wanted {sp.nbytes} at {sp.offset}, got {n}"
                )
            self.metrics.record_read(sp.reader, sp.nbytes, dt)
            if self.opts.topology is not None:
                # Splinter-size histogram (per-reader sizing observable);
                # skipped without a topology to keep the default read loop
                # free of the extra lock acquisition.
                self.locality.record_splinter(sp.reader, sp.nbytes)
            self._mark_done(sp)

    def _mark_done(self, sp: Splinter) -> None:
        to_fire: List[Callable[[], None]] = []
        ev = SplinterEvent(
            index=sp.index,
            reader=sp.reader,
            offset=sp.offset,
            nbytes=sp.nbytes,
            arena_off=sp.offset - self._base,
            t_arrival=time.perf_counter(),
        )
        # _stream_lock spans the record + delivery so concurrent completions
        # reach every subscriber in the same order they enter ``_events``
        # (== ``_arrival`` order).
        with self._stream_lock:
            with self._lock:
                self._done[sp.index] = True
                self._ndone += 1
                self._arrival.append(sp.index)
                self._events.append(ev)
                if self._ndone == len(self._done):
                    self._complete_evt.set()
                for w in self._waiters_by_splinter.pop(sp.index, ()):  # type: ignore[arg-type]
                    w.remaining -= 1
                    if w.remaining == 0:
                        to_fire.append(w.fire)
                subs = list(self._subs.values()) if self._subs else ()
            for cb in subs:
                cb(ev)
        if not to_fire:
            return
        # One splinter can release many waiters; batch their enqueues into a
        # single scheduler lock/notify round.
        with self.sched.batch():
            for fire in to_fire:
                fire()

    # -- splinter completion stream -------------------------------------------
    def subscribe(
        self, cb: Callable[[SplinterEvent], None], replay: bool = True
    ) -> int:
        """Register ``cb`` for per-splinter completion events; returns a token.

        ``cb`` runs on the completing I/O thread and must be cheap (enqueue a
        scheduler task — the split-phase rule) and must not call
        ``subscribe``/``unsubscribe`` inline (delivery holds the stream lock).
        With ``replay=True`` (default), splinters that completed before the
        subscription are delivered first, in arrival order, before any new
        event — a subscriber attached mid-session misses nothing.
        """
        with self._stream_lock:
            with self._lock:
                token = self._next_sub
                self._next_sub += 1
                past = list(self._events) if replay else []
                self._subs[token] = cb
            for ev in past:
                cb(ev)
        return token

    def unsubscribe(self, token: int) -> None:
        """Remove a stream subscriber. Barrier semantics: once this returns,
        the callback will not be invoked again (any in-flight delivery has
        completed — both paths hold the stream lock)."""
        with self._stream_lock:
            with self._lock:
                self._subs.pop(token, None)

    def events(self) -> Tuple[SplinterEvent, ...]:
        """Snapshot of recorded completion events (arrival order)."""
        with self._lock:
            return tuple(self._events)

    # -- client-facing --------------------------------------------------------
    def when_available(
        self, abs_off: int, nbytes: int, fire: Callable[[], None]
    ) -> None:
        """Invoke ``fire`` once every byte of the range is resident.

        Thread-safe. ``fire`` must be cheap (it enqueues a scheduler task).
        If the data is already resident the callback runs immediately in the
        caller — the paper's "request buffered until the I/O is finished"
        semantics, with the buffered case handled by the waiter table.
        """
        need = [
            s.index
            for s in splinters_covering(self.plan, abs_off, nbytes)
        ]
        with self._lock:
            missing = [i for i in need if not self._done[i]]
            if missing:
                w = _Waiter(remaining=len(missing), fire=fire)
                for i in missing:
                    self._waiters_by_splinter.setdefault(i, []).append(w)
                return
        fire()

    def view(self, abs_off: int, nbytes: int) -> memoryview:
        """Zero-copy view of resident session bytes (the paper's zero-copy
        buffer→assembler hand-off; the Manager's tag table reduces to arena
        offsets in a shared address space)."""
        lo = abs_off - self._base
        return memoryview(self._arena)[lo : lo + nbytes]

    def borrow_view(self, abs_off: int, nbytes: int) -> memoryview:
        """Read-only zero-copy view handed to a client (``read(dest=None)``).

        Session-lifetime borrow: the view is tracked and *released* when the
        session closes, so use-after-close raises ``ValueError`` instead of
        silently reading recycled memory."""
        lo = abs_off - self._base
        mv = memoryview(self._arena)[lo : lo + nbytes].toreadonly()
        with self._lock:
            self._borrows.append(mv)
        return mv

    def invalidate_borrows(self) -> int:
        """Release every borrowed view (close_read_session). Returns count.

        A view with a live buffer export (e.g. an ``np.frombuffer`` array the
        client still holds) cannot be released — Python pins the memory for
        the exporter, so this stays memory-safe; the borrow is dropped from
        tracking and dies when the last exporter does."""
        with self._lock:
            borrows, self._borrows = self._borrows, []
        n = 0
        for mv in borrows:
            try:
                mv.release()
                n += 1
            except BufferError:   # live export pins the arena; safe to skip
                pass
        return n

    def reader_pe(self, r: int) -> int:
        return self.reader_pes[r]

    def reader_node(self, r: int) -> int:
        return self.sched.node_of(self.reader_pes[r])

    def reader_domain(self, r: int) -> int:
        """NUMA domain of reader ``r``'s PE (node granularity when no
        topology is configured — one memory domain per address space)."""
        pe = self.reader_pes[r]
        topo = self.opts.topology
        return topo.domain_of(pe) if topo is not None else \
            self.sched.node_of(pe)

    def reader_locality(self, r: int) -> Tuple[int, int]:
        """(node, domain) of reader ``r`` — the piece-coalescing key.

        Keyed on both so coalescing never merges across a scheduler node
        even when the topology's domain grid does not nest inside the
        node grid (a merged piece is attributed to its first reader, so a
        node-spanning merge would skip the NetworkModel transfer and
        miscount cross-node bytes for the tail of the piece)."""
        pe = self.reader_pes[r]
        topo = self.opts.topology
        node = self.sched.node_of(pe)
        return (node, topo.domain_of(pe) if topo is not None else node)
