"""Buffer readers: greedy striped prefetch with splintered I/O + work stealing.

This is the paper's *buffer chare* layer (§III-C.4): a configurable set of
reader agents, each owning a disjoint stripe of the session, reading
asynchronously on helper I/O threads so the PEs stay available for
application tasks. Two extensions from the paper's §VI future-work are
implemented as first-class features:

* **Splintered I/O** (§VI-C): stripes are read in ``splinter_bytes`` units and
  client requests are fulfilled as soon as *their* splinters land, rather than
  after the whole stripe.
* **Work stealing / straggler mitigation**: an I/O thread that drains its own
  stripe steals unread splinters from the most-backlogged reader. On a
  1000+-node system slow readers (failing disks, contended OSTs) are the norm;
  stealing bounds session completion at roughly max(splinter) rather than
  max(stripe). A ``delay_model`` hook lets tests/benchmarks inject stragglers
  deterministically.

A ``NetworkModel`` optionally models the buffer→client transfer cost for
cross-"node" deliveries (used by the migration-locality benchmark, paper
Fig. 12); by default delivery is an immediate zero-copy memoryview hand-off.
"""
from __future__ import annotations

import heapq
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.metrics import LocalityMetrics, SessionMetrics
from repro.core.placement import Topology
from repro.core.scheduler import TaskScheduler
from repro.io.layout import StripePlan, Splinter, splinters_covering
from repro.io.numa import first_touch, pin_thread_to_cpus
from repro.io.posix import DEFAULT_ALIGN, DirectIOError, PosixFile
from repro.io.submit import AsyncReadEngine
from repro.ipc.ring import (
    PIN_NONE,
    PIN_OK,
    ST_DONE,
    ST_ERROR,
    ST_INIT,
    EventRing,
    RingEvent,
    ring_bytes,
)
from repro.ipc.shm import SharedArena
from repro.ipc.worker import WorkerCrashed, WorkerSpec, worker_main


@dataclass
class ReaderOptions:
    """Tunables for the reader layer (the knobs the paper exposes + §VI)."""

    splinter_bytes: int = 8 * 1024 * 1024
    work_stealing: bool = True
    max_io_threads: int = 64
    # Reader backend: "thread" (helper I/O threads in this process — the
    # default) or "process" (one OS worker process per reader group reading
    # into a shared-memory arena, events over a cross-process ring —
    # ProcessReaderSet below; src/repro/ipc/).
    backend: str = "thread"
    # process backend: cap on spawned worker processes (readers are split
    # across them the way threads split readers in the thread backend).
    max_workers: int = 8
    # process backend: per-worker event-ring capacity (slots). A full ring
    # throttles its worker (backoff), never drops events.
    ring_slots: int = 512
    # process backend: picklable test hook run before each splinter read in
    # the worker ((reader, splinter_index) -> None; may raise or _exit) —
    # crash-path injection (repro.ipc.worker.ExitAfter / RaiseAfter).
    worker_fault: Optional[object] = None
    # process backend: seconds to wait for spawned workers to attach
    # (interpreter start + numpy import) before failing the session.
    worker_attach_timeout: float = 120.0
    # process backend: graceful-drain join timeout before SIGKILL.
    worker_stop_timeout: float = 10.0
    # process backend: what to do when a worker dies (or errors, or is
    # watchdog-killed) after the start gate opened, with splinters left:
    #   "none"    — fail the session fast (the PR-5 contract; default),
    #   "respawn" — spawn a replacement process that attaches to the SAME
    #               arena (go-gate protocol) and reads the unfinished tail,
    #   "reissue" — the supervisor re-reads the unfinished splinters itself
    #               (parent-side fd, straight into the mapped arena).
    # Attach-phase failures stay terminal in every mode: the first-touch
    # placement barrier cannot be re-run once other workers hold data.
    recovery: str = "none"
    # process backend: respawn budget for the whole session; exhausting it
    # fails the session with a descriptive WorkerCrashed.
    max_respawns: int = 2
    # process backend: hung-worker watchdog — a live worker that has made
    # no ring progress for this many seconds while owning unfinished
    # splinters is SIGKILLed (then handled per ``recovery``). 0 = off.
    worker_watchdog_s: float = 0.0
    # Fault-injection hooks (core/faults.py — picklable for the process
    # backend): io_fault plugs into PosixFile.pread_into (short reads /
    # transient OSErrors), ring_fault into EventRing.publish (torn stamps).
    io_fault: Optional[object] = None
    ring_fault: Optional[object] = None
    # test/bench hook: seconds of injected delay before reading a splinter
    # (process backend: must be picklable — see repro.ipc.worker.StallReader)
    delay_model: Optional[Callable[[int, Splinter], float]] = None
    # optional cross-node transfer model (None = immediate hand-off)
    network: Optional["NetworkModel"] = None
    # per-piece delivery timing sample rate (0 = off; N = every Nth piece)
    piece_timing_every: int = 0
    # PE -> NUMA-domain model (core/placement.py). Enables domain-coalesced
    # pieces, cross-domain delivery accounting, and — with prefault_arena —
    # per-stripe first-touch on the owning reader's thread.
    topology: Optional[Topology] = None
    # Pin each reader I/O thread to the host CPUs of its stripe's NUMA
    # domain (requires a topology with a CPU map, e.g. Topology.detect).
    # Best-effort; outcomes are counted in LocalityMetrics.
    numa_pin: bool = False
    # Arena prefault policy. Without a topology this reproduces the seed's
    # up-front zero-fill (a full memset on the start critical path — used by
    # benchmarks as the legacy "before"). WITH a topology it becomes the
    # NUMA first-touch hook instead: each reader thread faults its own
    # stripe's pages (one byte per page, on its own — optionally pinned —
    # thread) before reading, so first-touch places every stripe on its
    # reader's domain without defeating the non-zero-filled np.empty arena.
    prefault_arena: bool = False
    # -- cold-cache read engine (io/submit.py) -------------------------------
    # The file handle was opened O_DIRECT (reads DMA past the page cache).
    # start() validates the arena/plan against the probed block size and
    # raises io.posix.DirectIOError on any structural misalignment.
    direct_io: bool = False
    # In-flight reads per reader thread/worker: 0/1 = the blocking
    # per-splinter loop (the seed behaviour); >= 2 = depth-managed async
    # submission (io_uring or a preadv pool, see submit_mode).
    queue_depth: int = 0
    # WILLNEED window advised ahead of the submission frontier (bytes;
    # buffered files only — O_DIRECT bypasses the page cache).
    readahead_bytes: int = 0
    # "auto" | "io_uring" | "threads" (io/submit.py make_submitter).
    submit_mode: str = "auto"


class NetworkModel:
    """Deterministic cross-node delivery model (single timer thread).

    ``deliver`` fires ``fn`` after bytes/bw + latency when the transfer
    crosses nodes, immediately otherwise. Used only where a benchmark needs
    to expose locality (everything runs in one address space here, so the
    physical copy cost does not differ by "node" — the model supplies the
    difference and is documented wherever used).
    """

    def __init__(self, bw_bytes_per_s: float = 25e9, latency_s: float = 2e-6):
        self.bw = bw_bytes_per_s
        self.latency = latency_s
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._lock = threading.Condition()
        self._seq = 0
        self._stop = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def transfer_time(self, nbytes: int) -> float:
        return self.latency + nbytes / self.bw

    def deliver(self, nbytes: int, same_node: bool, fn: Callable[[], None]) -> None:
        if same_node:
            fn()
            return
        due = time.monotonic() + self.transfer_time(nbytes)
        with self._lock:
            heapq.heappush(self._heap, (due, self._seq, fn))
            self._seq += 1
            self._lock.notify()

    def _run(self) -> None:
        while True:
            with self._lock:
                while not self._heap and not self._stop:
                    self._lock.wait(0.05)
                if self._stop:
                    return
                due, _, fn = self._heap[0]
                now = time.monotonic()
                if due > now:
                    self._lock.wait(min(due - now, 0.05))
                    continue
                heapq.heappop(self._heap)
            fn()

    def shutdown(self) -> None:
        with self._lock:
            self._stop = True
            self._lock.notify()


@dataclass
class _Waiter:
    remaining: int
    fire: Callable[[], None]
    # Error channel: invoked (as a scheduler task) with the session error
    # when the backend fails before the awaited range lands. None = no
    # error path (bench/driver waiters that use join() instead).
    fail: Optional[Callable[[BaseException], None]] = None


@dataclass(frozen=True)
class SplinterEvent:
    """One splinter-read completion, as seen by stream subscribers.

    Carries everything a streamed consumer needs to act on the arrival
    without touching the reader set again: identity (global splinter id +
    owning reader), location (absolute file offset and the offset of the
    bytes inside the session arena), size, and the ``perf_counter``
    timestamp of the completion — the anchor for arrival→staged latency.
    """

    index: int          # global splinter id within the session
    reader: int         # owning reader (post-steal: the planned owner)
    offset: int         # absolute file offset
    nbytes: int
    arena_off: int      # byte offset into the session arena
    t_arrival: float    # time.perf_counter() at read completion


class BufferReaderSet:
    """The buffer-chare collective for one read session."""

    def __init__(
        self,
        file: PosixFile,
        plan: StripePlan,
        sched: TaskScheduler,
        reader_pes: List[int],
        opts: ReaderOptions,
        metrics: Optional[SessionMetrics] = None,
    ):
        assert len(reader_pes) >= plan.num_readers
        self.file = file
        self.plan = plan
        self.sched = sched
        self.reader_pes = reader_pes[: plan.num_readers]
        self.opts = opts
        self.metrics = metrics or SessionMetrics()
        if opts.piece_timing_every:
            self.metrics.piece_timing_every = opts.piece_timing_every

        self.locality = LocalityMetrics()
        # FileSet sessions: the handle resolves offsets to shard ids
        # (io.posix.ShardedFile.shard_of); None for single-file sessions.
        # Splinters never span shards (hard stripe bounds), so attributing
        # a whole pread to shard_of(offset) is exact.
        self._shard_of = getattr(file, "shard_of", None)
        # Session storage: stripes are slices of one arena. Readers fill it;
        # clients get zero-copy memoryviews out of it. The allocation is a
        # subclass hook: the process backend substitutes a shared-memory
        # segment mapped into every worker process (same aliasing contract).
        self._arena: np.ndarray = self._alloc_arena(plan)
        self._base = plan.offset

        self._lock = threading.Lock()
        self._done = [False] * len(plan.splinters)
        self._ndone = 0
        # Fatal session error (the process backend's worker-crash path sets
        # it via _fail; the thread backend never does). Checked under
        # ``_lock`` by when_available so registration and failure are
        # atomic: a request lands either before a failure (the raising
        # task unblocks its pump) or raises here — never in between.
        self.error: Optional[BaseException] = None
        self._error_surfaced = False   # one bare raising task per session
        # Global splinter ids in completion order — the staging order a
        # streamed (per-splinter) host→device path would see; consumed by
        # the device-ingest index-map construction (data/packing.py).
        self._arrival: List[int] = []
        # Per-splinter completion stream: recorded events (for subscriber
        # replay) + live subscribers. ``_stream_lock`` serializes deliveries
        # so each subscriber sees events exactly once, in arrival order, and
        # ``unsubscribe`` is a barrier (no callback runs after it returns).
        self._events: List[SplinterEvent] = []
        self._subs: Dict[int, Callable[[SplinterEvent], None]] = {}
        self._next_sub = 0
        self._stream_lock = threading.Lock()
        self._waiters_by_splinter: Dict[int, List[_Waiter]] = {}
        # per-reader deque of unread splinters (lists popped from index 0 /
        # stolen from the end)
        self._pending: List[List[Splinter]] = [
            list(plan.splinters_for_reader(r)) for r in range(plan.num_readers)
        ]
        self._threads: List[threading.Thread] = []
        # NUMA setup gate: count of reader threads whose _thread_setup has
        # not finished. While nonzero, work STEALING is disabled — a steal
        # is the only cross-thread read, and a stolen splinter read before
        # its owner's page-stride first-touch would be corrupted by the
        # touch landing afterwards. Own-stripe reads are always safe (each
        # thread touches its stripes before its first read), so this gate
        # closes the hazard without a start barrier: no timeout, no
        # broken-barrier window, regardless of thread scheduling.
        self._setup_pending = 0
        self._cancelled = False
        self._complete_evt = threading.Event()
        if not plan.splinters:
            self._complete_evt.set()
        self.started = False
        # Borrowed read-only views handed to zero-copy clients; released
        # (invalidated) when the session closes. _pinned_borrows counts the
        # ones a live buffer export kept alive through invalidation — the
        # reader-service arena pool quarantines (never recycles) a segment
        # with a nonzero count, so a pinned view can't alias a later
        # session's bytes.
        self._borrows: List[memoryview] = []
        self._pinned_borrows = 0

    def _alloc_arena(self, plan: StripePlan) -> np.ndarray:
        """Allocate the session arena (subclass hook). np.empty skips the
        memset a bytearray would do — every byte is overwritten by preadv
        anyway, and for multi-GB sessions the zero-fill pass dominated
        session start (it sat on the critical path of the first request).

        Direct-I/O sessions need the arena base on the FS block grid
        (O_DIRECT DMA targets), but numpy only guarantees 16-byte
        alignment for small allocations — over-allocate one block and
        slice to the grid (the parent buffer stays alive through
        ``.base``; costs at most ``block_size`` bytes per session)."""
        if getattr(self.file, "direct_io", False):
            bs = getattr(self.file, "block_size", DEFAULT_ALIGN)
            raw = np.empty(plan.nbytes + bs, dtype=np.uint8)
            skew = (-raw.ctypes.data) % bs
            arena = raw[skew: skew + plan.nbytes]
        else:
            arena = np.empty(plan.nbytes, dtype=np.uint8)
        if self.opts.prefault_arena and self.opts.topology is None:
            # Legacy (topology-blind) prefault — explicit memset: np.zeros
            # would calloc lazily-zeroed pages without touching them —
            # fill() actually faults every page in and reproduces the
            # seed's bytearray zero-fill. With a topology, prefault happens
            # per stripe on the reader threads instead (_thread_setup).
            arena.fill(0)
        return arena

    def _validate_direct_io(self) -> None:
        """Fail fast when a direct-I/O session cannot satisfy the probed
        block alignment — the no-silent-fallback half of the O_DIRECT
        contract. Checks the arena base (DMA target), the session offset,
        and every splinter's file offset (the splinter grid); sub-block
        *lengths* (tails) are legal — they finish through the buffered fd,
        counted."""
        if not getattr(self.file, "direct_io", False) or not self.plan.nbytes:
            return
        bs = getattr(self.file, "block_size", DEFAULT_ALIGN)
        problems: List[str] = []
        base_addr = self._arena.ctypes.data
        if base_addr % bs:
            problems.append(
                f"arena base 0x{base_addr:x} is not {bs}-byte aligned")
        if self.plan.offset % bs:
            problems.append(
                f"session offset {self.plan.offset} is off the {bs}-byte "
                f"block grid")
        bad_sp = [sp for sp in self.plan.splinters if sp.offset % bs]
        if bad_sp:
            problems.append(
                f"{len(bad_sp)} splinter offset(s) off the {bs}-byte grid "
                f"(first: splinter {bad_sp[0].index} at {bad_sp[0].offset}) "
                f"— plan the session with align=fs_block_size(path)")
        # Arena positions must land on the grid too (the DMA destination is
        # base + (sp.offset - plan.offset); with base and plan.offset
        # aligned this follows from aligned splinter offsets, so no extra
        # scan is needed).
        if problems:
            raise DirectIOError(
                "direct_io=True cannot run this session: "
                + "; ".join(problems))

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        """Begin greedy prefetch: every reader starts reading immediately
        (paper Fig. 5: "Buffer Chares begin reading on session instantiation,
        without waiting for client requests")."""
        if self.started:
            return
        self._validate_direct_io()
        self.started = True
        # Recorded here (not only in the async loop) so blocking-path
        # sessions still report their open mode.
        self.metrics.direct_io = bool(getattr(self.file, "direct_io", False))
        nthreads = min(
            max(1, self.plan.num_readers), max(1, self.opts.max_io_threads)
        )
        if self.opts.topology is not None and (
                self.opts.prefault_arena or self.opts.numa_pin):
            # Defer stealing until every thread's pin+first-touch setup is
            # done (see _setup_pending). Setup is microseconds (a syscall
            # + strided writes), so the gate lifts as soon as the last
            # thread is scheduled.
            self._setup_pending = nthreads
        self.metrics.session_started(self.plan.nbytes, self.plan.num_readers)
        if self.plan.nbytes:
            # Kick kernel readahead for the whole session before the first
            # pread lands (greedy prefetch starts now anyway).
            self.file.advise_sequential(self.plan.offset, self.plan.nbytes,
                                        stats=self.metrics.recovery)
        for t in range(nthreads):
            th = threading.Thread(
                target=self._reader_main, args=(t, nthreads), daemon=True
            )
            self._threads.append(th)
            th.start()

    def cancel(self) -> None:
        self._cancelled = True

    def stop(self, timeout: float = 10.0) -> bool:
        """Cancel and join the reader threads (file-close barrier).

        Returns True when every thread exited — only then is it safe to
        close the underlying file. False means a straggler survived the
        per-thread join timeout (e.g. a pread stalled on a dying FS) and
        may still touch the fd; the caller must not close it."""
        self._cancelled = True
        ok = True
        for th in self._threads:
            if th.is_alive():
                th.join(timeout)
                ok &= not th.is_alive()
        return ok

    def join(self, timeout: float = 120.0) -> bool:
        """Wait for all splinters to be resident (bench/driver use only —
        application code uses `when_available`/callbacks instead)."""
        return self._complete_evt.wait(timeout)

    @property
    def complete(self) -> bool:
        return self._complete_evt.is_set()

    def progress(self) -> Tuple[int, int]:
        with self._lock:
            return self._ndone, len(self._done)

    def arrival_order(self) -> Tuple[int, ...]:
        """Global splinter ids in the order their reads completed (snapshot).

        A permutation of ``range(len(plan.splinters))`` once the session is
        complete; work stealing and stragglers make it differ from file
        order, which is exactly what the device-side reassembly index maps
        (``data/packing.py``) consume."""
        with self._lock:
            return tuple(self._arrival)

    # -- reader threads -------------------------------------------------------
    def _next_splinter(self, tid: int, nthreads: int) -> Optional[Splinter]:
        """Pop own work first; steal from the most-backlogged reader if idle."""
        with self._lock:
            # own readers: reader indices congruent to tid (thread pool may be
            # smaller than the reader count)
            for r in range(tid, self.plan.num_readers, nthreads):
                if self._pending[r]:
                    return self._pending[r].pop(0)
            if self.opts.work_stealing and self._setup_pending == 0:
                victim = max(
                    range(self.plan.num_readers),
                    key=lambda r: len(self._pending[r]),
                    default=None,
                )
                if victim is not None and self._pending[victim]:
                    self.metrics.record_steal(victim)
                    return self._pending[victim].pop()  # steal from the tail
        return None

    def _thread_setup(self, tid: int, nthreads: int) -> None:
        """Per-I/O-thread NUMA placement, before the first read.

        With a topology: first-touch-fault the pages of every stripe this
        thread owns (``prefault_arena``) — with ``numa_pin``, pinned to
        *that stripe's* domain CPUs while touching it (a thread can own
        stripes in several domains when the pool is smaller than the
        reader count; re-pinning per domain is a cheap syscall and it is
        the touch-time affinity that decides first-touch placement), then
        settle on the primary stripe's domain for the read loop. Under
        Linux first-touch each stripe's memory thus lands on its own
        domain, one byte written per page, never a whole-arena zero-fill.
        Stolen splinters later read into already-placed pages, so
        straggler stealing cannot scatter a stripe across domains.
        """
        topo = self.opts.topology
        if topo is None:
            return
        owned = range(tid, self.plan.num_readers, nthreads)
        if not len(owned):
            return
        pinned_dom = [None]
        pin_outcomes: List[bool] = []

        def pin_to(dom: int) -> None:
            if not self.opts.numa_pin or dom == pinned_dom[0]:
                return
            cpus = topo.cpus_of_domain(dom)
            pin_outcomes.append(bool(cpus) and pin_thread_to_cpus(cpus))
            pinned_dom[0] = dom
        if self.opts.prefault_arena:
            for r in owned:
                lo, hi = self.plan.stripe_bounds[r]
                if hi > lo:
                    pin_to(self.reader_domain(r))
                    pages = first_touch(
                        self._arena[lo - self._base: hi - self._base])
                    self.locality.record_prefault(pages)
        pin_to(self.reader_domain(owned[0]))   # read-loop affinity
        if pin_outcomes:
            # One record per THREAD (the counter's name and the verify
            # docs read it as a thread count): success only if every
            # re-pin along the way (one per owned domain) succeeded.
            self.locality.record_pin(all(pin_outcomes))

    def _reader_main(self, tid: int, nthreads: int) -> None:
        gated = self._setup_pending > 0     # set before threads start
        if gated:
            try:
                self._thread_setup(tid, nthreads)
            finally:
                with self._lock:
                    self._setup_pending -= 1
        if self.opts.queue_depth >= 2:
            self._reader_main_async(tid, nthreads)
            return
        while not self._cancelled:
            sp = self._next_splinter(tid, nthreads)
            if sp is None:
                if not self.opts.work_stealing:
                    return            # own stripes drained; nothing to steal
                with self._lock:
                    has_work = any(self._pending)
                    gated = self._setup_pending > 0
                if not has_work:
                    return
                # Unclaimed splinters remain. Either stealing is still
                # setup-gated (spin briefly — the gate lifts within
                # microseconds of the last thread being scheduled) or the
                # gate lifted between our failed pop and this check —
                # retry immediately rather than exiting and silently
                # leaving the session without a thief.
                if gated:
                    time.sleep(0.0005)
                continue
            if self.opts.delay_model is not None:
                d = self.opts.delay_model(sp.reader, sp)
                if d > 0:
                    time.sleep(d)
            t0 = time.perf_counter()
            lo = sp.offset - self._base
            view = memoryview(self._arena)[lo : lo + sp.nbytes]
            n = self.file.pread_into(sp.offset, view,
                                     stats=self.metrics.recovery,
                                     fault=self.opts.io_fault)
            dt = time.perf_counter() - t0
            if n != sp.nbytes and not self._cancelled:
                raise IOError(
                    f"short read: wanted {sp.nbytes} at {sp.offset}, got {n}"
                )
            self.metrics.record_read(sp.reader, sp.nbytes, dt)
            if self._shard_of is not None:
                self.metrics.record_shard_read(self._shard_of(sp.offset),
                                               sp.nbytes)
            if self.opts.topology is not None:
                # Splinter-size histogram (per-reader sizing observable);
                # skipped without a topology to keep the default read loop
                # free of the extra lock acquisition.
                self.locality.record_splinter(sp.reader, sp.nbytes)
            self._mark_done(sp)

    def _reader_main_async(self, tid: int, nthreads: int) -> None:
        """Depth-managed drain: same work source (``_next_splinter`` — so
        stealing survives), same completion fan-out (``_mark_done``), but
        up to ``queue_depth`` splinter reads in flight through
        ``io/submit.py`` instead of one blocking pread at a time."""
        opts = self.opts
        delay = None
        if opts.delay_model is not None:
            dm = opts.delay_model

            def delay(sp, nbytes):
                d = dm(sp.reader, sp)
                if d > 0:
                    time.sleep(d)
        eng = AsyncReadEngine(
            self.file, opts.queue_depth,
            readahead_bytes=opts.readahead_bytes,
            mode=opts.submit_mode,
            stats=self.metrics.recovery,
            fault=opts.io_fault,
            delay=delay,
        )
        self.metrics.record_submit_config(
            opts.queue_depth, opts.readahead_bytes, eng.kind,
            bool(getattr(self.file, "direct_io", False)))

        def next_item():
            while not self._cancelled:
                sp = self._next_splinter(tid, nthreads)
                if sp is not None:
                    lo = sp.offset - self._base
                    view = memoryview(self._arena)[lo: lo + sp.nbytes]
                    return (sp, sp.offset, view)
                if not opts.work_stealing:
                    return None
                with self._lock:
                    has_work = any(self._pending)
                    g = self._setup_pending > 0
                if not has_work:
                    return None
                # Unclaimed splinters remain but stealing is setup-gated
                # (or the gate lifted between the failed pop and this
                # check) — retry, same as the synchronous loop.
                if g:
                    time.sleep(0.0005)
            return None

        def on_complete(sp, n, dt):
            if n != sp.nbytes and not self._cancelled:
                raise IOError(
                    f"short read: wanted {sp.nbytes} at {sp.offset}, got {n}"
                )
            # Folded per completion (not only in the finally below): join()
            # wakes on the last _mark_done, possibly before this thread's
            # engine teardown runs — the high-water mark must already be
            # visible to that waiter.
            self.metrics.record_inflight_hwm(eng.max_inflight)
            self.metrics.record_read(sp.reader, sp.nbytes, dt)
            if self._shard_of is not None:
                self.metrics.record_shard_read(self._shard_of(sp.offset),
                                               sp.nbytes)
            if opts.topology is not None:
                self.locality.record_splinter(sp.reader, sp.nbytes)
            self._mark_done(sp)

        try:
            eng.run(next_item, on_complete, stop=lambda: self._cancelled)
        finally:
            self.metrics.record_inflight_hwm(eng.max_inflight)

    def _mark_done(self, sp: Splinter, t_arrival: Optional[float] = None) -> None:
        """Record one splinter completion and fan out waiters/subscribers.

        ``t_arrival`` defaults to now; the process backend passes the
        worker-side completion timestamp instead (``perf_counter`` is
        CLOCK_MONOTONIC on Linux — comparable across processes)."""
        to_fire: List[Callable[[], None]] = []
        ev = SplinterEvent(
            index=sp.index,
            reader=sp.reader,
            offset=sp.offset,
            nbytes=sp.nbytes,
            arena_off=sp.offset - self._base,
            t_arrival=time.perf_counter() if t_arrival is None else t_arrival,
        )
        # _stream_lock spans the record + delivery so concurrent completions
        # reach every subscriber in the same order they enter ``_events``
        # (== ``_arrival`` order).
        with self._stream_lock:
            with self._lock:
                self._done[sp.index] = True
                self._ndone += 1
                self._arrival.append(sp.index)
                self._events.append(ev)
                if self._ndone == len(self._done):
                    self._complete_evt.set()
                for w in self._waiters_by_splinter.pop(sp.index, ()):  # type: ignore[arg-type]
                    w.remaining -= 1
                    if w.remaining == 0:
                        to_fire.append(w.fire)
                subs = list(self._subs.values()) if self._subs else ()
            for cb in subs:
                cb(ev)
        if not to_fire:
            return
        # One splinter can release many waiters; batch their enqueues into a
        # single scheduler lock/notify round.
        with self.sched.batch():
            for fire in to_fire:
                fire()

    # -- splinter completion stream -------------------------------------------
    def subscribe(
        self, cb: Callable[[SplinterEvent], None], replay: bool = True
    ) -> int:
        """Register ``cb`` for per-splinter completion events; returns a token.

        ``cb`` runs on the completing I/O thread and must be cheap (enqueue a
        scheduler task — the split-phase rule) and must not call
        ``subscribe``/``unsubscribe`` inline (delivery holds the stream lock).
        With ``replay=True`` (default), splinters that completed before the
        subscription are delivered first, in arrival order, before any new
        event — a subscriber attached mid-session misses nothing.
        """
        with self._stream_lock:
            with self._lock:
                token = self._next_sub
                self._next_sub += 1
                past = list(self._events) if replay else []
                self._subs[token] = cb
            for ev in past:
                cb(ev)
        return token

    def unsubscribe(self, token: int) -> None:
        """Remove a stream subscriber. Barrier semantics: once this returns,
        the callback will not be invoked again (any in-flight delivery has
        completed — both paths hold the stream lock)."""
        with self._stream_lock:
            with self._lock:
                self._subs.pop(token, None)

    def events(self) -> Tuple[SplinterEvent, ...]:
        """Snapshot of recorded completion events (arrival order)."""
        with self._lock:
            return tuple(self._events)

    # -- client-facing --------------------------------------------------------
    def when_available(
        self,
        abs_off: int,
        nbytes: int,
        fire: Callable[[], None],
        on_error: Optional[Callable[[BaseException], None]] = None,
    ) -> None:
        """Invoke ``fire`` once every byte of the range is resident.

        Thread-safe. ``fire`` must be cheap (it enqueues a scheduler task).
        If the data is already resident the callback runs immediately in the
        caller — the paper's "request buffered until the I/O is finished"
        semantics, with the buffered case handled by the waiter table.

        ``on_error`` is the failure channel (process backend): if the
        session dies before the range lands, ``on_error(exc)`` is delivered
        as a scheduler task instead of ``fire`` — exactly once per waiter.
        A request arriving after the failure raises synchronously here.
        """
        need = [
            s.index
            for s in splinters_covering(self.plan, abs_off, nbytes)
        ]
        with self._lock:
            if self.error is not None:
                raise self.error
            missing = [i for i in need if not self._done[i]]
            if missing:
                w = _Waiter(remaining=len(missing), fire=fire,
                            fail=on_error)
                for i in missing:
                    self._waiters_by_splinter.setdefault(i, []).append(w)
                return
        fire()

    def view(self, abs_off: int, nbytes: int) -> memoryview:
        """Zero-copy view of resident session bytes (the paper's zero-copy
        buffer→assembler hand-off; the Manager's tag table reduces to arena
        offsets in a shared address space)."""
        lo = abs_off - self._base
        return memoryview(self._arena)[lo : lo + nbytes]

    def borrow_view(self, abs_off: int, nbytes: int) -> memoryview:
        """Read-only zero-copy view handed to a client (``read(dest=None)``).

        Session-lifetime borrow: the view is tracked and *released* when the
        session closes, so use-after-close raises ``ValueError`` instead of
        silently reading recycled memory."""
        lo = abs_off - self._base
        mv = memoryview(self._arena)[lo : lo + nbytes].toreadonly()
        with self._lock:
            self._borrows.append(mv)
        return mv

    def invalidate_borrows(self) -> int:
        """Release every borrowed view (close_read_session). Returns count.

        A view with a live buffer export (e.g. an ``np.frombuffer`` array the
        client still holds) cannot be released — Python pins the memory for
        the exporter, so this stays memory-safe; the borrow is dropped from
        tracking and dies when the last exporter does."""
        with self._lock:
            borrows, self._borrows = self._borrows, []
        n = 0
        pinned = 0
        for mv in borrows:
            try:
                mv.release()
                n += 1
            except BufferError:   # live export pins the arena; safe to skip
                pinned += 1
        with self._lock:
            self._pinned_borrows += pinned
        return n

    def claim_error_surface(self) -> bool:
        """One-shot claim on surfacing this session's error as a *bare
        raising task* (for failed requests with no future to route the
        error into). Capped at one per session: the first raising task
        unblocks whichever pump is waiting, and a second one would linger
        in the queue to explode out of an unrelated later pump (e.g. the
        pipeline's teardown flush)."""
        with self._lock:
            if self._error_surfaced:
                return False
            self._error_surfaced = True
            return True

    def release(self) -> None:
        """Free backend resources after the session closed (no-op for the
        thread backend — the arena is ordinary process memory; the process
        backend unmaps/unlinks its shared-memory segments here)."""

    def reader_pe(self, r: int) -> int:
        return self.reader_pes[r]

    def reader_node(self, r: int) -> int:
        return self.sched.node_of(self.reader_pes[r])

    def reader_domain(self, r: int) -> int:
        """NUMA domain of reader ``r``'s PE (node granularity when no
        topology is configured — one memory domain per address space)."""
        pe = self.reader_pes[r]
        topo = self.opts.topology
        return topo.domain_of(pe) if topo is not None else \
            self.sched.node_of(pe)

    def reader_locality(self, r: int) -> Tuple[int, int]:
        """(node, domain) of reader ``r`` — the piece-coalescing key.

        Keyed on both so coalescing never merges across a scheduler node
        even when the topology's domain grid does not nest inside the
        node grid (a merged piece is attributed to its first reader, so a
        node-spanning merge would skip the NetworkModel transfer and
        miscount cross-node bytes for the tail of the piece)."""
        pe = self.reader_pes[r]
        topo = self.opts.topology
        node = self.sched.node_of(pe)
        return (node, topo.domain_of(pe) if topo is not None else node)


class ProcessReaderSet(BufferReaderSet):
    """Multi-process reader backend (``FileOptions(backend="process")``).

    The paper's buffer chares as real OS processes: the session arena is a
    shared-memory segment (``ipc/shm.py``) mapped into every reader worker
    process (``ipc/worker.py``) and this consumer process; splinter
    completions cross the process boundary through per-worker
    sequence-numbered event rings (``ipc/ring.py``) drained by a supervisor
    poller thread that re-enters the inherited ``_mark_done`` machinery —
    waiters, the splinter stream (``subscribe``/``read_stream``) and the
    streaming pipeline consume worker-process events transparently.

    Zero-copy delivery survives the split: ``view``/``borrow_view`` return
    memoryviews into the *mapped* arena, so ``bytes_copied`` stays 0 in the
    consumer process. PR-4's NUMA striping carries over: each worker
    first-touch-faults (and with ``numa_pin`` ``sched_setaffinity``-pins
    itself to) its own stripes before the supervisor opens the start gate,
    so domain placement is decided by the owning *process* and pinning
    spans real CPU sets.

    Lifecycle (the supervisor half of the ``ipc/worker.py`` protocol):
    ``start`` spawns workers (``spawn`` — no fork of this process's
    threads/JAX state) + the poller; the poller waits for every worker to
    attach, records their first-touch/pin reports, unlinks the segment
    names (mappings keep them alive — after this point a parent crash
    leaks nothing in ``/dev/shm``: orphaned workers notice the vanished
    supervisor via the getppid() checks polled in every wait loop and
    exit, and the last mapping frees the pages; only a SIGKILL landing in
    the short spawn→attach window can leave named segments behind), opens
    the gates, then drains rings until the session is complete. A worker that reports ``ERROR`` — or vanishes before
    ``DONE`` — fails the session fast: ``join``/``wait_attached`` raise,
    pending waiters are dropped, and a raising task is enqueued so any
    scheduler-pumping read call surfaces a descriptive :class:`WorkerCrashed`
    within one poll interval instead of hanging. ``stop``/``cancel``
    request a graceful drain (workers exit between splinters) and the
    poller SIGKILLs survivors after ``worker_stop_timeout``.

    Deliberate differences from the thread backend: no work stealing (the
    pending queues cannot be shared), ``delay_model``/``worker_fault`` must
    be picklable, and a worker process pins once (its primary stripe's
    domain) rather than re-pinning per stripe.

    Fault recovery (``ReaderOptions(recovery=...)``): with recovery
    enabled, a worker that dies, errors, or trips the no-progress watchdog
    *after* the start gate opened no longer fails the session — its
    unfinished splinters are re-routed, either to a replacement process
    attached to the same arena (``"respawn"``, bounded by
    ``max_respawns``) or to an emergency supervisor-side reader
    (``"reissue"``). Both paths re-enter ``_mark_done``, so waiters,
    subscriber order/replay, the arrival log and zero-copy delivery all
    behave as if the original worker had read the bytes — double delivery
    is impossible (``_done[index]`` already gates it) and ``bytes_copied``
    stays 0 (the bytes land in the same shared pages). Attach-phase
    failures remain terminal in every mode: the first-touch placement
    barrier cannot be re-run. Recovery observables land in
    ``metrics.recovery`` (:class:`~repro.core.metrics.RecoveryMetrics`).
    """

    def __init__(
        self,
        file: PosixFile,
        plan: StripePlan,
        sched: TaskScheduler,
        reader_pes: List[int],
        opts: ReaderOptions,
        metrics: Optional[SessionMetrics] = None,
    ):
        self._shm: Optional[SharedArena] = None
        super().__init__(file, plan, sched, reader_pes, opts, metrics)
        self._rings_shm: Optional[SharedArena] = None
        self._rings: List[EventRing] = []
        self._procs: List[object] = []
        self._poller: Optional[threading.Thread] = None
        self._attached_evt = threading.Event()
        self._gates_open = False
        # -- recovery state (supervisor thread only, except where noted) --
        # per-worker splinter assignment (parallel to _procs/_rings; what a
        # recovery has to re-route), retirement flags (a retired worker is
        # excluded from liveness checks — its work moved elsewhere), and
        # last-ring-progress stamps (the watchdog's signal).
        self._worker_splinters: List[Tuple[Splinter, ...]] = []
        self._worker_retired: List[bool] = []
        self._last_progress: List[float] = []
        # respawned worker -> (attach deadline, failure-detection stamp);
        # its gate opens individually as soon as it attaches.
        self._pending_attach: Dict[int, Tuple[float, float]] = {}
        # respawned workers get their own ring segments (the original ring
        # block's name is unlinked at gate open); unlinked at their own
        # gate open, closed at shutdown.
        self._extra_ring_shms: Dict[int, SharedArena] = {}
        self._respawns_used = 0
        self._reissue_threads: List[threading.Thread] = []
        self._workers_shutdown = False   # one-shot guard (io-counter fold)

    def _alloc_arena(self, plan: StripePlan) -> np.ndarray:
        # Named shm segment instead of private np.empty: ftruncate allocates
        # lazily, so no page is faulted here — first touch happens in the
        # worker that owns the stripe (the cross-process analog of PR-4's
        # per-thread first-touch; the legacy zero-fill prefault does not
        # apply to this backend).
        self._shm = SharedArena.create(plan.nbytes, tag="sess")
        return self._shm.ndarray()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        if self.started:
            return
        self._validate_direct_io()
        self.started = True
        self.metrics.direct_io = bool(getattr(self.file, "direct_io", False))
        self.metrics.session_started(self.plan.nbytes, self.plan.num_readers)
        if self.opts.queue_depth >= 2:
            # Workers decide io_uring-vs-threads themselves (their kernel
            # view is authoritative); mirror the same selection rule here
            # so the session metrics name the backend they will pick.
            from repro.io.submit import io_uring_supported
            kind = "io_uring" if (
                self.opts.submit_mode in ("auto", "io_uring")
                and getattr(self.file, "segments", None) is None
                and self.opts.delay_model is None
                and io_uring_supported()) else "threads"
            self.metrics.record_submit_config(
                self.opts.queue_depth, self.opts.readahead_bytes, kind,
                bool(getattr(self.file, "direct_io", False)))
        if not self.plan.splinters:
            self._gates_open = True          # trivially: nothing to attach
            self._attached_evt.set()
            return
        # Readahead from the parent helps too: the page cache is shared
        # with the workers.
        self.file.advise_sequential(self.plan.offset, self.plan.nbytes,
                                    stats=self.metrics.recovery)
        nworkers = min(self.plan.num_readers, max(1, self.opts.max_workers))
        rb = ring_bytes(self.opts.ring_slots)
        self._rings_shm = SharedArena.create(nworkers * rb, tag="rings")
        region = self._rings_shm.buf
        topo = self.opts.topology
        import multiprocessing as mp

        ctx = mp.get_context("spawn")
        try:
            self._spawn_workers(ctx, nworkers, rb, region, topo)
        except BaseException:
            # Spawn failed (unpicklable delay/fault hook, resource error):
            # the poller that would normally unlink the named segments and
            # reap workers will never run — run its teardown here or the
            # tmpfs names (and any already-started worker) leak forever.
            self._shutdown_workers()
            self._procs = []
            raise
        self._poller = threading.Thread(
            target=self._poll_main, daemon=True, name="ckio-ring-poller")
        self._poller.start()

    def _spawn_workers(self, ctx, nworkers: int, rb: int,
                       region: memoryview, topo: Optional[Topology]) -> None:
        for w in range(nworkers):
            self._rings.append(EventRing(
                region[w * rb: (w + 1) * rb], self.opts.ring_slots,
                create=True,
            ))
            owned = list(range(w, self.plan.num_readers, nworkers))
            pin_cpus = None
            if self.opts.numa_pin and topo is not None and owned:
                cpus = topo.cpus_of_domain(self.reader_domain(owned[0]))
                pin_cpus = tuple(cpus) if cpus else None
            spec = WorkerSpec(
                worker_id=w,
                file_path=self.file.path,
                arena_path=self._shm.path,
                arena_bytes=self.plan.nbytes,
                base_offset=self._base,
                ring_path=self._rings_shm.path,
                ring_region_bytes=nworkers * rb,
                ring_offset=w * rb,
                ring_slots=self.opts.ring_slots,
                splinters=tuple(
                    sp for r in owned
                    for sp in self.plan.splinters_for_reader(r)),
                stripe_bounds=tuple(
                    self.plan.stripe_bounds[r] for r in owned),
                prefault=self.opts.prefault_arena,
                pin_cpus=pin_cpus,
                delay_model=self.opts.delay_model,
                fault=self.opts.worker_fault,
                io_fault=self.opts.io_fault,
                ring_fault=self.opts.ring_fault,
                parent_pid=os.getpid(),
                shards=getattr(self.file, "worker_segments", None),
                direct_io=self.opts.direct_io,
                queue_depth=self.opts.queue_depth,
                readahead_bytes=self.opts.readahead_bytes,
                submit_mode=self.opts.submit_mode,
            )
            self._worker_splinters.append(spec.splinters)
            self._worker_retired.append(False)
            self._last_progress.append(time.monotonic())
            self._procs.append(ctx.Process(
                target=worker_main, args=(spec,), daemon=True,
                name=f"ckio-reader-{w}",
            ))
        for p in self._procs:
            p.start()

    def wait_attached(self, timeout: float = 120.0) -> bool:
        """Block until every worker has attached + placed its stripes (the
        supervisor opened the start gates) — the point where drain timing
        starts in benchmarks. Raises if the session already failed;
        returns False if it was cancelled (or timed out) before the gates
        opened, rather than sleeping out the timeout on a torn-down
        session (cancel and poller exit both wake this event)."""
        ok = self._attached_evt.wait(timeout)
        if self.error is not None:
            raise self.error
        return ok and self._gates_open

    def worker_pids(self) -> List[int]:
        """Live (non-retired) worker pids, ring-reported — what a fault
        harness SIGKILLs to exercise recovery from outside."""
        return [self._rings[w].pid()
                for w in range(len(self._rings))
                if not self._worker_retired[w] and self._rings[w].pid()]

    def cancel(self) -> None:
        self._cancelled = True
        for ring in list(self._rings):
            ring.request_stop()
        # Wake anyone parked on the attach barrier of a session that will
        # now never open its gates (wait_attached returns False).
        self._attached_evt.set()

    def stop(self, timeout: float = 30.0) -> bool:
        """Graceful drain + join (SIGKILL on timeout happens in the
        poller's shutdown); True once poller and workers are gone."""
        self.cancel()
        th = self._poller
        if th is not None and th.is_alive():
            th.join(timeout)
            if th.is_alive():
                return False
        return all(not p.is_alive() for p in self._procs)

    def join(self, timeout: float = 120.0) -> bool:
        ok = self._complete_evt.wait(timeout)
        if self.error is not None:
            raise self.error
        return ok

    def release(self) -> None:
        """Unmap/unlink the shm segments once the session is closed.

        Joins the (cancelled) poller first — it owns the ring mappings.
        The arena unmap is best-effort: any chunk view still pinned by a
        staged device transfer keeps its pages alive until the exporter
        dies (the names were already unlinked, so nothing leaks)."""
        th = self._poller
        if th is not None and th.is_alive():
            self.cancel()
            th.join(self.opts.worker_stop_timeout + 15.0)
            if th.is_alive():      # stuck worker: leave mappings to GC
                return
        if self._shm is not None:
            # Best-effort: ``self._arena`` still exports the mapping (late
            # piece-delivery tasks racing the close may read through it,
            # exactly like the thread backend's arena), so close() here
            # typically only unlinks; the pages are freed the moment the
            # last exporter — the session object itself — is dropped.
            self._shm.close()

    # -- supervisor poller ----------------------------------------------------
    def _on_ring_event(self, ev: RingEvent) -> None:
        sp = Splinter(reader=ev.reader, index=ev.index,
                      offset=ev.offset, nbytes=ev.nbytes)
        self.metrics.record_read(ev.reader, ev.nbytes, ev.read_dt)
        if self._shard_of is not None:
            self.metrics.record_shard_read(self._shard_of(ev.offset),
                                           ev.nbytes)
        if self.opts.topology is not None:
            self.locality.record_splinter(ev.reader, ev.nbytes)
        self._mark_done(sp, t_arrival=ev.t_arrival)

    def _fail(self, exc: BaseException) -> None:
        """Fail the session fast: record the error, unblock every waiter
        path (join / wait_attached / scheduler pumps) with it."""
        with self._lock:
            if self.error is not None:
                return
            self.error = exc
            waiters: List[_Waiter] = []
            seen = set()
            for ws in self._waiters_by_splinter.values():
                for w in ws:
                    if id(w) not in seen:         # distinct, once each
                        seen.add(id(w))
                        waiters.append(w)
            self._waiters_by_splinter.clear()
            self._complete_evt.set()
        self._attached_evt.set()

        def raise_error() -> None:
            raise exc

        # Every registered waiter gets the error through its own failure
        # channel (the assembler routes it to the request's future /
        # callback — exactly once per request), so EVERY blocked caller
        # fails fast, not just whichever pump pops a task first. A waiter
        # without an error channel (bench/driver join()-style code) gets a
        # raising task to unblock its pump. Requests arriving after the
        # failure raise synchronously in when_available, so nothing is
        # delivered twice.
        with self.sched.batch():
            for w in waiters:
                if w.fail is not None:
                    self.sched.enqueue(0, w.fail, exc, label="ckio-read-error")
                elif self.claim_error_surface():
                    # Channel-less waiters share one raising task (see
                    # claim_error_surface).
                    self.sched.enqueue(0, raise_error,
                                       label="ckio-worker-error")

    def _worker_label(self, w: int) -> str:
        ring, p = self._rings[w], self._procs[w]
        pid = ring.pid() or getattr(p, "pid", None)
        return f"reader worker {w} (pid {pid})"

    def _poll_main(self) -> None:
        total = len(self._done)
        gated = True
        deadline = time.monotonic() + self.opts.worker_attach_timeout
        pause = 50e-6
        try:
            while not self._cancelled:
                progressed = 0
                for w in range(len(self._rings)):
                    events = self._rings[w].consume(limit=1024)
                    for ev in events:
                        self._on_ring_event(ev)
                    if events:
                        self._last_progress[w] = time.monotonic()
                    progressed += len(events)
                if gated:
                    # Initial attach barrier. Recovery never runs while
                    # gated (attach-phase failures are terminal — see
                    # _handle_worker_failure), so _rings still holds
                    # exactly the original workers here.
                    states = [r.state() for r in self._rings]
                    if any(st == ST_ERROR for st in states):
                        # A worker died during attach: do NOT open gates or
                        # report attachment — fall through to the dead-
                        # child loop below, which fails the session
                        # (wait_attached then raises instead of returning
                        # success on a dying session).
                        pass
                    elif all(st != ST_INIT for st in states):
                        for ring in self._rings:
                            pages, pin = ring.touch_report()
                            if pages:
                                self.locality.record_prefault(pages)
                            if pin != PIN_NONE:
                                self.locality.record_pin(pin == PIN_OK)
                            ring.open_gate()
                        # Names are no longer needed (everyone holds a
                        # mapping): unlink now so nothing leaks in
                        # /dev/shm even if this process dies. With
                        # recovery="respawn" the ARENA name must survive —
                        # a replacement worker attaches to it by name — so
                        # its unlink waits for _shutdown_workers (the
                        # SIGKILL-leak window widens from spawn→attach to
                        # the session lifetime; that is the price of
                        # in-place respawn and it is opt-in).
                        if self.opts.recovery != "respawn":
                            self._shm.unlink()
                        self._rings_shm.unlink()
                        gated = False
                        self._gates_open = True
                        now = time.monotonic()
                        for w in range(len(self._last_progress)):
                            self._last_progress[w] = now
                        self._attached_evt.set()
                    elif time.monotonic() > deadline:
                        waiting = [w for w, r in enumerate(self._rings)
                                   if r.state() == ST_INIT]
                        self._fail(WorkerCrashed(
                            f"reader worker(s) {waiting} failed to attach "
                            f"within {self.opts.worker_attach_timeout}s"))
                        return
                if self._pending_attach and not self._check_pending_attach():
                    return
                with self._lock:
                    if self._ndone >= total:
                        return
                if not gated:
                    self._watchdog_sweep()
                for w in range(len(self._procs)):
                    if self._worker_retired[w]:
                        continue
                    p, ring = self._procs[w], self._rings[w]
                    st = ring.state()
                    if st != ST_ERROR and (st == ST_DONE or p.is_alive()):
                        continue
                    # Dead or errored. Drain anything it published before
                    # dying, then decide: the session may actually be
                    # complete.
                    events = ring.consume()
                    for ev in events:
                        self._on_ring_event(ev)
                    progressed += len(events)
                    with self._lock:
                        ndone = self._ndone
                    if ndone >= total:
                        return
                    if ring.state() == ST_ERROR:
                        msg = (f"{self._worker_label(w)} failed: "
                               f"{ring.error_message()}")
                    else:
                        msg = (f"{self._worker_label(w)} exited with code "
                               f"{p.exitcode} before completing its "
                               f"splinters ({ndone}/{total} read)")
                    if not self._handle_worker_failure(w, msg, gated):
                        return
                if progressed:
                    pause = 50e-6
                else:
                    time.sleep(pause)
                    pause = min(pause * 2, 2e-3)   # futex-free backoff
        finally:
            self._shutdown_workers()
            # Whatever ended the poll loop, nobody may stay parked on the
            # attach barrier of a dead session.
            self._attached_evt.set()

    # -- recovery (supervisor thread) -----------------------------------------
    def _shard_attribution(
            self, splinters: List[Splinter]) -> Optional[Dict[int, int]]:
        """FileSet sessions: re-routed bytes per shard id (splinters never
        span shards). None for single-file sessions."""
        if self._shard_of is None:
            return None
        by: Dict[int, int] = {}
        for sp in splinters:
            sh = self._shard_of(sp.offset)
            by[sh] = by.get(sh, 0) + sp.nbytes
        return by

    def _unfinished(self, w: int) -> List[Splinter]:
        """Splinters assigned to worker ``w`` that have not landed (its
        ring must be drained first so nothing already-published counts)."""
        with self._lock:
            return [sp for sp in self._worker_splinters[w]
                    if not self._done[sp.index]]

    def _retire_worker(self, w: int) -> None:
        self._worker_retired[w] = True
        self._pending_attach.pop(w, None)

    def _handle_worker_failure(self, w: int, msg: str, gated: bool) -> bool:
        """A worker died / errored (ring drained). Recover per
        ``opts.recovery`` or fail the session; returns True when the
        session should keep running.

        Attach-phase failures are always terminal: the go-gate exists so
        every stripe's first-touch placement completes before any read,
        and that collective barrier cannot be re-run once gates opened.
        Post-gate, a replacement skips prefault entirely (stripe pages
        either carry placement from the dead worker's touch or hold
        already-read data a re-touch would corrupt — first_touch writes).
        """
        unfinished = self._unfinished(w)
        self._retire_worker(w)
        if not unfinished:
            # Everything it owned already landed (e.g. died after its last
            # publish but before ST_DONE) — nothing to recover.
            return True
        mode = self.opts.recovery
        if gated or mode == "none":
            self._fail(WorkerCrashed(msg))
            return False
        t_detect = time.monotonic()
        if mode == "respawn":
            if self._respawns_used >= self.opts.max_respawns:
                self._fail(WorkerCrashed(
                    f"{msg}; respawn budget exhausted "
                    f"({self.opts.max_respawns})"))
                return False
            return self._respawn_worker(unfinished, msg, t_detect)
        if mode == "reissue":
            self._reissue_splinters(unfinished, t_detect)
            return True
        self._fail(WorkerCrashed(msg))     # unknown mode: behave as "none"
        return False

    def _respawn_worker(self, unfinished: List[Splinter], msg: str,
                        t_detect: float) -> bool:
        """Spawn a replacement process owning exactly the unfinished tail.

        The replacement attaches to the SAME session arena by name (which
        is why the arena unlink is deferred under this mode) and to a fresh
        ring segment of its own, then runs the normal go-gate protocol —
        its gate opens individually in _check_pending_attach. ``prefault``
        is off and ``stripe_bounds`` empty: re-touching pages that already
        hold read data would corrupt them.
        """
        import multiprocessing as mp
        self._respawns_used += 1
        rb = ring_bytes(self.opts.ring_slots)
        try:
            shm = SharedArena.create(rb, tag="ring-r")
        except OSError as e:
            self._fail(WorkerCrashed(f"{msg}; respawn failed: {e}"))
            return False
        new_w = len(self._procs)
        ring = EventRing(shm.buf[:rb], self.opts.ring_slots, create=True)
        spec = WorkerSpec(
            worker_id=new_w,
            file_path=self.file.path,
            arena_path=self._shm.path,
            arena_bytes=self.plan.nbytes,
            base_offset=self._base,
            ring_path=shm.path,
            ring_region_bytes=rb,
            ring_offset=0,
            ring_slots=self.opts.ring_slots,
            splinters=tuple(unfinished),
            stripe_bounds=(),
            prefault=False,
            pin_cpus=None,
            delay_model=self.opts.delay_model,
            fault=self.opts.worker_fault,
            io_fault=self.opts.io_fault,
            ring_fault=self.opts.ring_fault,
            parent_pid=os.getpid(),
            shards=getattr(self.file, "worker_segments", None),
            direct_io=self.opts.direct_io,
            queue_depth=self.opts.queue_depth,
            readahead_bytes=self.opts.readahead_bytes,
            submit_mode=self.opts.submit_mode,
        )
        ctx = mp.get_context("spawn")
        p = ctx.Process(target=worker_main, args=(spec,), daemon=True,
                        name=f"ckio-reader-r{new_w}")
        try:
            p.start()
        except BaseException as e:
            shm.close()
            self._fail(WorkerCrashed(f"{msg}; respawn failed: {e}"))
            return False
        self._rings.append(ring)
        self._procs.append(p)
        self._worker_splinters.append(tuple(unfinished))
        self._worker_retired.append(False)
        self._last_progress.append(time.monotonic())
        self._extra_ring_shms[new_w] = shm
        self._pending_attach[new_w] = (
            time.monotonic() + self.opts.worker_attach_timeout, t_detect)
        self.metrics.recovery.record_respawn(
            len(unfinished), sum(sp.nbytes for sp in unfinished),
            by_shard=self._shard_attribution(unfinished))
        return True

    def _check_pending_attach(self) -> bool:
        """Open the go-gate of each respawned worker as it attaches (its
        placement phase is empty — no collective barrier to wait for).
        Returns False only on a terminal attach timeout."""
        for w in list(self._pending_attach):
            attach_deadline, t_detect = self._pending_attach[w]
            if self._rings[w].state() == ST_INIT:
                if time.monotonic() > attach_deadline:
                    self._fail(WorkerCrashed(
                        f"respawned {self._worker_label(w)} failed to "
                        f"attach within {self.opts.worker_attach_timeout}s"))
                    return False
                continue
            # Attached (or already errored — the dead-child loop will see
            # ST_ERROR next iteration either way): open its private gate.
            self._rings[w].open_gate()
            shm = self._extra_ring_shms.get(w)
            if shm is not None:
                shm.unlink()
            self._last_progress[w] = time.monotonic()
            self.metrics.recovery.record_recovery_latency(
                time.monotonic() - t_detect)
            del self._pending_attach[w]
        return True

    def _reissue_splinters(self, unfinished: List[Splinter],
                           t_detect: float) -> None:
        """Re-read a dead worker's unfinished splinters supervisor-side.

        A surviving worker's splinter list is fixed at spawn (SPSC rings
        carry no work-push channel), so "reassign to surviving readers"
        means: an emergency reader thread in THIS process reads the tail
        through the parent's own fd straight into the mapped arena and
        re-enters _mark_done — every delivery invariant (waiters,
        subscriber order, arrival log, zero-copy views) holds because it
        is the same fan-out path, and ``bytes_copied`` stays 0 because the
        bytes land in the same shared pages workers write. Worker-side
        injection hooks (delay_model / worker_fault / io_fault) model the
        dead worker's environment and deliberately do NOT apply here."""
        self.metrics.recovery.record_reissue(
            len(unfinished), sum(sp.nbytes for sp in unfinished),
            by_shard=self._shard_attribution(unfinished))
        th = threading.Thread(
            target=self._reissue_main, args=(list(unfinished), t_detect),
            daemon=True, name="ckio-reissue")
        self._reissue_threads.append(th)
        th.start()

    def _reissue_main(self, splinters: List[Splinter],
                      t_detect: float) -> None:
        try:
            for sp in splinters:
                if self._cancelled or self.error is not None:
                    return
                t0 = time.perf_counter()
                lo = sp.offset - self._base
                view = memoryview(self._arena)[lo: lo + sp.nbytes]
                n = self.file.pread_into(sp.offset, view,
                                         stats=self.metrics.recovery)
                dt = time.perf_counter() - t0
                if n != sp.nbytes:
                    raise IOError(
                        f"short read re-issuing splinter {sp.index}: "
                        f"wanted {sp.nbytes} at {sp.offset}, got {n}")
                self.metrics.record_read(sp.reader, sp.nbytes, dt)
                if self._shard_of is not None:
                    self.metrics.record_shard_read(
                        self._shard_of(sp.offset), sp.nbytes)
                if self.opts.topology is not None:
                    self.locality.record_splinter(sp.reader, sp.nbytes)
                self._mark_done(sp)
            self.metrics.recovery.record_recovery_latency(
                time.monotonic() - t_detect)
        except BaseException as e:
            self._fail(WorkerCrashed(f"splinter re-issue failed: {e}"))

    def _watchdog_sweep(self) -> None:
        """SIGKILL any live worker that owns unfinished splinters but has
        published nothing for ``worker_watchdog_s`` — a hung pread (dying
        FS) or a stalled process. The dead-child loop then converts the
        kill into recovery (or a terminal failure under recovery="none",
        which still turns a silent hang into a descriptive error)."""
        wd = self.opts.worker_watchdog_s
        if wd <= 0:
            return
        now = time.monotonic()
        for w in range(len(self._procs)):
            if self._worker_retired[w] or w in self._pending_attach:
                continue
            p, ring = self._procs[w], self._rings[w]
            if ring.state() in (ST_DONE, ST_ERROR) or not p.is_alive():
                continue
            if now - self._last_progress[w] <= wd:
                continue
            if not self._unfinished(w):
                continue
            self.metrics.recovery.record_watchdog_kill()
            p.kill()
            p.join(5.0)

    def _shutdown_workers(self) -> None:
        """Graceful drain, then SIGKILL-on-timeout; releases ring mappings."""
        if self._workers_shutdown:
            return
        self._workers_shutdown = True
        rings, procs = self._rings, self._procs
        for ring in rings:
            ring.request_stop()
        deadline = time.monotonic() + self.opts.worker_stop_timeout
        # ``p.pid is None`` = never started (spawn aborted mid-loop) —
        # join/kill on those raise instead of no-op'ing.
        for p in procs:
            if p.pid is not None:
                p.join(max(0.0, deadline - time.monotonic()))
        for p in procs:
            if p.pid is not None and p.is_alive():
                p.kill()
                p.join(5.0)
        # Emergency re-issue readers exit between splinters once cancel or
        # completion lands; join them before the arena mapping goes away.
        for th in self._reissue_threads:
            if th.is_alive():
                th.join(5.0)
        # Fold each worker's transient-I/O counters (ring header words)
        # into the session's recovery metrics — exactly once, guarded by
        # _workers_shutdown above.
        for ring in rings:
            r, s = ring.io_report()
            if r or s:
                self.metrics.recovery.add_worker_io(r, s)
        # Workers are gone: the names can't be needed again. Unlink here
        # too (idempotent) so a session that failed before the gate opened
        # still leaves nothing behind in /dev/shm. Under recovery="respawn"
        # this is where the deferred arena unlink happens.
        if self._shm is not None:
            self._shm.unlink()
        # Drop the parent-side ring views before closing their mapping (a
        # live export pins it — close() tolerates stragglers either way).
        self._rings = []
        del rings
        if self._rings_shm is not None:
            self._rings_shm.close()
            self._rings_shm = None
        for shm in self._extra_ring_shms.values():
            shm.close()                # idempotent unlink + unmap
        self._extra_ring_shms = {}
