"""Migratable clients: LocationManager + VirtualProxy.

Charm++ chares migrate between PEs under RTS control while holding open file
and session handles; CkIO keeps their reads working by addressing callbacks to
the *virtual* chare proxy rather than a physical PE (paper §IV-A.3). We
reproduce that: consumers register with a ``LocationManager`` under a virtual
id; a ``VirtualProxy`` resolves the id to the current PE at *delivery* time.
``migrate()`` just updates the table — in-flight reads complete at the new
location, which the migration test and benchmark (paper Fig. 10–12) verify.

The same mechanism backs *elastic scaling* in the training pipeline: when the
consumer count or host set changes, consumers are re-registered (migrated)
and the reader layer is untouched.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Optional

from repro.core.scheduler import TaskScheduler


class LocationManager:
    """Virtual-id → current-PE table (thread-safe)."""

    def __init__(self, sched: TaskScheduler):
        self.sched = sched
        self._lock = threading.Lock()
        self._where: Dict[int, int] = {}
        self._next_vid = 0
        self.migrations = 0
        self.stale_deliveries = 0

    def register(self, pe: int, vid: Optional[int] = None) -> int:
        with self._lock:
            if vid is None:
                vid = self._next_vid
                self._next_vid += 1
            if not (0 <= pe < self.sched.num_pes):
                raise ValueError(f"PE {pe} out of range")
            self._where[vid] = pe
            self._next_vid = max(self._next_vid, vid + 1)
            return vid

    def migrate(self, vid: int, new_pe: int) -> None:
        with self._lock:
            if vid not in self._where:
                raise KeyError(f"unknown virtual id {vid}")
            if not (0 <= new_pe < self.sched.num_pes):
                raise ValueError(f"PE {new_pe} out of range")
            self._where[vid] = new_pe
            self.migrations += 1

    def deregister(self, vid: int) -> None:
        """Retire a virtual id (consumer destruction / elastic shrink).

        Later ``lookup``/``migrate`` on the id raise ``KeyError`` — a retired
        consumer must not silently resolve to a stale PE. Idempotent."""
        with self._lock:
            self._where.pop(vid, None)

    def count(self) -> int:
        """Currently registered virtual ids (leak detector for tests)."""
        with self._lock:
            return len(self._where)

    def count_stale(self) -> None:
        """Fold an externally-detected stale delivery into the counter (a
        streamed splinter event reaching a step that already finalized —
        same observability channel as the routing-level drops)."""
        with self._lock:
            self.stale_deliveries += 1

    def lookup(self, vid: int) -> int:
        with self._lock:
            return self._where[vid]

    def lookup_or_home(self, vid: int) -> int:
        """PE for delivery: current location, or the home PE (0) when the id
        has been deregistered — completions racing an elastic shrink must
        still land somewhere (Charm++: messages to a destroyed chare are
        delivered via its home location manager). Counted for observability."""
        with self._lock:
            pe = self._where.get(vid)
            if pe is None:
                self.stale_deliveries += 1
                return 0
            return pe

    def lookup_or_drop(self, vid: int) -> Optional[int]:
        """PE for delivery, or ``None`` when the id has been deregistered.

        The drop-capable variant of ``lookup_or_home`` for *streamed splinter
        deliveries*: a request completion racing an elastic shrink must land
        somewhere (home PE — the data was asked for), but a splinter event
        addressed to a retired consumer must be **dropped**, never rerouted —
        rerouting could deliver it to a consumer slot reused by a later
        ``resize()`` grow, staging the same bytes twice. Drops are counted in
        ``stale_deliveries`` alongside the home-PE fallbacks."""
        with self._lock:
            pe = self._where.get(vid)
            if pe is None:
                self.stale_deliveries += 1
                return None
            return pe

    def proxy(self, vid: int) -> "VirtualProxy":
        return VirtualProxy(self, vid)


class VirtualProxy:
    """Late-binding handle to a migratable consumer."""

    __slots__ = ("loc", "vid")

    def __init__(self, loc: LocationManager, vid: int):
        self.loc = loc
        self.vid = vid

    def current_pe(self) -> int:
        return self.loc.lookup(self.vid)

    def delivery_pe(self) -> int:
        """Current PE, falling back to the home PE for deregistered ids."""
        return self.loc.lookup_or_home(self.vid)

    def delivery_pe_or_drop(self) -> Optional[int]:
        """Current PE, or ``None`` (drop, counted) for deregistered ids."""
        return self.loc.lookup_or_drop(self.vid)

    def current_node(self) -> int:
        return self.loc.sched.node_of(self.current_pe())


class Client:
    """Base class for migratable data consumers (the paper's client chares).

    Holds a virtual id; exposes ``callback(fn)`` which builds a CkCallback
    routed through the proxy, so continuations chase the client across
    migrations.
    """

    def __init__(self, loc: LocationManager, pe: int):
        self.loc = loc
        self.vid = loc.register(pe)

    @property
    def pe(self) -> int:
        return self.loc.lookup(self.vid)

    @property
    def node(self) -> int:
        return self.loc.sched.node_of(self.pe)

    def migrate(self, new_pe: int) -> None:
        self.loc.migrate(self.vid, new_pe)

    def deregister(self) -> None:
        """Drop this client from the location table (idempotent)."""
        self.loc.deregister(self.vid)

    def callback(self, fn: Callable, drop_stale: bool = False) -> "CkCallback":
        """Continuation routed through the virtual proxy.

        ``drop_stale=True`` selects drop-and-count delivery for retired ids
        (streamed splinter events) instead of the home-PE fallback (request
        completions) — see ``LocationManager.lookup_or_drop``."""
        from repro.core.futures import CkCallback

        return CkCallback(fn, proxy=self.loc.proxy(self.vid),
                          drop_stale=drop_stale)
