"""CkIO core — the paper's contribution: two-phase, split-phase parallel file
input with reader/consumer decomposition independence, greedy read sessions,
splintered I/O, work-stealing straggler mitigation, and migratable consumers.
"""
from repro.core.api import CkIO
from repro.core.autotune import AutoTuner, SplinterSizer, suggest_num_readers
from repro.core.buffers import (
    BufferReaderSet,
    NetworkModel,
    ProcessReaderSet,
    ReaderOptions,
    SplinterEvent,
)
from repro.ipc.worker import WorkerCrashed
from repro.core.faults import FaultPlan
from repro.core.futures import CkCallback, CkFuture
from repro.core.migration import Client, LocationManager, VirtualProxy
from repro.core.placement import Topology, place_readers
from repro.core.scheduler import BackgroundWorker, TaskScheduler
from repro.core.metrics import (
    IngestMetrics,
    LocalityMetrics,
    RecoveryMetrics,
    ServeMetrics,
    SessionMetrics,
    StreamMetrics,
    percentile,
)
from repro.core.session import FileHandle, FileOptions, Session
from repro.core.assembler import ReadComplete

__all__ = [
    "CkIO",
    "Topology",
    "place_readers",
    "LocalityMetrics",
    "AutoTuner",
    "SplinterSizer",
    "suggest_num_readers",
    "BufferReaderSet",
    "NetworkModel",
    "ProcessReaderSet",
    "WorkerCrashed",
    "FaultPlan",
    "RecoveryMetrics",
    "ReaderOptions",
    "SplinterEvent",
    "StreamMetrics",
    "CkCallback",
    "CkFuture",
    "Client",
    "LocationManager",
    "VirtualProxy",
    "BackgroundWorker",
    "TaskScheduler",
    "FileHandle",
    "FileOptions",
    "IngestMetrics",
    "ServeMetrics",
    "percentile",
    "Session",
    "SessionMetrics",
    "ReadComplete",
]
