"""Read sessions: handles, options, lifecycle state (paper §III-A)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from repro.core.buffers import BufferReaderSet, NetworkModel, ReaderOptions
from repro.core.faults import FaultPlan
from repro.core.metrics import SessionMetrics
from repro.core.placement import Topology
from repro.io.layout import StripePlan
from repro.io.posix import PosixFile


@dataclass
class FileOptions:
    """Paper: ``Ck::IO::Options`` — ``numReaders`` is the headline knob."""

    num_readers: Optional[int] = None       # None → autotuned (§VI-A)
    splinter_bytes: int = 8 * 1024 * 1024
    # Reader backend: "thread" (default — helper I/O threads in this
    # process) or "process" (real reader worker processes preadv-ing into a
    # shared-memory arena, splinter events over cross-process rings; see
    # src/repro/ipc/ and core.buffers.ProcessReaderSet). Zero-copy borrowed
    # views and the splinter stream work identically in both; the process
    # backend has no work stealing and needs picklable delay/fault hooks.
    backend: str = "thread"
    # process backend: cap on worker processes per session (readers are
    # split across workers the way threads split readers).
    max_workers: int = 8
    # process backend: per-worker splinter-event ring capacity (slots). A
    # full ring throttles its worker; it never drops or overwrites events.
    ring_slots: int = 512
    # process backend: picklable crash-injection hook run in the worker
    # before each splinter read ((reader, splinter_index) -> None; e.g.
    # repro.ipc.worker.ExitAfter / RaiseAfter). Test/bench only.
    worker_fault: object = None
    # process backend: seconds to wait for spawned workers to attach
    # (interpreter start + imports — raise on cold/slow-spawn hosts)
    # before the session fails, and the graceful-drain join window
    # before SIGKILL on stop.
    worker_attach_timeout: float = 120.0
    worker_stop_timeout: float = 10.0
    # Dynamic splinter sizing: when True, each new session's splinter size is
    # chosen by the Director's SplinterSizer from observed per-reader
    # throughput and steal pressure (core/autotune.py); ``splinter_bytes``
    # then only seeds the first session (no observations yet).
    adaptive_splinters: bool = False
    work_stealing: bool = True
    max_io_threads: int = 64
    placement: str = "node_spread"          # see core/placement.py
    network: Optional[NetworkModel] = None
    delay_model: object = None              # test hook, forwarded to readers
    piece_timing_every: int = 0             # 0 = delivery timing off (hot path)
    # PE -> NUMA-domain model (core/placement.py Topology): turns on
    # domain-coalesced pieces, cross-domain delivery accounting, topology-
    # aware placement policies, and the first-touch arena prefault.
    topology: Optional[Topology] = None
    # Pin reader I/O threads to their stripe's domain CPUs (needs a
    # topology with a CPU map, e.g. Topology.detect; best-effort).
    numa_pin: bool = False
    # Without a topology: zero-fill the arena up front (legacy seed path).
    # With a topology: per-stripe first-touch on the owning reader thread.
    prefault_arena: bool = False
    # -- fault tolerance ------------------------------------------------------
    # process backend: post-gate worker-failure policy — "none" (fail fast,
    # the default), "respawn" (replacement process, same arena, bounded by
    # max_respawns) or "reissue" (supervisor re-reads the unfinished tail).
    # See core.buffers.ProcessReaderSet.
    recovery: str = "none"
    max_respawns: int = 2
    # process backend: no-progress watchdog (seconds; 0 = off) — a hung
    # worker is SIGKILLed and then handled per ``recovery``.
    worker_watchdog_s: float = 0.0
    # Opt-in degraded mode: when backend="process" setup fails (spawn or
    # shm errors), rebuild the session on this backend instead of raising.
    # Only "thread" (or None = no fallback) is valid; warns once per
    # FileOptions and sets RecoveryMetrics.degraded_mode on each session.
    fallback_backend: Optional[str] = None
    # Fault-injection hooks for the lower layers (picklable for the
    # process backend; core/faults.py): io_fault → PosixFile.pread_into,
    # ring_fault → EventRing.publish.
    io_fault: object = None
    ring_fault: object = None
    # A seeded core.faults.FaultPlan: expands into worker_fault /
    # delay_model / io_fault / ring_fault for any hook not set explicitly
    # (explicit hooks win). The deterministic-replay entry point.
    fault_plan: Optional[FaultPlan] = None
    # -- cold-cache read engine (io/submit.py) -------------------------------
    # Open the file(s) O_DIRECT: reads bypass the page cache and DMA
    # straight into the arena. Requires block-aligned session offset, arena
    # and (for FileSets) shard data regions — violations raise
    # io.posix.DirectIOError at open/start, never silently fall back;
    # sub-block tails go through the buffered fd, counted in
    # RecoveryMetrics.direct_tail_reads.
    direct_io: bool = False
    # In-flight reads per reader: 0/1 = the blocking per-splinter loop;
    # >= 2 = depth-managed async submission through io/submit.py.
    queue_depth: int = 0
    # WILLNEED window (bytes) advised ahead of the submission frontier
    # (buffered files only — O_DIRECT bypasses the cache readahead).
    readahead_bytes: int = 0
    # Submission backend: "auto" (io_uring when the kernel/sandbox allows,
    # else the preadv worker pool), or force "io_uring"/"threads".
    submit_mode: str = "auto"
    # When True, each session's (queue_depth, readahead_bytes) is chosen by
    # the Director's QueueTuner from observed throughput; the explicit
    # fields then only seed the first session.
    adaptive_queue: bool = False
    # -- persistent reader service (ipc/service.py) --------------------------
    # Routing for process-backend sessions when a ReaderService is attached
    # to the Director: None ("auto", the default) runs on the service and
    # falls back to legacy per-session spawn if admission rejects
    # (ServiceBusy); True pins the session to the service (ServiceBusy
    # surfaces to the caller); False opts out (always legacy spawn). With
    # no service attached, every value behaves like False.
    use_service: Optional[bool] = None
    # Admission fair-share key: sessions from distinct tenants split the
    # service's worker pool fairly ("" = the shared default tenant).
    tenant: str = ""

    def reader_options(self) -> ReaderOptions:
        if self.backend not in ("thread", "process"):
            raise ValueError(
                f"unknown reader backend {self.backend!r} "
                f"(expected 'thread' or 'process')")
        if self.recovery not in ("none", "respawn", "reissue"):
            raise ValueError(
                f"unknown recovery mode {self.recovery!r} "
                f"(expected 'none', 'respawn' or 'reissue')")
        if self.fallback_backend not in (None, "thread"):
            raise ValueError(
                f"unknown fallback backend {self.fallback_backend!r} "
                f"(expected None or 'thread')")
        if self.submit_mode not in ("auto", "io_uring", "threads"):
            raise ValueError(
                f"unknown submit mode {self.submit_mode!r} "
                f"(expected 'auto', 'io_uring' or 'threads')")
        if self.queue_depth < 0:
            raise ValueError(
                f"queue_depth must be >= 0, got {self.queue_depth}")
        if self.readahead_bytes < 0:
            raise ValueError(
                f"readahead_bytes must be >= 0, got {self.readahead_bytes}")
        worker_fault = self.worker_fault
        delay_model = self.delay_model
        io_fault = self.io_fault
        ring_fault = self.ring_fault
        if self.fault_plan is not None:
            worker_fault = worker_fault or self.fault_plan.worker_fault()
            delay_model = delay_model or self.fault_plan.delay_model()
            io_fault = io_fault or self.fault_plan.io_fault()
            ring_fault = ring_fault or self.fault_plan.ring_fault()
        return ReaderOptions(
            splinter_bytes=self.splinter_bytes,
            work_stealing=self.work_stealing,
            max_io_threads=self.max_io_threads,
            backend=self.backend,
            max_workers=self.max_workers,
            ring_slots=self.ring_slots,
            worker_fault=worker_fault,
            worker_attach_timeout=self.worker_attach_timeout,
            worker_stop_timeout=self.worker_stop_timeout,
            recovery=self.recovery,
            max_respawns=self.max_respawns,
            worker_watchdog_s=self.worker_watchdog_s,
            io_fault=io_fault,
            ring_fault=ring_fault,
            delay_model=delay_model,  # type: ignore[arg-type]
            network=self.network,
            piece_timing_every=self.piece_timing_every,
            topology=self.topology,
            numa_pin=self.numa_pin,
            prefault_arena=self.prefault_arena,
            direct_io=self.direct_io,
            queue_depth=self.queue_depth,
            readahead_bytes=self.readahead_bytes,
            submit_mode=self.submit_mode,
        )


@dataclass
class FileHandle:
    """Returned by ``CkIO.open`` / ``CkIO.open_fileset`` (paper:
    ``Ck::IO::File``). ``posix`` is a ``PosixFile`` for single-file opens
    and the byte-space-compatible ``io.posix.ShardedFile`` for FileSet
    opens (``fileset`` then carries the manifest; offsets are global data
    bytes — header pages excluded)."""

    id: int
    path: str
    posix: PosixFile                    # or io.posix.ShardedFile (duck-typed)
    opts: FileOptions
    fileset: Optional[object] = None    # data.fileset.FileSet when sharded

    @property
    def size(self) -> int:
        return self.posix.size


@dataclass
class Session:
    """Live read session (paper: ``Ck::IO::Session``)."""

    id: int
    file: FileHandle
    plan: StripePlan
    readers: BufferReaderSet
    opts: FileOptions
    reader_pes: List[int]
    metrics: SessionMetrics = field(default_factory=SessionMetrics)
    closed: bool = False

    @property
    def offset(self) -> int:
        return self.plan.offset

    @property
    def nbytes(self) -> int:
        return self.plan.nbytes

    @property
    def num_readers(self) -> int:
        return self.plan.num_readers

    def contains(self, abs_off: int, nbytes: int) -> bool:
        return abs_off >= self.plan.offset and abs_off + nbytes <= self.plan.end

    @property
    def arrival_order(self):
        """Splinter completion order (see BufferReaderSet.arrival_order)."""
        return self.readers.arrival_order()

    @property
    def locality(self):
        """Per-session memory-locality counters (LocalityMetrics)."""
        return self.readers.locality

    # -- streaming ------------------------------------------------------------
    def subscribe_splinters(self, cb, replay: bool = True) -> int:
        """Per-splinter completion stream (see BufferReaderSet.subscribe)."""
        return self.readers.subscribe(cb, replay=replay)

    def unsubscribe_splinters(self, token: int) -> None:
        self.readers.unsubscribe(token)

    @property
    def splinter_events(self):
        """Recorded completion events so far (arrival order snapshot)."""
        return self.readers.events()
