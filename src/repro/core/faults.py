"""Deterministic fault injection for the reader runtime.

The recovery layer (worker respawn, splinter re-issue, I/O retry, ring
CRC-retry) is only trustworthy if its failure paths are *reproducibly*
exercisable. This module provides:

* picklable injector hooks for every layer the runtime exposes a seam at —
  worker crash (``CrashReader`` / ``CrashSplinter``), syscall faults
  (``FlakyEIO`` / ``ShortRead`` plug into ``PosixFile.pread_into``), and
  torn ring publications (``TornSlot`` plugs into ``EventRing.publish``).
  All are plain dataclasses so ``spawn`` can ship them to reader worker
  processes through ``WorkerSpec``;
* :class:`FaultPlan` — a *seeded* schedule over those hooks: the same seed
  always derives the same injection points (which reader crashes after how
  many splinters, which syscalls fail, which slots publish torn), so a
  failing fault run is replayable from nothing but its seed
  (``CKIO_FAULT_SEED`` in CI's fault-matrix leg).

Hooks with per-process counters (``CrashReader``, ``FlakyEIO``, …) reset in
a respawned worker — deliberately: a *transient* fault clears on respawn.
``CrashSplinter`` is the persistent variant (keyed on the global splinter
index, it fires in every generation) for driving respawn-budget exhaustion.
"""
from __future__ import annotations

import errno
import os
import random
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.io.layout import Splinter
from repro.ipc.worker import ExitAfter, RaiseAfter, StallReader  # noqa: F401
#   re-exported: a FaultPlan user gets every injector from one module


# -- worker-level injectors ----------------------------------------------------
@dataclass
class CrashReader:
    """Hard-crash the worker right after it has read ``after`` splinters of
    reader ``reader`` (``os._exit`` — no cleanup, like a segfault). The
    counter is per-process, so a respawned worker with fewer than ``after``
    of that reader's splinters left completes — the "transient crash"
    injector a successful respawn needs."""

    reader: int
    after: int
    code: int = 66
    _seen: int = 0

    def __call__(self, reader: int, index: int) -> None:
        if reader != self.reader:
            return
        if self._seen >= self.after:
            os._exit(self.code)
        self._seen += 1


@dataclass
class CrashSplinter:
    """Hard-crash any worker generation that attempts the given *global*
    splinter index — a persistently poisonous splinter. Every respawn dies
    at the same point, which is how respawn-budget exhaustion is driven
    deterministically."""

    index: int
    code: int = 71

    def __call__(self, reader: int, index: int) -> None:
        if index == self.index:
            os._exit(self.code)


@dataclass
class DelayEach:
    """delay_model: stretch every splinter read by ``seconds`` (all
    readers). Benchmarks use it to give a drain a controlled duration so a
    mid-drain kill reliably lands mid-drain."""

    seconds: float

    def __call__(self, reader: int, sp: Splinter) -> float:
        return self.seconds


# -- io-level injectors (PosixFile.pread_into ``fault`` hook) ------------------
@dataclass
class FlakyEIO:
    """Raise a transient ``OSError`` on every ``every``-th syscall — the
    blip the posix retry/backoff layer must absorb. ``every=1`` makes the
    fault persistent (retry-exhaustion tests)."""

    every: int
    err: int = errno.EIO
    _n: int = 0

    def __call__(self, offset: int, nbytes: int) -> Optional[int]:
        self._n += 1
        if self.every and self._n % self.every == 0:
            raise OSError(self.err, "injected transient I/O error")
        return None


@dataclass
class ShortRead:
    """Cap every ``every``-th syscall at ``max_bytes`` — deterministic
    short reads, exercising the pread_into resume loop."""

    every: int
    max_bytes: int = 4096
    _n: int = 0

    def __call__(self, offset: int, nbytes: int) -> Optional[int]:
        self._n += 1
        if self.every and self._n % self.every == 0:
            return min(self.max_bytes, nbytes)
        return None


@dataclass
class ComposedIOFault:
    """Apply several io-fault hooks to one syscall: the first raiser wins;
    otherwise the smallest returned cap applies."""

    hooks: Tuple[object, ...]

    def __call__(self, offset: int, nbytes: int) -> Optional[int]:
        cap: Optional[int] = None
        for h in self.hooks:
            c = h(offset, nbytes)
            if c is not None:
                cap = c if cap is None else min(cap, c)
        return cap


# -- ring-level injector (EventRing.publish ``fault`` hook) --------------------
@dataclass
class TornSlot:
    """Publish every ``every``-th ring slot stamp-first with ``delay_s``
    before the payload lands — the simulated weakly-ordered host. The
    consumer's seq-keyed CRC must reject the slot until the payload is
    visible (re-read, never delivered torn, never deadlocked)."""

    every: int
    delay_s: float = 2e-3

    def __call__(self, seq: int) -> bool:
        return bool(self.every) and (seq + 1) % self.every == 0


# -- the seeded schedule -------------------------------------------------------
@dataclass
class FaultPlan:
    """A deterministic, seed-derived fault schedule.

    Toggle the fault classes on (``crash`` / ``stall`` / ``short_reads`` /
    ``flaky_io`` / ``torn_slots``); *where* each fires — which reader, after
    how many splinters, every how many syscalls/slots — is derived from
    ``seed`` alone (given the same ``num_readers``/``num_splinters`` layout
    hints), so two runs with one seed inject identically and a CI failure
    replays from the seed in its log.

    ``FileOptions(fault_plan=...)`` expands the plan into the per-layer
    hooks (worker_fault / delay_model / io_fault / ring_fault) unless a
    hook is also set explicitly (explicit wins).
    """

    seed: int
    crash: bool = True
    stall: bool = False
    short_reads: bool = False
    flaky_io: bool = False
    torn_slots: bool = False
    # layout hints the schedule derives injection points from
    num_readers: int = 2
    num_splinters: int = 16
    stall_seconds: float = 0.05
    # derived (filled by __post_init__ — do not pass)
    crash_reader: int = field(init=False, default=0)
    crash_after: int = field(init=False, default=1)
    crash_code: int = field(init=False, default=64)
    stall_reader: int = field(init=False, default=0)
    short_every: int = field(init=False, default=0)
    short_max_bytes: int = field(init=False, default=4096)
    eio_every: int = field(init=False, default=0)
    torn_every: int = field(init=False, default=0)

    def __post_init__(self) -> None:
        rng = random.Random(self.seed)
        per_reader = max(1, self.num_splinters // max(1, self.num_readers))
        self.crash_reader = rng.randrange(self.num_readers)
        # Crash strictly inside the reader's stripe (at least one splinter
        # read, at least one left) so recovery always has work to re-route.
        self.crash_after = 1 + rng.randrange(max(1, per_reader - 1))
        self.crash_code = 64 + rng.randrange(32)
        self.stall_reader = rng.randrange(self.num_readers)
        self.short_every = 2 + rng.randrange(3)
        self.short_max_bytes = 4096 * (1 + rng.randrange(4))
        self.eio_every = 3 + rng.randrange(4)
        self.torn_every = 2 + rng.randrange(3)

    # -- hook factories (None when that fault class is off) -------------------
    def worker_fault(self) -> Optional[object]:
        if not self.crash:
            return None
        return CrashReader(
            reader=self.crash_reader, after=self.crash_after,
            code=self.crash_code)

    def delay_model(self) -> Optional[object]:
        if not self.stall:
            return None
        return StallReader(self.stall_reader, self.stall_seconds)

    def io_fault(self) -> Optional[object]:
        hooks = []
        if self.short_reads:
            hooks.append(ShortRead(self.short_every, self.short_max_bytes))
        if self.flaky_io:
            hooks.append(FlakyEIO(self.eio_every))
        if not hooks:
            return None
        if len(hooks) == 1:
            return hooks[0]
        return ComposedIOFault(tuple(hooks))

    def ring_fault(self) -> Optional[object]:
        if not self.torn_slots:
            return None
        return TornSlot(self.torn_every)

    def describe(self) -> Dict[str, object]:
        """The concrete injection points — equal for equal seeds (the
        determinism contract tests and CI assert on)."""
        return {
            "seed": self.seed,
            "crash": (self.crash, self.crash_reader, self.crash_after,
                      self.crash_code),
            "stall": (self.stall, self.stall_reader, self.stall_seconds),
            "short_reads": (self.short_reads, self.short_every,
                            self.short_max_bytes),
            "flaky_io": (self.flaky_io, self.eio_every),
            "torn_slots": (self.torn_slots, self.torn_every),
        }
