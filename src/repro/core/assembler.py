"""ReadAssembler: per-PE request fulfilment (paper §III-C.3).

All read requests from clients on a given PE are handled by that PE's
assembler. A request may span multiple buffer readers; the assembler splits
it into pieces, registers availability waiters with the reader set, and as
pieces land copies them into the client's destination buffer *on the client's
PE* (as a scheduled task — never inline from an I/O thread). When the last
piece arrives it fires the user's ``after_read`` callback, which Charm++ would
deliver as an asynchronous method invocation and we deliver as a scheduler
task routed through the client's virtual proxy (so it survives migration).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

from repro.core.futures import CkCallback
from repro.core.metrics import SessionMetrics
from repro.core.scheduler import TaskScheduler
from repro.io.layout import pieces_for_range


@dataclass
class ReadComplete:
    """Message delivered to ``after_read`` (paper: read completion msg)."""

    offset: int
    nbytes: int
    data: Any            # the destination buffer passed to read()
    session_id: int
    latency_s: float


class _RequestState:
    __slots__ = ("outstanding", "lock", "t0")

    def __init__(self, n: int):
        self.outstanding = n
        self.lock = threading.Lock()
        self.t0 = time.perf_counter()

    def piece_done(self) -> bool:
        with self.lock:
            self.outstanding -= 1
            return self.outstanding == 0


def _as_byteview(buf: Any) -> memoryview:
    mv = memoryview(buf)
    if mv.format != "B" or mv.ndim != 1:
        mv = mv.cast("B")
    if mv.readonly:
        raise ValueError("read() destination buffer must be writable")
    return mv


class ReadAssembler:
    """One per PE (a chare-group member in the paper)."""

    def __init__(self, sched: TaskScheduler, pe: int):
        self.sched = sched
        self.pe = pe

    def submit(
        self,
        session: "Session",  # noqa: F821 (circular; duck-typed)
        abs_off: int,
        nbytes: int,
        dest: Any,
        after_read: CkCallback,
        metrics: Optional[SessionMetrics] = None,
    ) -> None:
        readers = session.readers
        plan = session.plan
        dest_view = _as_byteview(dest)
        if len(dest_view) < nbytes:
            raise ValueError(
                f"destination buffer too small: {len(dest_view)} < {nbytes}"
            )
        metrics = metrics or session.metrics
        pieces = pieces_for_range(plan, abs_off, nbytes)
        state = _RequestState(len(pieces))
        net = session.opts.network
        my_node = self.sched.node_of(self.pe)

        def make_piece_handler(reader: int, p_off: int, p_len: int):
            dst_lo = p_off - abs_off

            def copy_on_pe() -> None:
                t0 = time.perf_counter()
                src = readers.view(p_off, p_len)
                dest_view[dst_lo : dst_lo + p_len] = src
                cross = readers.reader_node(reader) != my_node
                metrics.record_piece(p_len, cross, time.perf_counter() - t0)
                if state.piece_done():
                    lat = time.perf_counter() - state.t0
                    metrics.record_request(lat)
                    msg = ReadComplete(
                        offset=abs_off,
                        nbytes=nbytes,
                        data=dest,
                        session_id=session.id,
                        latency_s=lat,
                    )
                    after_read.send(self.sched, msg)

            def on_available() -> None:
                # Runs on an I/O thread (or inline if data already resident):
                # model the buffer→client transfer, then enqueue the copy as
                # a task on this PE.
                cross = readers.reader_node(reader) != my_node
                enqueue = lambda: self.sched.enqueue(  # noqa: E731
                    self.pe, copy_on_pe, label="ckio-piece"
                )
                if net is not None:
                    net.deliver(p_len, not cross, enqueue)
                else:
                    enqueue()

            return on_available

        for reader, p_off, p_len in pieces:
            readers.when_available(
                p_off, p_len, make_piece_handler(reader, p_off, p_len)
            )
