"""ReadAssembler: per-PE request fulfilment (paper §III-C.3).

All read requests from clients on a given PE are handled by that PE's
assembler. A request may span multiple buffer readers; the assembler splits
it into pieces, registers availability waiters with the reader set, and as
pieces land copies them into the client's destination buffer *on the client's
PE* (as a scheduled task — never inline from an I/O thread). When the last
piece arrives it fires the user's ``after_read`` callback, which Charm++ would
deliver as an asynchronous method invocation and we deliver as a scheduler
task routed through the client's virtual proxy (so it survives migration).

Hot-path structure (this is the per-piece cost every delivered byte pays):

* pieces are **coalesced by (node, memory domain)**
  (``pieces_for_range(coalesce_key=...)``): contiguous stripes whose
  readers share a scheduler node AND a NUMA domain (without a
  ``Topology``, just the node) merge into one piece — one waiter, one
  scheduled task, one copy — since the session arena is directly
  addressable within a node (Thakur-style request merging). Domain
  granularity keeps each merged piece's bytes on one memory controller,
  so a same-domain assembler touches only local memory; with a topology,
  cross- vs same-domain delivered bytes are tracked per session in
  ``LocalityMetrics`` (the counter NUMA-aware placement is judged by).
* ``dest=None`` selects the **borrowed-view** path (paper §III-C.4's
  zero-copy buffer→assembler hand-off): ``after_read`` receives a read-only
  ``memoryview`` into the session arena instead of a filled buffer. The view
  is a *session-lifetime borrow* — it is invalidated (released) by
  ``close_read_session``; copy out anything needed beyond that.
* per-piece wall timing runs only when ``metrics.should_time_piece()`` says
  so (sampled/off by default), keeping instrumentation off the hot path.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Optional

from repro.core.futures import CkCallback
from repro.core.metrics import SessionMetrics
from repro.core.scheduler import TaskScheduler
from repro.io.layout import pieces_for_range


@dataclass
class ReadComplete:
    """Message delivered to ``after_read`` (paper: read completion msg).

    ``data`` is the destination buffer passed to ``read()``, or — on the
    borrowed-view path (``dest=None``) — a read-only memoryview into the
    session arena, valid until the session closes.
    """

    offset: int
    nbytes: int
    data: Any
    session_id: int
    latency_s: float


class _RequestState:
    __slots__ = ("outstanding", "lock", "t0", "failed")

    def __init__(self, n: int):
        self.outstanding = n
        self.lock = threading.Lock()
        self.t0 = time.perf_counter()
        self.failed = False

    def piece_done(self) -> bool:
        with self.lock:
            self.outstanding -= 1
            return self.outstanding == 0 and not self.failed

    def mark_failed(self) -> bool:
        """First piece-waiter to report a session failure wins — the
        request surfaces its error exactly once."""
        with self.lock:
            first = not self.failed
            self.failed = True
            return first


def _as_byteview(buf: Any) -> memoryview:
    mv = memoryview(buf)
    if mv.format != "B" or mv.ndim != 1:
        mv = mv.cast("B")
    if mv.readonly:
        raise ValueError("read() destination buffer must be writable")
    return mv


class ReadAssembler:
    """One per PE (a chare-group member in the paper)."""

    def __init__(self, sched: TaskScheduler, pe: int):
        self.sched = sched
        self.pe = pe

    def submit(
        self,
        session: "Session",  # noqa: F821 (circular; duck-typed)
        abs_off: int,
        nbytes: int,
        dest: Any,
        after_read: CkCallback,
        metrics: Optional[SessionMetrics] = None,
        materialize_view: bool = True,
        classify_locality: bool = True,
    ) -> None:
        """Fulfil one client request.

        ``dest=None`` is the zero-copy path; with ``materialize_view=False``
        the completion message carries ``data=None`` (residency signal only —
        no borrow is created or tracked), for callers that will view the
        arena themselves later. ``classify_locality=False`` skips the
        same-/cross-domain LocalityMetrics accounting for this request —
        used by callers whose delivered bytes are classified elsewhere
        (the streaming pipeline's whole-window residency probe, whose
        bytes the splinter stream already classifies per event)."""
        readers = session.readers
        plan = session.plan
        zero_copy = dest is None
        dest_view: Optional[memoryview] = None
        if not zero_copy:
            dest_view = _as_byteview(dest)
            if len(dest_view) < nbytes:
                raise ValueError(
                    f"destination buffer too small: {len(dest_view)} < {nbytes}"
                )
        metrics = metrics or session.metrics
        # Coalesce by (node, NUMA domain) when a topology is configured
        # (plain node otherwise): merged pieces never span a memory domain
        # *or* a scheduler node — a merged piece is attributed to its
        # first reader, so both the NetworkModel decision and the domain
        # classification below stay correct for the whole piece.
        pieces = pieces_for_range(
            plan, abs_off, nbytes, coalesce_key=readers.reader_locality
        )
        state = _RequestState(len(pieces))

        def fail_request(exc: BaseException) -> None:
            """Session died before this request's data landed (process
            backend worker crash): surface the error exactly once per
            request — through the caller's future when there is one
            (``read_sync`` and friends raise it from their wait).
            Future-less requests (plain callbacks, ``read_notify``) share
            ONE raising task per session (``claim_error_surface``): it
            unblocks the waiting pump, and capping it keeps failed
            fan-outs from littering the queue with tasks that would
            re-raise out of unrelated later pumps."""
            if not state.mark_failed():
                return
            fut = getattr(after_read, "future", None)
            if fut is not None:
                fut.set_error(exc)
                return
            if not session.readers.claim_error_surface():
                return

            def raise_error() -> None:
                raise exc

            self.sched.enqueue(self.pe, raise_error, label="ckio-read-error")

        net = session.opts.network
        my_node = self.sched.node_of(self.pe)
        topo = session.opts.topology
        # Domain classification (LocalityMetrics) only runs with a
        # topology: without one it would duplicate record_piece's
        # cross-node counter at an extra lock acquisition per piece on
        # the delivery hot path.
        my_domain = (topo.domain_of(self.pe)
                     if topo is not None and classify_locality else None)

        def finish() -> None:
            lat = time.perf_counter() - state.t0
            metrics.record_request(lat)
            if zero_copy:
                data = (readers.borrow_view(abs_off, nbytes)
                        if materialize_view else None)
            else:
                data = dest
            msg = ReadComplete(
                offset=abs_off,
                nbytes=nbytes,
                data=data,
                session_id=session.id,
                latency_s=lat,
            )
            after_read.send(self.sched, msg)

        def make_piece_handler(reader: int, p_off: int, p_len: int):
            dst_lo = p_off - abs_off
            cross = readers.reader_node(reader) != my_node
            cross_domain = (my_domain is not None
                            and readers.reader_domain(reader) != my_domain)

            def deliver_on_pe() -> None:
                timed = metrics.should_time_piece()
                t0 = time.perf_counter() if timed else 0.0
                copied = 0
                if not zero_copy:
                    src = readers.view(p_off, p_len)
                    dest_view[dst_lo : dst_lo + p_len] = src
                    copied = p_len
                metrics.record_piece(
                    p_len,
                    cross,
                    (time.perf_counter() - t0) if timed else None,
                    copied=copied,
                    borrowed=zero_copy,
                )
                if my_domain is not None:
                    readers.locality.record_delivery(p_len, not cross_domain)
                if state.piece_done():
                    finish()

            def on_available() -> None:
                # Runs on an I/O thread (or inline if data already resident):
                # model the buffer→client transfer, then enqueue the delivery
                # as a task on this PE. Borrowed-view (zero-copy) pieces skip
                # the model: the client receives a view of the arena — same
                # address space, or the mapped shm segment under the process
                # backend — so no bytes cross a node; modeling a transfer
                # AND reporting a zero-copy delivery would double-count the
                # piece (its locality lands in cross_node_view_bytes).
                enqueue = lambda: self.sched.enqueue(  # noqa: E731
                    self.pe, deliver_on_pe, label="ckio-piece"
                )
                if net is not None and not zero_copy:
                    net.deliver(p_len, not cross, enqueue)
                else:
                    enqueue()

            return on_available

        if not pieces:
            # Zero-length read: still split-phase — complete via the queue.
            self.sched.enqueue(self.pe, finish, label="ckio-piece")
            return
        # Batch the resident-data case: pieces already in the arena fire
        # inline here, and the batch turns their enqueues into one
        # lock/notify round.
        with self.sched.batch():
            for reader, p_off, p_len in pieces:
                readers.when_available(
                    p_off, p_len, make_piece_handler(reader, p_off, p_len),
                    on_error=fail_request,
                )
