"""Reader-count selection (paper future-work §VI-A, implemented).

Two pieces:

* ``suggest_num_readers`` — a closed-form heuristic from file size and
  machine shape. The paper's Figs. 1/4 show a U-curve: too few readers miss
  disk parallelism, too many congest the FS with small requests. The
  heuristic targets a fixed bytes-per-reader chunk (large enough for
  streaming bandwidth) bounded by [1 per node, 2 per PE].
* ``AutoTuner`` — online refinement: records (num_readers → throughput)
  observations across sessions and explores the power-of-two neighbourhood
  of the current best (the search-based approach of Behzad et al. [4] that
  the paper cites, restricted to a single knob).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


def suggest_num_readers(
    file_bytes: int,
    num_pes: int,
    num_nodes: int = 1,
    target_chunk_bytes: int = 64 * 1024 * 1024,
) -> int:
    if file_bytes <= 0:
        return 1
    by_chunk = max(1, (file_bytes + target_chunk_bytes - 1) // target_chunk_bytes)
    lo = max(1, num_nodes)            # at least one independent path per node
    hi = max(lo, 2 * num_pes)         # paper Fig. 4: beyond ~2/PE only adds contention
    return int(min(max(by_chunk, lo), hi))


@dataclass
class AutoTuner:
    """Online power-of-two hillclimb over the reader count."""

    num_pes: int
    num_nodes: int = 1
    observations: Dict[int, List[float]] = field(default_factory=dict)
    _trial_queue: List[int] = field(default_factory=list)

    def record(self, num_readers: int, throughput: float) -> None:
        self.observations.setdefault(num_readers, []).append(throughput)

    def _score(self, r: int) -> float:
        obs = self.observations.get(r, [])
        return sum(obs) / len(obs) if obs else float("-inf")

    def best(self) -> Optional[int]:
        if not self.observations:
            return None
        return max(self.observations, key=self._score)

    def suggest(self, file_bytes: int) -> int:
        seed = suggest_num_readers(file_bytes, self.num_pes, self.num_nodes)
        if not self.observations:
            return seed
        best = self.best()
        assert best is not None
        # explore the untried half/double neighbour with the best prior
        for cand in (best, max(1, best // 2), best * 2):
            if cand not in self.observations and cand <= 4 * self.num_pes:
                return cand
        return best
