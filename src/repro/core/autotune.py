"""Online tuning of the reader layer (paper future-work §VI-A, implemented).

Three pieces:

* ``suggest_num_readers`` — a closed-form heuristic from file size and
  machine shape. The paper's Figs. 1/4 show a U-curve: too few readers miss
  disk parallelism, too many congest the FS with small requests. The
  heuristic targets a fixed bytes-per-reader chunk (large enough for
  streaming bandwidth) bounded by [1 per node, 2 per PE].
* ``AutoTuner`` — online refinement: records (num_readers → throughput)
  observations across sessions and explores the power-of-two neighbourhood
  of the current best (the search-based approach of Behzad et al. [4] that
  the paper cites, restricted to a single knob).
* ``SplinterSizer`` — dynamic splinter sizing for the streaming delivery
  path: sizes the unit of physical I/O from observed per-reader throughput
  (large splinters on fast streaming stripes — fewer syscalls, better
  sequential bandwidth) shrunk under steal pressure (small splinters near
  straggler-stolen tails — finer-grained stealing, tighter completion
  bound).

``AutoTuner`` and ``SplinterSizer`` share one observation path:
``record_session(metrics)`` takes the ``SessionMetrics`` every session
already collects — the Director feeds both on session close, so any
controller added later observes for free.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.metrics import SessionMetrics


def suggest_num_readers(
    file_bytes: int,
    num_pes: int,
    num_nodes: int = 1,
    target_chunk_bytes: int = 64 * 1024 * 1024,
) -> int:
    if file_bytes <= 0:
        return 1
    by_chunk = max(1, (file_bytes + target_chunk_bytes - 1) // target_chunk_bytes)
    lo = max(1, num_nodes)            # at least one independent path per node
    hi = max(lo, 2 * num_pes)         # paper Fig. 4: beyond ~2/PE only adds contention
    return int(min(max(by_chunk, lo), hi))


@dataclass
class AutoTuner:
    """Online power-of-two hillclimb over the reader count.

    Exploration is **deterministic**: given the same observation history,
    ``suggest`` returns the same value. The candidate order is fixed —
    current best, then its half, then its double — and the first candidate
    without observations (and within the ``4 * num_pes`` contention cap) is
    explored; with the whole neighbourhood observed, the best is exploited.
    Ties in ``best()`` break toward the reader count observed first
    (dict insertion order), which is itself deterministic per history.
    """

    num_pes: int
    num_nodes: int = 1
    observations: Dict[int, List[float]] = field(default_factory=dict)

    def record(self, num_readers: int, throughput: float) -> None:
        self.observations.setdefault(num_readers, []).append(throughput)

    def record_session(self, metrics: SessionMetrics) -> None:
        """Shared observation hook: fold one finished session's metrics in.

        Sessions that never read a byte (e.g. cancelled before any splinter
        landed) carry no throughput signal and are skipped."""
        bps = metrics.throughput_bytes_per_s()
        if metrics.num_readers > 0 and bps > 0:
            self.record(metrics.num_readers, bps)

    def _score(self, r: int) -> float:
        obs = self.observations.get(r, [])
        return sum(obs) / len(obs) if obs else float("-inf")

    def best(self) -> Optional[int]:
        if not self.observations:
            return None
        return max(self.observations, key=self._score)

    def suggest(self, file_bytes: int) -> int:
        seed = suggest_num_readers(file_bytes, self.num_pes, self.num_nodes)
        if not self.observations:
            return seed
        best = self.best()
        assert best is not None
        # Fixed exploration order: best, half, double — first untried wins.
        for cand in (best, max(1, best // 2), best * 2):
            if cand not in self.observations and cand <= 4 * self.num_pes:
                return cand
        return best


@dataclass
class SplinterSizer:
    """Observation-driven splinter sizing (streaming controller).

    Targets ``target_splinter_s`` seconds of I/O per splinter at the
    observed per-reader-thread bandwidth, then shrinks under steal pressure:
    a session where many splinters were stolen is straggler-bound, and
    smaller splinters bound its completion tighter (steal granularity).
    Both signals are EMA-smoothed so one outlier session cannot whipsaw the
    size; the result is clamped to ``[min_bytes, max_bytes]`` and rounded
    down to a 256 KiB multiple (FS-block friendly, stable across jitter).
    The smoothing + quantization also bound a side effect on the streamed
    device path: every size change alters the per-splinter chunk shapes
    and retraces the fused consume executable once, so suggestions must
    converge rather than wander (see data/pipeline.py).
    """

    min_bytes: int = 256 * 1024
    max_bytes: int = 64 * 1024 * 1024
    target_splinter_s: float = 0.05
    alpha: float = 0.5                 # EMA weight of the newest session
    sessions_observed: int = 0
    ema_reader_bps: float = 0.0
    ema_steal_frac: float = 0.0

    def record_session(self, metrics: SessionMetrics) -> None:
        """Same shared hook as ``AutoTuner.record_session``."""
        if metrics.read_calls <= 0 or metrics.read_time_s <= 0:
            return
        # read_time_s is summed across reader threads, so this is per-thread
        # (per-stripe) bandwidth — exactly the rate one splinter is read at.
        bps = metrics.bytes_read / metrics.read_time_s
        steal_frac = metrics.steals / metrics.read_calls
        a = self.alpha if self.sessions_observed else 1.0
        self.ema_reader_bps += a * (bps - self.ema_reader_bps)
        self.ema_steal_frac += a * (steal_frac - self.ema_steal_frac)
        self.sessions_observed += 1

    def suggest(self, default: int) -> int:
        """Splinter size for the next session; ``default`` until observed."""
        if not self.sessions_observed or self.ema_reader_bps <= 0:
            return default
        size = self.ema_reader_bps * self.target_splinter_s
        # Steal pressure shrinks the unit: at >=50% stolen splinters the
        # size bottoms out at a quarter of the throughput-derived target.
        shrink = 1.0 - 1.5 * min(self.ema_steal_frac, 0.5)
        size = int(size * shrink)
        size = max(self.min_bytes, min(self.max_bytes, size))
        return max(self.min_bytes, (size // (256 * 1024)) * (256 * 1024))
