"""Online tuning of the reader layer (paper future-work §VI-A, implemented).

Three pieces:

* ``suggest_num_readers`` — a closed-form heuristic from file size and
  machine shape. The paper's Figs. 1/4 show a U-curve: too few readers miss
  disk parallelism, too many congest the FS with small requests. The
  heuristic targets a fixed bytes-per-reader chunk (large enough for
  streaming bandwidth) bounded by [1 per node, 2 per PE].
* ``AutoTuner`` — online refinement: records (num_readers → throughput)
  observations across sessions and explores the power-of-two neighbourhood
  of the current best (the search-based approach of Behzad et al. [4] that
  the paper cites, restricted to a single knob).
* ``SplinterSizer`` — dynamic splinter sizing for the streaming delivery
  path: sizes the unit of physical I/O from observed per-reader throughput
  (large splinters on fast streaming stripes — fewer syscalls, better
  sequential bandwidth) shrunk under steal pressure (small splinters near
  straggler-stolen tails — finer-grained stealing, tighter completion
  bound).

* ``QueueTuner`` — the cold-path controller: a deterministic hill-climb
  over the 2-D (queue depth, readahead window) space of the async
  submission layer (``io/submit.py``). Depth trades request concurrency
  against FS congestion (TASIO's central knob); the readahead window
  trades kernel prefetch reach against cache churn. Both knobs move
  multiplicatively (the response curves are log-shaped: doubling depth
  matters at 2, not at 62), observations are keyed by the exact
  (depth, readahead) pair, and exploration follows a fixed neighbour
  order — same-history determinism like ``AutoTuner``.

``AutoTuner``, ``SplinterSizer`` and ``QueueTuner`` share one observation
path: ``record_session(metrics)`` takes the ``SessionMetrics`` every
session already collects — the Director feeds all three on session close,
so any controller added later observes for free.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.metrics import SessionMetrics
from repro.io.posix import DEFAULT_ALIGN, aligned_floor


def suggest_num_readers(
    file_bytes: int,
    num_pes: int,
    num_nodes: int = 1,
    target_chunk_bytes: int = 64 * 1024 * 1024,
) -> int:
    if file_bytes <= 0:
        return 1
    by_chunk = max(1, (file_bytes + target_chunk_bytes - 1) // target_chunk_bytes)
    lo = max(1, num_nodes)            # at least one independent path per node
    hi = max(lo, 2 * num_pes)         # paper Fig. 4: beyond ~2/PE only adds contention
    return int(min(max(by_chunk, lo), hi))


@dataclass
class AutoTuner:
    """Online power-of-two hillclimb over the reader count.

    Exploration is **deterministic**: given the same observation history,
    ``suggest`` returns the same value. The candidate order is fixed —
    current best, then its half, then its double — and the first candidate
    without observations (and within the ``4 * num_pes`` contention cap) is
    explored; with the whole neighbourhood observed, the best is exploited.
    Ties in ``best()`` break toward the reader count observed first
    (dict insertion order), which is itself deterministic per history.
    """

    num_pes: int
    num_nodes: int = 1
    observations: Dict[int, List[float]] = field(default_factory=dict)

    def record(self, num_readers: int, throughput: float) -> None:
        self.observations.setdefault(num_readers, []).append(throughput)

    def record_session(self, metrics: SessionMetrics) -> None:
        """Shared observation hook: fold one finished session's metrics in.

        Sessions that never read a byte (e.g. cancelled before any splinter
        landed) carry no throughput signal and are skipped."""
        bps = metrics.throughput_bytes_per_s()
        if metrics.num_readers > 0 and bps > 0:
            self.record(metrics.num_readers, bps)

    def _score(self, r: int) -> float:
        obs = self.observations.get(r, [])
        return sum(obs) / len(obs) if obs else float("-inf")

    def best(self) -> Optional[int]:
        if not self.observations:
            return None
        return max(self.observations, key=self._score)

    def suggest(self, file_bytes: int) -> int:
        seed = suggest_num_readers(file_bytes, self.num_pes, self.num_nodes)
        if not self.observations:
            return seed
        best = self.best()
        assert best is not None
        # Fixed exploration order: best, half, double — first untried wins.
        for cand in (best, max(1, best // 2), best * 2):
            if cand not in self.observations and cand <= 4 * self.num_pes:
                return cand
        return best


@dataclass
class QueueTuner:
    """Deterministic 2-D hillclimb over (queue depth, readahead window).

    Observations are mean throughput per exact ``(depth, readahead)`` pair,
    folded in through the shared ``record_session`` hook (sessions that ran
    the blocking loop — ``queue_depth == 0`` — or read nothing carry no
    signal and are skipped). ``suggest`` explores the fixed-order
    multiplicative neighbourhood of the current best — depth doubled,
    halved, then readahead doubled, halved, then the diagonal — first
    unobserved candidate wins; a fully-observed neighbourhood exploits the
    best. Readahead is quantized to ``readahead_quantum`` so float jitter
    cannot mint spurious grid points; depth clamps to
    ``[min_depth, max_depth]``.
    """

    min_depth: int = 1
    max_depth: int = 64
    max_readahead: int = 64 * 1024 * 1024
    readahead_quantum: int = 1024 * 1024
    observations: Dict[Tuple[int, int], List[float]] = field(
        default_factory=dict)

    def _quant(self, readahead: int) -> int:
        q = self.readahead_quantum
        r = (max(0, int(readahead)) // q) * q
        return min(r, self.max_readahead)

    def _clamp(self, depth: int, readahead: int) -> Tuple[int, int]:
        return (min(max(int(depth), self.min_depth), self.max_depth),
                self._quant(readahead))

    def record(self, depth: int, readahead: int, throughput: float) -> None:
        key = self._clamp(depth, readahead)
        self.observations.setdefault(key, []).append(throughput)

    def record_session(self, metrics: SessionMetrics) -> None:
        """Shared observation hook (Director feeds this on session close)."""
        bps = metrics.throughput_bytes_per_s()
        if metrics.queue_depth > 0 and bps > 0:
            self.record(metrics.queue_depth, metrics.readahead_bytes, bps)

    def _score(self, key: Tuple[int, int]) -> float:
        obs = self.observations.get(key, [])
        return sum(obs) / len(obs) if obs else float("-inf")

    def best(self) -> Optional[Tuple[int, int]]:
        if not self.observations:
            return None
        return max(self.observations, key=self._score)

    def best_throughput(self) -> float:
        b = self.best()
        return self._score(b) if b is not None else 0.0

    def _neighbourhood(self, d: int, r: int) -> List[Tuple[int, int]]:
        q = self.readahead_quantum
        raw = [
            (d, r),
            (d * 2, r),
            (max(self.min_depth, d // 2), r),
            (d, r * 2 if r else q),
            (d, r // 2 if r >= 2 * q else 0),
            (d * 2, r * 2 if r else q),
        ]
        out: List[Tuple[int, int]] = []
        for cand in raw:
            c = self._clamp(*cand)
            if c not in out:
                out.append(c)
        return out

    def suggest(self, default_depth: int,
                default_readahead: int = 0) -> Tuple[int, int]:
        """(queue_depth, readahead_bytes) for the next session."""
        if not self.observations:
            return self._clamp(default_depth, default_readahead)
        best = self.best()
        assert best is not None
        for cand in self._neighbourhood(*best):
            if cand not in self.observations:
                return cand
        return best


@dataclass
class _ReaderEMA:
    """Per-reader (stripe-index) smoothed observations."""

    bps: float = 0.0
    steal_frac: float = 0.0
    sessions: int = 0


@dataclass
class SplinterSizer:
    """Observation-driven splinter sizing (streaming controller).

    Targets ``target_splinter_s`` seconds of I/O per splinter at the
    observed per-reader-thread bandwidth, then shrinks under steal pressure:
    a session where many splinters were stolen is straggler-bound, and
    smaller splinters bound its completion tighter (steal granularity).
    Both signals are EMA-smoothed so one outlier session cannot whipsaw the
    size; the result is clamped to ``[min_bytes, max_bytes]``, rounded
    down to a 256 KiB multiple (stable across jitter), and finally floored
    to the FS block alignment (``io.posix.aligned_floor``) — shrink under
    steal pressure can never produce a sub-block size that would put preadv
    offsets off the block grid and break the zero-copy alignment contract.
    The smoothing + quantization also bound a side effect on the streamed
    device path: every size change alters the per-splinter chunk shapes
    and retraces the fused consume executable once, so suggestions must
    converge rather than wander (see data/pipeline.py).

    Sizing is tracked at two granularities sharing one observation hook:

    * **session-level** (``suggest``) — the EMA over all readers, the PR-3
      behaviour;
    * **per-reader** (``suggest_per_reader``) — one EMA per stripe index,
      keyed by the per-reader breakdowns ``SessionMetrics`` records (bytes,
      wall time, splinters stolen *from* that reader). A straggling stripe
      alone gets fine splinters (tight steal granularity where it matters)
      while healthy stripes keep large streaming reads; readers without
      enough signal fall back to the session-level size.
    """

    min_bytes: int = 256 * 1024
    max_bytes: int = 64 * 1024 * 1024
    target_splinter_s: float = 0.05
    alpha: float = 0.5                 # EMA weight of the newest session
    align: int = DEFAULT_ALIGN         # FS block floor for every suggestion
    sessions_observed: int = 0
    ema_reader_bps: float = 0.0
    ema_steal_frac: float = 0.0
    per_reader: Dict[int, _ReaderEMA] = field(default_factory=dict)

    def record_session(self, metrics: SessionMetrics) -> None:
        """Same shared hook as ``AutoTuner.record_session``."""
        if metrics.read_calls <= 0 or metrics.read_time_s <= 0:
            return
        # read_time_s is summed across reader threads, so this is per-thread
        # (per-stripe) bandwidth — exactly the rate one splinter is read at.
        bps = metrics.bytes_read / metrics.read_time_s
        steal_frac = metrics.steals / metrics.read_calls
        a = self.alpha if self.sessions_observed else 1.0
        self.ema_reader_bps += a * (bps - self.ema_reader_bps)
        self.ema_steal_frac += a * (steal_frac - self.ema_steal_frac)
        self.sessions_observed += 1
        # Per-reader fold: bytes/time/steals attributed to the planned
        # stripe owner (stolen splinters count against their owner — the
        # straggler — not the thief).
        for r, nbytes in metrics.bytes_per_reader.items():
            dt = metrics.read_time_per_reader.get(r, 0.0)
            calls = metrics.reads_per_reader.get(r, 0)
            if dt <= 0 or calls <= 0:
                continue
            st = self.per_reader.setdefault(r, _ReaderEMA())
            ar = self.alpha if st.sessions else 1.0
            st.bps += ar * (nbytes / dt - st.bps)
            st.steal_frac += ar * (
                metrics.steals_from_reader.get(r, 0) / calls - st.steal_frac)
            st.sessions += 1

    def _size_from(self, bps: float, steal_frac: float) -> int:
        size = bps * self.target_splinter_s
        # Steal pressure shrinks the unit: at >=50% stolen splinters the
        # size bottoms out at a quarter of the throughput-derived target.
        shrink = 1.0 - 1.5 * min(steal_frac, 0.5)
        size = int(size * shrink)
        size = max(self.min_bytes, min(self.max_bytes, size))
        size = max(self.min_bytes, (size // (256 * 1024)) * (256 * 1024))
        # Alignment floor LAST: whatever min_bytes the caller configured,
        # the emitted size is a whole number of FS blocks.
        return aligned_floor(size, self.align)

    def suggest(self, default: int) -> int:
        """Splinter size for the next session; ``default`` until observed."""
        if not self.sessions_observed or self.ema_reader_bps <= 0:
            return default
        return self._size_from(self.ema_reader_bps, self.ema_steal_frac)

    def suggest_per_reader(
        self, num_readers: int, default: int
    ) -> Optional[List[int]]:
        """Per-stripe splinter sizes for the next ``num_readers``-reader
        session, or ``None`` before any per-reader signal exists (the plan
        then uses the scalar ``suggest`` size everywhere)."""
        if not self.sessions_observed or not self.per_reader:
            return None
        base = self.suggest(default)
        out: List[int] = []
        for r in range(num_readers):
            st = self.per_reader.get(r)
            if st is None or st.bps <= 0:
                out.append(base)
            else:
                out.append(self._size_from(st.bps, st.steal_frac))
        return out
