"""Reader placement policies (paper §III-C.4 + future-work §VI-B).

Maps each buffer reader of a session to a PE. Policies:

* ``round_robin`` — readers cycle over PEs in index order.
* ``node_spread`` — spread readers across *nodes* first, then PEs within a
  node; maximizes independent I/O paths when each node has its own storage
  connection (the common Lustre-router topology the paper runs on).
* ``near_consumers`` — co-locate readers with a provided consumer PE list,
  minimizing phase-2 cross-node traffic (the locality play of paper Fig. 10–12,
  from the reader side instead of migrating the client).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.scheduler import TaskScheduler


def place_readers(
    policy: str,
    num_readers: int,
    sched: TaskScheduler,
    consumer_pes: Optional[Sequence[int]] = None,
) -> List[int]:
    if num_readers < 1:
        raise ValueError("num_readers must be >= 1")
    if policy == "round_robin":
        return [r % sched.num_pes for r in range(num_readers)]
    if policy == "node_spread":
        nodes = sched.num_nodes
        ppn = sched.pes_per_node
        out = []
        for r in range(num_readers):
            node = r % nodes
            slot = (r // nodes) % ppn
            pe = min(node * ppn + slot, sched.num_pes - 1)
            out.append(pe)
        return out
    if policy == "near_consumers":
        if not consumer_pes:
            return place_readers("node_spread", num_readers, sched)
        return [consumer_pes[r % len(consumer_pes)] for r in range(num_readers)]
    raise ValueError(f"unknown placement policy: {policy!r}")
