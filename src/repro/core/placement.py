"""Reader placement policies + NUMA topology model (paper §III-C.4, §VI-B).

Maps each buffer reader of a session to a PE. Policies:

* ``round_robin`` — readers cycle over PEs in index order.
* ``node_spread`` — spread readers across *nodes* first, then PEs within a
  node; maximizes independent I/O paths when each node has its own storage
  connection (the common Lustre-router topology the paper runs on).
  Readers beyond ``num_pes`` wrap around the spread order — every PE is
  used exactly once before any PE is reused (no duplicate placement on
  uneven topologies).
* ``domain_spread`` — like ``node_spread`` but over NUMA *domains*: one
  reader per memory domain before doubling up, so each domain's memory
  controller serves one arena stripe (requires a ``Topology``; defaults to
  one domain per node).
* ``near_consumers`` — co-locate readers with a provided consumer PE list,
  minimizing phase-2 cross-node traffic (the locality play of paper
  Fig. 10–12, from the reader side instead of migrating the client). With a
  ``Topology``, readers spread over all PEs of the *consumers' NUMA
  domains* instead of stacking on the exact consumer PEs — same-domain
  delivery stays zero-copy-local while the readers keep independent PEs.

``Topology`` is the memory-locality model the scheduler lacks: the
scheduler knows nodes (address spaces); ``Topology`` subdivides each node
into NUMA domains and optionally carries the host CPU set backing each
domain (from ``io/numa.py`` detection) so reader I/O threads can be pinned
where their arena stripe's memory lives.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.core.scheduler import TaskScheduler


@dataclass(frozen=True)
class Topology:
    """PE → NUMA-domain map layered on the scheduler's node grid.

    ``domains_per_node`` subdivides each node's PEs into equal contiguous
    domains (the way cores split across sockets/CCDs). ``domain_cpus``
    optionally maps each *global* domain id to the host CPUs backing it —
    required only for ``numa_pin`` (reader-thread affinity); the logical
    model works without it.
    """

    num_pes: int
    pes_per_node: int = 1
    domains_per_node: int = 1
    domain_cpus: Optional[Tuple[Tuple[int, ...], ...]] = None

    def __post_init__(self) -> None:
        if self.num_pes < 1:
            raise ValueError("num_pes must be >= 1")
        if self.pes_per_node < 1:
            raise ValueError("pes_per_node must be >= 1")
        if not 1 <= self.domains_per_node <= self.pes_per_node:
            raise ValueError(
                f"domains_per_node must be in [1, {self.pes_per_node}] "
                f"(pes_per_node), got {self.domains_per_node}")
        if (self.domain_cpus is not None
                and len(self.domain_cpus) != self.num_domains):
            # A short map would silently pin high domains' reader threads
            # to the wrong domain's CPUs (defeating first-touch placement
            # while reporting pin success).
            raise ValueError(
                f"domain_cpus has {len(self.domain_cpus)} entries for "
                f"{self.num_domains} domains")

    # -- shape ---------------------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return (self.num_pes + self.pes_per_node - 1) // self.pes_per_node

    @property
    def num_domains(self) -> int:
        return self.num_nodes * self.domains_per_node

    @property
    def pes_per_domain(self) -> int:
        return (self.pes_per_node + self.domains_per_node - 1) \
            // self.domains_per_node

    def node_of(self, pe: int) -> int:
        return pe // self.pes_per_node

    def domain_of(self, pe: int) -> int:
        """Global NUMA-domain id of ``pe``."""
        if not 0 <= pe < self.num_pes:
            raise ValueError(f"PE {pe} out of range [0,{self.num_pes})")
        within = pe % self.pes_per_node
        local = min(within // self.pes_per_domain, self.domains_per_node - 1)
        return self.node_of(pe) * self.domains_per_node + local

    def pes_in_domain(self, domain: int) -> List[int]:
        return [pe for pe in range(self.num_pes)
                if self.domain_of(pe) == domain]

    def cpus_of_domain(self, domain: int) -> Optional[Tuple[int, ...]]:
        """Host CPUs backing ``domain`` (None when no CPU map was given)."""
        if self.domain_cpus is None:
            return None
        if not 0 <= domain < self.num_domains:
            raise ValueError(
                f"domain {domain} out of range [0,{self.num_domains})")
        return self.domain_cpus[domain]

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_sched(
        cls, sched: TaskScheduler, domains_per_node: int = 1,
        domain_cpus: Optional[Sequence[Sequence[int]]] = None,
    ) -> "Topology":
        return cls(
            num_pes=sched.num_pes,
            pes_per_node=sched.pes_per_node,
            domains_per_node=min(max(1, domains_per_node),
                                 sched.pes_per_node),
            domain_cpus=(tuple(tuple(c) for c in domain_cpus)
                         if domain_cpus else None),
        )

    @classmethod
    def with_host_cpus(
        cls, num_pes: int, pes_per_node: int = 1, domains_per_node: int = 1
    ) -> "Topology":
        """Topology of the given logical shape with the host's NUMA CPU
        sets (sysfs) cycled over the global domains — the CPU map
        ``numa_pin`` needs, whatever the logical domain count."""
        from repro.io.numa import detect_numa_domains

        host = detect_numa_domains()
        shape = cls(num_pes=num_pes, pes_per_node=pes_per_node,
                    domains_per_node=domains_per_node)
        cpus = tuple(host[d % len(host)] for d in range(shape.num_domains))
        return cls(num_pes=num_pes, pes_per_node=pes_per_node,
                   domains_per_node=domains_per_node, domain_cpus=cpus)

    @classmethod
    def detect(cls, num_pes: int, pes_per_node: int = 1) -> "Topology":
        """Topology with domains taken from the host's NUMA nodes (sysfs).

        The detected domains are spread over the logical nodes (clamped to
        ``pes_per_node`` — a 1-PE-per-node grid cannot subdivide further)
        and each global domain carries its host CPU set for ``numa_pin``.
        """
        from repro.io.numa import detect_numa_domains

        host = detect_numa_domains()
        num_nodes = (num_pes + pes_per_node - 1) // pes_per_node
        per_node = min(max(1, len(host) // max(1, num_nodes)), pes_per_node)
        return cls.with_host_cpus(num_pes, pes_per_node, per_node)

    @classmethod
    def from_spec(
        cls, spec: str, num_pes: int, pes_per_node: int = 1
    ) -> "Topology":
        """Parse a CLI topology spec: ``"auto"`` (detect from the host) or
        an integer number of domains per node (clamped to ``pes_per_node``).
        """
        if spec == "auto":
            return cls.detect(num_pes, pes_per_node)
        try:
            per_node = int(spec)
        except ValueError:
            raise ValueError(
                f"bad --topology spec {spec!r}: expected 'auto' or an "
                f"integer domains-per-node") from None
        return cls(num_pes=num_pes, pes_per_node=pes_per_node,
                   domains_per_node=min(max(1, per_node), pes_per_node))


def _bucket_pes(num_pes: int, key, num_groups: int) -> List[List[int]]:
    """Group PEs by ``key(pe)`` in one O(num_pes) pass (session starts run
    this per step — no per-group rescans)."""
    groups: List[List[int]] = [[] for _ in range(num_groups)]
    for pe in range(num_pes):
        groups[key(pe)].append(pe)
    return groups


def _interleave(groups: Sequence[Sequence[int]]) -> List[int]:
    """Merge PE groups round-robin: one PE from each group per pass.

    The result is a permutation of every PE in ``groups`` — the spread
    order policies index with ``r % len(perm)``, which is what guarantees
    no PE repeats before all PEs have been used (the old ``node_spread``
    clamped overflow onto the last PE instead, silently stacking readers).
    """
    out: List[int] = []
    idx = [0] * len(groups)
    total = sum(len(g) for g in groups)
    while len(out) < total:
        for g, group in enumerate(groups):
            if idx[g] < len(group):
                out.append(group[idx[g]])
                idx[g] += 1
    return out


def place_readers(
    policy: str,
    num_readers: int,
    sched: TaskScheduler,
    consumer_pes: Optional[Sequence[int]] = None,
    topology: Optional[Topology] = None,
) -> List[int]:
    if num_readers < 1:
        raise ValueError("num_readers must be >= 1")
    if topology is not None and topology.num_pes != sched.num_pes:
        # A topology over a different PE grid would emit reader PEs that
        # index nonexistent scheduler queues (or mis-map domains). The
        # domain subdivision may differ from the scheduler's node grid;
        # the PE count may not. Every session start passes through here,
        # so a mismatched FileOptions.topology fails fast.
        raise ValueError(
            f"topology covers {topology.num_pes} PEs but the scheduler "
            f"has {sched.num_pes}")
    if policy == "round_robin":
        return [r % sched.num_pes for r in range(num_readers)]
    if policy == "node_spread":
        groups = _bucket_pes(sched.num_pes, sched.node_of, sched.num_nodes)
        perm = _interleave(groups)
        return [perm[r % len(perm)] for r in range(num_readers)]
    if policy == "domain_spread":
        topo = topology or Topology.from_sched(sched)
        groups = _bucket_pes(topo.num_pes, topo.domain_of, topo.num_domains)
        perm = _interleave([g for g in groups if g])
        return [perm[r % len(perm)] for r in range(num_readers)]
    if policy == "near_consumers":
        if not consumer_pes:
            return place_readers(
                "node_spread", num_readers, sched, topology=topology)
        bad = [p for p in consumer_pes if not 0 <= p < sched.num_pes]
        if bad:
            raise ValueError(
                f"near_consumers: consumer PE(s) {bad} out of range "
                f"[0,{sched.num_pes}) — a reader placed there would index "
                f"a nonexistent scheduler queue")
        if topology is None:
            return [consumer_pes[r % len(consumer_pes)]
                    for r in range(num_readers)]
        # Topology-aware: readers spread over every PE of the consumers'
        # NUMA domains (deliveries stay same-domain without stacking all
        # readers on the handful of consumer PEs).
        doms: List[int] = []
        for p in consumer_pes:
            d = topology.domain_of(p)
            if d not in doms:
                doms.append(d)
        by_domain = _bucket_pes(
            topology.num_pes, topology.domain_of, topology.num_domains)
        perm = _interleave([by_domain[d] for d in doms])
        return [perm[r % len(perm)] for r in range(num_readers)]
    raise ValueError(f"unknown placement policy: {policy!r}")
