"""Instrumentation for CkIO: per-session counters and timings.

Everything the paper's evaluation plots (throughput, overlap fraction,
permutation cost, cross-node traffic) is derived from these counters.
Thread-safe; negligible overhead (integer adds under a lock).

Per-piece *timing* (two ``perf_counter`` calls per delivered piece) is the
one non-negligible probe, so it sits behind ``piece_timing_every``: 0 (the
default) disables it entirely, N samples every Nth piece — delivery
instrumentation stays off the hot path unless a benchmark opts in.
``bytes_copied`` counts bytes physically memcpy'd into a client destination
buffer; the borrowed-view path leaves it untouched, which is how benchmarks
and tests *prove* zero-copy delivery rather than assume it.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class SessionMetrics:
    lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    session_bytes: int = 0
    num_readers: int = 0
    t_start: float = 0.0
    t_last_read: float = 0.0
    read_calls: int = 0
    bytes_read: int = 0
    read_time_s: float = 0.0          # summed per-call wall time (across threads)
    bytes_per_reader: Dict[int, int] = field(default_factory=dict)
    steals: int = 0
    # phase-2 (permutation/delivery) accounting
    pieces_served: int = 0
    bytes_served: int = 0
    bytes_copied: int = 0             # memcpy'd to client buffers (0 = zero-copy)
    cross_node_bytes: int = 0
    permute_time_s: float = 0.0
    timed_pieces: int = 0             # pieces that contributed to permute_time_s
    piece_timing_every: int = 0       # 0 = timing off; N = time every Nth piece
    requests: int = 0
    request_latencies_s: List[float] = field(default_factory=list)
    _piece_seq: int = 0               # sampling counter (racy by design)

    def session_started(self, nbytes: int, num_readers: int) -> None:
        with self.lock:
            self.session_bytes = nbytes
            self.num_readers = num_readers
            self.t_start = time.perf_counter()

    def record_read(self, reader: int, nbytes: int, dt: float) -> None:
        with self.lock:
            self.read_calls += 1
            self.bytes_read += nbytes
            self.read_time_s += dt
            self.t_last_read = time.perf_counter()
            self.bytes_per_reader[reader] = (
                self.bytes_per_reader.get(reader, 0) + nbytes
            )

    def should_time_piece(self) -> bool:
        """Cheap sampling decision — no lock; an off-by-one under contention
        only shifts which piece gets sampled."""
        if self.piece_timing_every <= 0:
            return False
        self._piece_seq += 1
        return self._piece_seq % self.piece_timing_every == 0

    def record_piece(
        self,
        nbytes: int,
        cross_node: bool,
        dt: Optional[float] = None,
        copied: int = 0,
    ) -> None:
        with self.lock:
            self.pieces_served += 1
            self.bytes_served += nbytes
            self.bytes_copied += copied
            if cross_node:
                self.cross_node_bytes += nbytes
            if dt is not None:
                self.permute_time_s += dt
                self.timed_pieces += 1

    def record_request(self, latency_s: float) -> None:
        with self.lock:
            self.requests += 1
            self.request_latencies_s.append(latency_s)

    # -- derived -------------------------------------------------------------
    def ingest_seconds(self) -> float:
        """Wall time from session start to last byte read."""
        if self.t_last_read == 0.0:
            return 0.0
        return self.t_last_read - self.t_start

    def throughput_bytes_per_s(self) -> float:
        t = self.ingest_seconds()
        return self.bytes_read / t if t > 0 else 0.0

    def imbalance(self) -> float:
        """max/mean bytes per reader — straggler indicator."""
        if not self.bytes_per_reader:
            return 0.0
        vals = list(self.bytes_per_reader.values())
        mean = sum(vals) / len(vals)
        return max(vals) / mean if mean else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "session_bytes": float(self.session_bytes),
            "num_readers": float(self.num_readers),
            "read_calls": float(self.read_calls),
            "bytes_read": float(self.bytes_read),
            "ingest_s": self.ingest_seconds(),
            "throughput_MBps": self.throughput_bytes_per_s() / 1e6,
            "steals": float(self.steals),
            "pieces_served": float(self.pieces_served),
            "bytes_served": float(self.bytes_served),
            "bytes_copied": float(self.bytes_copied),
            "cross_node_bytes": float(self.cross_node_bytes),
            "permute_time_s": self.permute_time_s,
            "timed_pieces": float(self.timed_pieces),
            "requests": float(self.requests),
            "imbalance": self.imbalance(),
        }


@dataclass
class IngestMetrics:
    """Per-pipeline step-ingest accounting (host vs device reassembly).

    ``host_permute_bytes`` counts bytes the *host* handles past the session
    arena to build a training batch — the paper's phase-2 permutation cost.
    The host path pays the window once per step; the device path
    (``get_batch_device``) must keep it at **0**: its only per-step host
    work is one ``device_put`` of the borrowed arena view, accounted
    separately as ``h2d_transfers`` / ``h2d_bytes``. Benchmarks assert on
    these counters rather than assuming the permutation moved.
    """

    lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    steps: int = 0
    host_steps: int = 0
    device_steps: int = 0
    host_permute_bytes: int = 0
    h2d_transfers: int = 0
    h2d_bytes: int = 0

    def record_host_step(self, permute_bytes: int) -> None:
        with self.lock:
            self.steps += 1
            self.host_steps += 1
            self.host_permute_bytes += permute_bytes

    def record_device_step(
        self, staged_bytes: int, transfers: int = 1, host_bytes: int = 0
    ) -> None:
        """``host_bytes`` covers host-side copies the staging still pays
        (e.g. the copy-mode session→step-arena copy); the zero-copy device
        path passes 0."""
        with self.lock:
            self.steps += 1
            self.device_steps += 1
            self.h2d_transfers += transfers
            self.h2d_bytes += staged_bytes
            self.host_permute_bytes += host_bytes

    def summary(self) -> Dict[str, float]:
        with self.lock:
            return {
                "steps": float(self.steps),
                "host_steps": float(self.host_steps),
                "device_steps": float(self.device_steps),
                "host_permute_bytes": float(self.host_permute_bytes),
                "h2d_transfers": float(self.h2d_transfers),
                "h2d_bytes": float(self.h2d_bytes),
            }
