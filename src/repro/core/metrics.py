"""Instrumentation for CkIO: per-session counters and timings.

Everything the paper's evaluation plots (throughput, overlap fraction,
permutation cost, cross-node traffic) is derived from these counters.
Thread-safe; negligible overhead (integer adds under a lock).

Per-piece *timing* (two ``perf_counter`` calls per delivered piece) is the
one non-negligible probe, so it sits behind ``piece_timing_every``: 0 (the
default) disables it entirely, N samples every Nth piece — delivery
instrumentation stays off the hot path unless a benchmark opts in.
``bytes_copied`` counts bytes physically memcpy'd into a client destination
buffer; the borrowed-view path leaves it untouched, which is how benchmarks
and tests *prove* zero-copy delivery rather than assume it.
"""
from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class RecoveryMetrics:
    """Fault-recovery accounting for the reader runtime.

    One instance per reader set (``SessionMetrics.recovery``), merged into
    a Director-lifetime aggregate on session close — the observables of the
    recovery layer, proving what it absorbed instead of letting faults pass
    silently:

    * ``respawns`` / ``reissues`` — recovery events by kind: a dead or
      watchdog-killed worker replaced by a fresh process attached to the
      *same* arena, vs its unfinished splinters re-read supervisor-side.
      ``reissued_splinters`` / ``reissued_bytes`` total the re-routed work
      for both kinds (a respawn also re-issues the unfinished tail, just
      to a new process).
    * ``io_retries`` / ``retried_errnos`` — transient pread errors absorbed
      by the posix backoff layer *in this process*; ``worker_io_retries`` /
      ``worker_suppressed`` — the same counters folded in from reader
      worker processes through their ring headers.
    * ``suppressed_errors`` — advisory (fadvise-class) errors swallowed by
      design but counted, never silent.
    * ``watchdog_kills`` — hung workers killed by the supervisor's
      no-progress watchdog (each then flows through respawn/reissue).
    * ``recovery_latency_s`` — summed seconds from failure detection to
      restored read capacity (replacement gate-open, or the re-issued tail
      fully landed).
    * ``degraded_mode`` — this session ran on the thread backend because
      ``backend="process"`` setup failed and ``fallback_backend`` allowed
      the downgrade.

    Duck-typing: ``record_io_retry``/``record_suppressed`` match the stats
    protocol of ``io/posix.py``, so a session's RecoveryMetrics can be
    passed directly as a pread ``stats`` sink.
    """

    lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    respawns: int = 0
    reissues: int = 0
    reissued_splinters: int = 0
    reissued_bytes: int = 0
    io_retries: int = 0
    retried_errnos: Dict[int, int] = field(default_factory=dict)
    suppressed_errors: int = 0
    worker_io_retries: int = 0
    worker_suppressed: int = 0
    watchdog_kills: int = 0
    recovery_latency_s: float = 0.0
    degraded_mode: bool = False
    # Direct-I/O tail accounting: sub-block fragments a direct-mode read had
    # to finish through the buffered descriptor (the only legal buffered
    # bytes in an O_DIRECT session — counted, never silent).
    direct_tail_reads: int = 0
    direct_tail_bytes: int = 0
    # FileSet sessions: re-issued bytes attributed to the shard whose file
    # they live in (splinters never span shards, so attribution is exact) —
    # proving a recovery re-read the RIGHT shard, not just the right amount.
    reissued_bytes_by_shard: Dict[int, int] = field(default_factory=dict)

    def record_io_retry(self, err: Optional[int] = None) -> None:
        with self.lock:
            self.io_retries += 1
            if err is not None:
                self.retried_errnos[err] = self.retried_errnos.get(err, 0) + 1

    def record_direct_tail(self, nbytes: int = 0) -> None:
        """One sub-block fragment of a direct read served buffered."""
        with self.lock:
            self.direct_tail_reads += 1
            self.direct_tail_bytes += int(nbytes)

    def record_suppressed(self, err: Optional[int] = None) -> None:
        with self.lock:
            self.suppressed_errors += 1

    def record_respawn(self, nsplinters: int, nbytes: int,
                       by_shard: Optional[Dict[int, int]] = None) -> None:
        with self.lock:
            self.respawns += 1
            self.reissued_splinters += nsplinters
            self.reissued_bytes += nbytes
            self._fold_shards(by_shard)

    def record_reissue(self, nsplinters: int, nbytes: int,
                       by_shard: Optional[Dict[int, int]] = None) -> None:
        with self.lock:
            self.reissues += 1
            self.reissued_splinters += nsplinters
            self.reissued_bytes += nbytes
            self._fold_shards(by_shard)

    def _fold_shards(self, by_shard: Optional[Dict[int, int]]) -> None:
        """Caller holds ``self.lock``."""
        if by_shard:
            for sh, nb in by_shard.items():
                self.reissued_bytes_by_shard[sh] = (
                    self.reissued_bytes_by_shard.get(sh, 0) + nb)

    def record_watchdog_kill(self) -> None:
        with self.lock:
            self.watchdog_kills += 1

    def record_recovery_latency(self, seconds: float) -> None:
        with self.lock:
            self.recovery_latency_s += max(seconds, 0.0)

    def add_worker_io(self, retries: int, suppressed: int) -> None:
        """Fold one worker ring's header counters in (once per ring)."""
        with self.lock:
            self.worker_io_retries += retries
            self.worker_suppressed += suppressed

    def mark_degraded(self) -> None:
        with self.lock:
            self.degraded_mode = True

    def recoveries(self) -> int:
        with self.lock:
            return self.respawns + self.reissues

    def merge(self, other: "RecoveryMetrics") -> None:
        """Fold ``other`` (a finished session's counters) into this one."""
        with other.lock:
            snap = (
                other.respawns, other.reissues, other.reissued_splinters,
                other.reissued_bytes, other.io_retries,
                dict(other.retried_errnos), other.suppressed_errors,
                other.worker_io_retries, other.worker_suppressed,
                other.watchdog_kills, other.recovery_latency_s,
                other.degraded_mode,
                dict(other.reissued_bytes_by_shard),
                other.direct_tail_reads, other.direct_tail_bytes,
            )
        with self.lock:
            self.respawns += snap[0]
            self.reissues += snap[1]
            self.reissued_splinters += snap[2]
            self.reissued_bytes += snap[3]
            self.io_retries += snap[4]
            for err, c in snap[5].items():
                self.retried_errnos[err] = self.retried_errnos.get(err, 0) + c
            self.suppressed_errors += snap[6]
            self.worker_io_retries += snap[7]
            self.worker_suppressed += snap[8]
            self.watchdog_kills += snap[9]
            self.recovery_latency_s += snap[10]
            self.degraded_mode = self.degraded_mode or snap[11]
            self._fold_shards(snap[12])
            self.direct_tail_reads += snap[13]
            self.direct_tail_bytes += snap[14]

    def summary(self) -> Dict[str, float]:
        with self.lock:
            return {
                "respawns": float(self.respawns),
                "reissues": float(self.reissues),
                "recoveries": float(self.respawns + self.reissues),
                "reissued_splinters": float(self.reissued_splinters),
                "reissued_bytes": float(self.reissued_bytes),
                "io_retries": float(self.io_retries),
                "worker_io_retries": float(self.worker_io_retries),
                "suppressed_errors": float(self.suppressed_errors),
                "worker_suppressed": float(self.worker_suppressed),
                "watchdog_kills": float(self.watchdog_kills),
                "recovery_latency_s": self.recovery_latency_s,
                "degraded_mode": float(self.degraded_mode),
                "shards_reissued": float(len(self.reissued_bytes_by_shard)),
                "direct_tail_reads": float(self.direct_tail_reads),
                "direct_tail_bytes": float(self.direct_tail_bytes),
            }


@dataclass
class SessionMetrics:
    lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    session_bytes: int = 0
    num_readers: int = 0
    t_start: float = 0.0
    t_last_read: float = 0.0
    read_calls: int = 0
    bytes_read: int = 0
    read_time_s: float = 0.0          # summed per-call wall time (across threads)
    bytes_per_reader: Dict[int, int] = field(default_factory=dict)
    # per-reader breakdowns (keyed by *planned owner*, i.e. stripe index):
    # the straggler signals the per-reader SplinterSizer consumes.
    read_time_per_reader: Dict[int, float] = field(default_factory=dict)
    reads_per_reader: Dict[int, int] = field(default_factory=dict)
    steals_from_reader: Dict[int, int] = field(default_factory=dict)
    steals: int = 0
    # phase-2 (permutation/delivery) accounting
    pieces_served: int = 0
    bytes_served: int = 0
    bytes_copied: int = 0             # memcpy'd to client buffers (0 = zero-copy)
    # Cross-node accounting is split by delivery kind so a piece is never
    # double-counted as both a transfer and a zero-copy delivery:
    # ``cross_node_bytes`` counts pieces physically copied to a client on
    # another node (the NetworkModel-modeled transfer); a piece delivered
    # as a borrowed view — same address space, or the mapped shm arena of
    # the process backend — moves no bytes and lands in
    # ``cross_node_view_bytes`` instead (the locality signal survives, the
    # phantom transfer does not).
    cross_node_bytes: int = 0
    cross_node_view_bytes: int = 0
    permute_time_s: float = 0.0
    timed_pieces: int = 0             # pieces that contributed to permute_time_s
    piece_timing_every: int = 0       # 0 = timing off; N = time every Nth piece
    requests: int = 0
    request_latencies_s: List[float] = field(default_factory=list)
    # Fault-recovery observables (respawns, re-issued splinters, I/O
    # retries, …); travels the same Director observer path as the rest of
    # the session counters. Has its own lock.
    recovery: RecoveryMetrics = field(default_factory=RecoveryMetrics)
    # FileSet sessions: physically-read bytes per shard id (splinters never
    # span shards, so every pread lands wholly in one shard file). Empty
    # for single-file sessions.
    shard_bytes: Dict[int, int] = field(default_factory=dict)
    # Submission-layer config + observables for this session — what the
    # QueueTuner consumes through the Director observer path. queue_depth 0
    # means the blocking (synchronous) loop; submit_backend is the backend
    # make_submitter actually chose ("io_uring"/"threads"/"" for blocking),
    # so an auto-mode fallback is observable, never silent.
    queue_depth: int = 0
    readahead_bytes: int = 0
    submit_backend: str = ""
    direct_io: bool = False
    inflight_hwm: int = 0
    # Pooled-service sessions (ipc/service.py): this session ran on checked-
    # out pool workers (pooled), under service generation service_epoch,
    # with worker-checkout latency service_checkout_s; arena_recycled marks
    # a recycled (already-prefaulted) arena-pool segment vs a fresh one.
    pooled: bool = False
    service_epoch: int = 0
    service_checkout_s: float = 0.0
    arena_recycled: bool = False
    _piece_seq: int = 0               # sampling counter (racy by design)

    def session_started(self, nbytes: int, num_readers: int) -> None:
        with self.lock:
            self.session_bytes = nbytes
            self.num_readers = num_readers
            self.t_start = time.perf_counter()

    def record_submit_config(self, queue_depth: int, readahead_bytes: int,
                             backend: str, direct_io: bool) -> None:
        """The submission shape this session ran with (reader-set start)."""
        with self.lock:
            self.queue_depth = int(queue_depth)
            self.readahead_bytes = int(readahead_bytes)
            self.submit_backend = backend
            self.direct_io = bool(direct_io)

    def record_inflight_hwm(self, hwm: int) -> None:
        """Fold one reader's in-flight high-water mark in (max across)."""
        with self.lock:
            if hwm > self.inflight_hwm:
                self.inflight_hwm = hwm

    def record_read(self, reader: int, nbytes: int, dt: float) -> None:
        with self.lock:
            self.read_calls += 1
            self.bytes_read += nbytes
            self.read_time_s += dt
            self.t_last_read = time.perf_counter()
            self.bytes_per_reader[reader] = (
                self.bytes_per_reader.get(reader, 0) + nbytes
            )
            self.read_time_per_reader[reader] = (
                self.read_time_per_reader.get(reader, 0.0) + dt
            )
            self.reads_per_reader[reader] = (
                self.reads_per_reader.get(reader, 0) + 1
            )

    def record_shard_read(self, shard: int, nbytes: int) -> None:
        """One physical read attributed to FileSet shard ``shard``."""
        with self.lock:
            self.shard_bytes[shard] = self.shard_bytes.get(shard, 0) + nbytes

    def record_steal(self, victim: int) -> None:
        """One splinter stolen from reader ``victim``'s pending queue —
        the per-reader straggler-pressure signal."""
        with self.lock:
            self.steals += 1
            self.steals_from_reader[victim] = (
                self.steals_from_reader.get(victim, 0) + 1
            )

    def should_time_piece(self) -> bool:
        """Cheap sampling decision — no lock; an off-by-one under contention
        only shifts which piece gets sampled."""
        if self.piece_timing_every <= 0:
            return False
        self._piece_seq += 1
        return self._piece_seq % self.piece_timing_every == 0

    def record_piece(
        self,
        nbytes: int,
        cross_node: bool,
        dt: Optional[float] = None,
        copied: int = 0,
        borrowed: bool = False,
    ) -> None:
        """``borrowed=True`` marks a zero-copy (view) delivery: cross-node
        bytes then count as ``cross_node_view_bytes`` (no transfer
        happened), never ``cross_node_bytes``."""
        with self.lock:
            self.pieces_served += 1
            self.bytes_served += nbytes
            self.bytes_copied += copied
            if cross_node:
                if borrowed:
                    self.cross_node_view_bytes += nbytes
                else:
                    self.cross_node_bytes += nbytes
            if dt is not None:
                self.permute_time_s += dt
                self.timed_pieces += 1

    def record_request(self, latency_s: float) -> None:
        with self.lock:
            self.requests += 1
            self.request_latencies_s.append(latency_s)

    def record_service_checkout(self, epoch: int, checkout_s: float,
                                arena_recycled: bool) -> None:
        """This session ran on the pooled reader service (one call, at
        reader-set start): the service generation it was armed as, the
        submit→all-workers-attached latency, and whether its arena came
        recycled from the pool."""
        with self.lock:
            self.pooled = True
            self.service_epoch = int(epoch)
            self.service_checkout_s = float(checkout_s)
            self.arena_recycled = bool(arena_recycled)

    # -- derived -------------------------------------------------------------
    def ingest_seconds(self) -> float:
        """Wall time from session start to last byte read."""
        if self.t_last_read == 0.0:
            return 0.0
        return self.t_last_read - self.t_start

    def throughput_bytes_per_s(self) -> float:
        t = self.ingest_seconds()
        return self.bytes_read / t if t > 0 else 0.0

    def imbalance(self) -> float:
        """max/mean bytes per reader — straggler indicator."""
        if not self.bytes_per_reader:
            return 0.0
        vals = list(self.bytes_per_reader.values())
        mean = sum(vals) / len(vals)
        return max(vals) / mean if mean else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "session_bytes": float(self.session_bytes),
            "num_readers": float(self.num_readers),
            "read_calls": float(self.read_calls),
            "bytes_read": float(self.bytes_read),
            "ingest_s": self.ingest_seconds(),
            "throughput_MBps": self.throughput_bytes_per_s() / 1e6,
            "steals": float(self.steals),
            "pieces_served": float(self.pieces_served),
            "bytes_served": float(self.bytes_served),
            "bytes_copied": float(self.bytes_copied),
            "cross_node_bytes": float(self.cross_node_bytes),
            "cross_node_view_bytes": float(self.cross_node_view_bytes),
            "permute_time_s": self.permute_time_s,
            "timed_pieces": float(self.timed_pieces),
            "requests": float(self.requests),
            "imbalance": self.imbalance(),
            "shards_read": float(len(self.shard_bytes)),
            "queue_depth": float(self.queue_depth),
            "readahead_bytes": float(self.readahead_bytes),
            "inflight_hwm": float(self.inflight_hwm),
            "direct_io": float(self.direct_io),
            "pooled": float(self.pooled),
            "service_epoch": float(self.service_epoch),
            "service_checkout_s": self.service_checkout_s,
            "arena_recycled": float(self.arena_recycled),
        }


@dataclass
class ServiceMetrics:
    """Reader-service observables (``ipc/service.py ReaderService``).

    One instance per service, fed from two directions: the service itself
    (admission, checkout, arena pool, worker lifecycle — recorded at the
    moment each event happens) and the Director observer path
    (``record_session`` — per-session roll-ups at close). The split keeps
    per-session metrics separate per tenant while the service totals stay
    queryable at any time.

    * ``admitted`` / ``queued`` / ``rejected`` / ``completed`` — admission
      controller outcomes; ``rejected`` counts descriptive ``ServiceBusy``
      errors raised at submit.
    * checkout latency — submit→all-workers-attached per session; the
      steady-state number the pool exists to shrink (vs ~0.5 s/worker
      spawn).
    * ``arena_hits`` / ``arena_misses`` — arena-pool recycling: a hit means
      the session reused a prefaulted segment (no ftruncate, no page
      faults); misses create fresh segments.
    * ``stale_events`` — ring events whose epoch did not match any live
      session (published by a worker whose session was already torn down);
      dropped, counted, never delivered.
    * ``workers_spawned`` / ``workers_evicted`` — pool membership churn;
      an eviction is a crashed/errored pooled worker removed WITHOUT
      tearing down sibling sessions.
    * ``rearms`` — park→re-arm transitions (sessions × workers granted).
    * ``queue_depth_hwm`` / ``occupancy_hwm`` — admission queue and
      worker-pool busy high-water marks.
    """

    lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    admitted: int = 0
    queued: int = 0
    rejected: int = 0
    completed: int = 0
    sessions_failed: int = 0
    checkout_count: int = 0
    checkout_latency_s: float = 0.0
    checkout_latency_max_s: float = 0.0
    arena_hits: int = 0
    arena_misses: int = 0
    stale_events: int = 0
    workers_spawned: int = 0
    workers_evicted: int = 0
    rearms: int = 0
    queue_depth_hwm: int = 0
    occupancy_hwm: int = 0

    def record_admitted(self) -> None:
        with self.lock:
            self.admitted += 1

    def record_queued(self, depth: int) -> None:
        with self.lock:
            self.queued += 1
            if depth > self.queue_depth_hwm:
                self.queue_depth_hwm = depth

    def record_rejected(self) -> None:
        with self.lock:
            self.rejected += 1

    def record_checkout(self, latency_s: float) -> None:
        with self.lock:
            self.checkout_count += 1
            self.checkout_latency_s += max(latency_s, 0.0)
            if latency_s > self.checkout_latency_max_s:
                self.checkout_latency_max_s = latency_s

    def record_arena(self, recycled: bool) -> None:
        with self.lock:
            if recycled:
                self.arena_hits += 1
            else:
                self.arena_misses += 1

    def record_stale_event(self) -> None:
        with self.lock:
            self.stale_events += 1

    def record_worker_spawned(self, n: int = 1) -> None:
        with self.lock:
            self.workers_spawned += n

    def record_worker_evicted(self) -> None:
        with self.lock:
            self.workers_evicted += 1

    def record_rearm(self, nworkers: int) -> None:
        with self.lock:
            self.rearms += nworkers

    def record_occupancy(self, busy: int) -> None:
        with self.lock:
            if busy > self.occupancy_hwm:
                self.occupancy_hwm = busy

    def record_session(self, m: "SessionMetrics") -> None:
        """Director observer hook: fold one closing session's outcome in.
        Non-pooled sessions (legacy spawn on a service-attached Director)
        are ignored — they never touched the pool."""
        if not m.pooled:
            return
        with self.lock:
            self.completed += 1

    def record_session_failed(self) -> None:
        with self.lock:
            self.sessions_failed += 1

    def arena_hit_rate(self) -> float:
        with self.lock:
            total = self.arena_hits + self.arena_misses
            return self.arena_hits / total if total else 0.0

    def mean_checkout_s(self) -> float:
        with self.lock:
            return (self.checkout_latency_s / self.checkout_count
                    if self.checkout_count else 0.0)

    def summary(self) -> Dict[str, float]:
        hit_rate = self.arena_hit_rate()
        mean_checkout = self.mean_checkout_s()
        with self.lock:
            return {
                "admitted": float(self.admitted),
                "queued": float(self.queued),
                "rejected": float(self.rejected),
                "completed": float(self.completed),
                "sessions_failed": float(self.sessions_failed),
                "checkout_count": float(self.checkout_count),
                "checkout_mean_s": mean_checkout,
                "checkout_max_s": self.checkout_latency_max_s,
                "arena_hits": float(self.arena_hits),
                "arena_misses": float(self.arena_misses),
                "arena_hit_rate": hit_rate,
                "stale_events": float(self.stale_events),
                "workers_spawned": float(self.workers_spawned),
                "workers_evicted": float(self.workers_evicted),
                "rearms": float(self.rearms),
                "queue_depth_hwm": float(self.queue_depth_hwm),
                "occupancy_hwm": float(self.occupancy_hwm),
            }


@dataclass
class StreamMetrics:
    """Per-pipeline streamed-staging accounting (the overlap proof).

    The streaming delivery path ships splinter groups host→device *while the
    session's reads are still in flight*; these counters exist so benchmarks
    and tests can prove the overlap instead of assuming it:

    * ``stage_latency_s`` / ``max_stage_latency_s`` — per-splinter
      arrival→staged latency (read completion to the end of the ``device_put``
      that shipped it);
    * ``inflight_bytes_hwm`` — high-water mark of bytes handed to
      ``device_put`` whose transfers have not been awaited yet (the staging
      budget's observable);
    * overlap fraction — per step, the staging span (first chunk's
      ``device_put`` start → last chunk's end) is intersected with the read
      span (session start → last byte read); the summed intersection over the
      summed step wall time is ``overlap_fraction()``. The whole-window path
      stages strictly after the last read, so it scores 0 by construction;
      a streaming run whose staging rides inside the read window approaches
      the read span / step time ratio.
    * ``stale_events`` — late splinter events dropped because their step was
      already finalized/retired (e.g. delivery racing ``resize()``).
    """

    lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    splinters_staged: int = 0
    bytes_staged: int = 0
    stage_chunks: int = 0             # device_put calls issued by the stager
    stage_time_s: float = 0.0         # summed wall time inside device_put
    stage_latency_s: float = 0.0      # summed arrival->staged latency
    max_stage_latency_s: float = 0.0
    inflight_bytes: int = 0
    inflight_bytes_hwm: int = 0
    stale_events: int = 0
    steps: int = 0
    overlap_s: float = 0.0            # read-span ∩ stage-span, summed
    step_time_s: float = 0.0
    read_time_s: float = 0.0          # summed read spans (denominator cap)

    def record_chunk(
        self, nbytes: int, nsplinters: int, dt: float, latencies_s: List[float]
    ) -> None:
        with self.lock:
            self.stage_chunks += 1
            self.splinters_staged += nsplinters
            self.bytes_staged += nbytes
            self.stage_time_s += dt
            for lat in latencies_s:
                self.stage_latency_s += lat
                if lat > self.max_stage_latency_s:
                    self.max_stage_latency_s = lat

    def stage_inflight(self, delta_bytes: int) -> None:
        """Track bytes staged-but-not-awaited (+ on device_put, - on wait)."""
        with self.lock:
            self.inflight_bytes += delta_bytes
            if self.inflight_bytes > self.inflight_bytes_hwm:
                self.inflight_bytes_hwm = self.inflight_bytes

    def record_stale_event(self) -> None:
        with self.lock:
            self.stale_events += 1

    def record_step(
        self,
        read_span: "tuple[float, float]",
        stage_span: "tuple[float, float]",
        step_time_s: float,
    ) -> None:
        """Fold one step's spans into the overlap accounting.

        Spans are absolute ``perf_counter`` intervals; the concurrent time is
        their intersection, clamped to the step wall time (prefetched steps
        can have spans that predate the step's own wall interval)."""
        r0, r1 = read_span
        s0, s1 = stage_span
        ov = max(0.0, min(r1, s1) - max(r0, s0))
        with self.lock:
            self.steps += 1
            self.step_time_s += max(step_time_s, 0.0)
            self.read_time_s += max(r1 - r0, 0.0)
            self.overlap_s += min(ov, max(step_time_s, 0.0))

    # -- derived -------------------------------------------------------------
    def overlap_fraction(self) -> float:
        """Concurrent read+staging time / total step time (0 when no steps)."""
        with self.lock:
            return self.overlap_s / self.step_time_s if self.step_time_s else 0.0

    def mean_stage_latency_s(self) -> float:
        with self.lock:
            return (self.stage_latency_s / self.splinters_staged
                    if self.splinters_staged else 0.0)

    def summary(self) -> Dict[str, float]:
        with self.lock:
            frac = self.overlap_s / self.step_time_s if self.step_time_s else 0.0
            mean_lat = (self.stage_latency_s / self.splinters_staged
                        if self.splinters_staged else 0.0)
            return {
                "splinters_staged": float(self.splinters_staged),
                "bytes_staged": float(self.bytes_staged),
                "stage_chunks": float(self.stage_chunks),
                "stage_time_s": self.stage_time_s,
                "mean_stage_latency_s": mean_lat,
                "max_stage_latency_s": self.max_stage_latency_s,
                "inflight_bytes_hwm": float(self.inflight_bytes_hwm),
                "stale_events": float(self.stale_events),
                "steps": float(self.steps),
                "overlap_s": self.overlap_s,
                "step_time_s": self.step_time_s,
                "read_time_s": self.read_time_s,
                "overlap_fraction": frac,
            }


@dataclass
class LocalityMetrics:
    """Memory-locality accounting for the topology-aware reader runtime.

    One instance per ``BufferReaderSet`` (merged into a Director-lifetime
    aggregate on session close), proving — not assuming — the locality
    levers:

    * ``same_domain_bytes`` / ``cross_domain_bytes`` — delivered piece
      bytes split by whether the owning reader's NUMA domain matches the
      consuming PE's domain. Recorded **only when a Topology is
      configured** — topology-less runs keep their locality signal in
      ``SessionMetrics.cross_node_bytes`` (node granularity), and these
      counters stay 0. Cross-domain bytes are what NUMA-aware placement
      (``near_consumers``/``domain_spread`` + domain-coalesced pieces)
      exists to reduce; ``benchmarks/perf_numa.py`` gates on them.
    * per-reader splinter histograms — splinter-size → count per reader,
      the observable of per-reader adaptive sizing (a straggling stripe
      alone showing fine splinters).
    * ``prefault_pages`` — arena pages first-touch-faulted by reader
      threads on their own domain (the ``prefault_arena`` NUMA hook);
      ``pinned_threads`` / ``pin_failures`` — ``numa_pin`` outcomes.
    """

    lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    same_domain_bytes: int = 0
    cross_domain_bytes: int = 0
    pieces_same_domain: int = 0
    pieces_cross_domain: int = 0
    prefault_pages: int = 0
    pinned_threads: int = 0
    pin_failures: int = 0
    # reader -> {splinter_bytes: count}
    splinter_hist: Dict[int, Dict[int, int]] = field(default_factory=dict)

    def record_delivery(self, nbytes: int, same_domain: bool) -> None:
        with self.lock:
            if same_domain:
                self.same_domain_bytes += nbytes
                self.pieces_same_domain += 1
            else:
                self.cross_domain_bytes += nbytes
                self.pieces_cross_domain += 1

    def record_splinter(self, reader: int, nbytes: int) -> None:
        with self.lock:
            hist = self.splinter_hist.setdefault(reader, {})
            hist[nbytes] = hist.get(nbytes, 0) + 1

    def record_prefault(self, pages: int) -> None:
        with self.lock:
            self.prefault_pages += pages

    def record_pin(self, ok: bool) -> None:
        with self.lock:
            if ok:
                self.pinned_threads += 1
            else:
                self.pin_failures += 1

    def merge(self, other: "LocalityMetrics") -> None:
        """Fold ``other`` (a finished session's counters) into this one."""
        with other.lock:
            snap = (
                other.same_domain_bytes, other.cross_domain_bytes,
                other.pieces_same_domain, other.pieces_cross_domain,
                other.prefault_pages, other.pinned_threads,
                other.pin_failures,
                {r: dict(h) for r, h in other.splinter_hist.items()},
            )
        with self.lock:
            self.same_domain_bytes += snap[0]
            self.cross_domain_bytes += snap[1]
            self.pieces_same_domain += snap[2]
            self.pieces_cross_domain += snap[3]
            self.prefault_pages += snap[4]
            self.pinned_threads += snap[5]
            self.pin_failures += snap[6]
            for r, h in snap[7].items():
                hist = self.splinter_hist.setdefault(r, {})
                for n, c in h.items():
                    hist[n] = hist.get(n, 0) + c

    # -- derived -------------------------------------------------------------
    def cross_domain_fraction(self) -> float:
        with self.lock:
            total = self.same_domain_bytes + self.cross_domain_bytes
            return self.cross_domain_bytes / total if total else 0.0

    def reader_splinter_sizes(self) -> Dict[int, List[int]]:
        """Distinct splinter sizes seen per reader (sorted)."""
        with self.lock:
            return {r: sorted(h) for r, h in self.splinter_hist.items()}

    def summary(self) -> Dict[str, float]:
        frac = self.cross_domain_fraction()
        with self.lock:
            return {
                "same_domain_bytes": float(self.same_domain_bytes),
                "cross_domain_bytes": float(self.cross_domain_bytes),
                "pieces_same_domain": float(self.pieces_same_domain),
                "pieces_cross_domain": float(self.pieces_cross_domain),
                "cross_domain_fraction": frac,
                "prefault_pages": float(self.prefault_pages),
                "pinned_threads": float(self.pinned_threads),
                "pin_failures": float(self.pin_failures),
                "readers_observed": float(len(self.splinter_hist)),
            }


@dataclass
class ShardMetrics:
    """FileSet / sharded-staging accounting.

    Two feeds, one aggregate:

    * **read side** — ``merge_session`` rides the Director observer path
      (``Director.add_observer``): each closing session's
      ``SessionMetrics.shard_bytes`` (physical bytes per FileSet shard)
      folds in here, so drivers read one object after many sessions.
    * **stage side** — the pipeline's sharded-streaming path records every
      ``device_put`` it issues (``record_stage``) plus, per step, the whole
      window size vs the bytes this host actually staged
      (``record_window``). ``addressable_bytes < window_bytes`` with
      ``cross_host_placements > 0`` is the multi-host proof: chunks bound
      for another host's devices were *placed* (counted) but never staged
      here. On a single-host mesh the two are equal and cross-host stays 0.
    """

    lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    sessions: int = 0
    shard_bytes: Dict[int, int] = field(default_factory=dict)
    device_put_calls: int = 0
    device_bytes: Dict[str, int] = field(default_factory=dict)
    window_bytes: int = 0             # full (B, S+1) windows, summed
    addressable_bytes: int = 0        # what THIS host staged, summed
    cross_host_placements: int = 0
    cross_host_bytes: int = 0

    def merge_session(self, sm: "SessionMetrics") -> None:
        """Director observer: fold one finished session's per-shard reads."""
        with sm.lock:
            snap = dict(sm.shard_bytes)
        with self.lock:
            self.sessions += 1
            for sh, nb in snap.items():
                self.shard_bytes[sh] = self.shard_bytes.get(sh, 0) + nb

    def record_stage(self, device_key: str, nbytes: int) -> None:
        """One ``device_put`` of ``nbytes`` to an addressable device."""
        with self.lock:
            self.device_put_calls += 1
            self.device_bytes[device_key] = (
                self.device_bytes.get(device_key, 0) + nbytes)

    def record_window(self, window_bytes: int, addressable_bytes: int) -> None:
        with self.lock:
            self.window_bytes += window_bytes
            self.addressable_bytes += addressable_bytes

    def record_cross_host(self, nbytes: int) -> None:
        """A chunk slice bound for a non-addressable (other-host) device:
        placed, counted, NOT staged here."""
        with self.lock:
            self.cross_host_placements += 1
            self.cross_host_bytes += nbytes

    def summary(self) -> Dict[str, float]:
        with self.lock:
            max_dev = max(self.device_bytes.values(), default=0)
            return {
                "sessions": float(self.sessions),
                "shards_read": float(len(self.shard_bytes)),
                "shard_read_bytes": float(sum(self.shard_bytes.values())),
                "device_put_calls": float(self.device_put_calls),
                "devices_staged": float(len(self.device_bytes)),
                "max_device_bytes": float(max_dev),
                "window_bytes": float(self.window_bytes),
                "addressable_bytes": float(self.addressable_bytes),
                "cross_host_placements": float(self.cross_host_placements),
                "cross_host_bytes": float(self.cross_host_bytes),
            }


@dataclass
class IngestMetrics:
    """Per-pipeline step-ingest accounting (host vs device reassembly).

    ``host_permute_bytes`` counts bytes the *host* handles past the session
    arena to build a training batch — the paper's phase-2 permutation cost.
    The host path pays the window once per step; the device path
    (``get_batch_device``) must keep it at **0**: its only per-step host
    work is one ``device_put`` of the borrowed arena view, accounted
    separately as ``h2d_transfers`` / ``h2d_bytes``. Benchmarks assert on
    these counters rather than assuming the permutation moved.
    """

    lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    steps: int = 0
    host_steps: int = 0
    device_steps: int = 0
    host_permute_bytes: int = 0
    h2d_transfers: int = 0
    h2d_bytes: int = 0

    def record_host_step(self, permute_bytes: int) -> None:
        with self.lock:
            self.steps += 1
            self.host_steps += 1
            self.host_permute_bytes += permute_bytes

    def record_device_step(
        self, staged_bytes: int, transfers: int = 1, host_bytes: int = 0
    ) -> None:
        """``host_bytes`` covers host-side copies the staging still pays
        (e.g. the copy-mode session→step-arena copy); the zero-copy device
        path passes 0."""
        with self.lock:
            self.steps += 1
            self.device_steps += 1
            self.h2d_transfers += transfers
            self.h2d_bytes += staged_bytes
            self.host_permute_bytes += host_bytes

    def summary(self) -> Dict[str, float]:
        with self.lock:
            return {
                "steps": float(self.steps),
                "host_steps": float(self.host_steps),
                "device_steps": float(self.device_steps),
                "host_permute_bytes": float(self.host_permute_bytes),
                "h2d_transfers": float(self.h2d_transfers),
                "h2d_bytes": float(self.h2d_bytes),
            }


# -- serving ------------------------------------------------------------------
def percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) — monotone in q by
    construction: rank = ceil(q/100 * n) indexes a *sorted* copy, so a
    larger q can never select a smaller order statistic. Empty input folds
    to 0.0 (a histogram with no samples has no tail)."""
    if not values:
        return 0.0
    s = sorted(values)
    if q <= 0.0:
        return s[0]
    rank = math.ceil(q / 100.0 * len(s))
    return s[min(len(s), max(1, rank)) - 1]


@dataclass
class ServeMetrics:
    """Serving-subsystem observables (``serve/``): request-latency
    histograms, slot occupancy, session churn rate, and the ingest
    backpressure state machine.

    Rides the Director observer path like every other metrics sink:
    ``director.add_observer(serve_metrics.record_session)`` folds each
    closing prompt-ingest session's byte counters in (a serving CkIO
    instance carries only ingest sessions, so no filtering is needed), and
    the proof obligation ``ingest_bytes_copied == 0`` is how the benchmark
    shows prompts ride the borrowed-view path end to end.

    Latency histograms are raw sample lists folded by nearest-rank
    :func:`percentile` at ``summary()`` time — p50/p99/p999 are monotone in
    q by construction. Three clocks per request, all measured from
    *arrival* (``submit``), not batch formation:

      * ``ingest``       arrival -> prompt bytes readable (view delivered)
      * ``first_token``  arrival -> first generated token
      * ``e2e``          arrival -> eviction (EOS / max-tokens)

    Backpressure is an explicit three-state machine owned by the
    ``RequestIngester`` and *recorded* here (``set_state`` counts every
    transition): ``open`` (admit immediately) -> ``queueing`` (``ServiceBusy``
    or the inflight-ingest-byte budget tripped; bounded FIFO) ->
    ``shedding`` (queue full; new submits raise ``ServeOverloaded``). A
    request that reached the queue is *admitted* and is never dropped —
    ``shed`` counts only rejected submits.
    """

    lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    slots: int = 0                    # decode slots (set by the batcher)
    # request lifecycle counters
    submitted: int = 0
    admitted: int = 0                 # accepted: started or queued (never dropped)
    shed: int = 0                     # rejected with ServeOverloaded at submit
    completed: int = 0
    failed: int = 0                   # terminal ingest errors (surfaced, not lost)
    generated_tokens: int = 0
    # backpressure state machine + triggers
    state: str = "open"
    transitions: Dict[str, int] = field(default_factory=dict)
    busy_events: int = 0              # ServiceBusy absorbed into the queue
    over_budget_events: int = 0       # inflight ingest bytes > budget
    queue_depth_hwm: int = 0
    inflight_bytes_hwm: int = 0
    # latency histograms (seconds, measured from arrival)
    ingest_lat_s: List[float] = field(default_factory=list)
    first_token_lat_s: List[float] = field(default_factory=list)
    e2e_lat_s: List[float] = field(default_factory=list)
    # decode-loop occupancy
    steps: int = 0
    occupied_slot_steps: int = 0
    admissions: int = 0
    evictions: int = 0
    # ingest-session fold (Director observer path)
    ingest_sessions: int = 0
    ingest_bytes: int = 0
    ingest_bytes_copied: int = 0
    pooled_sessions: int = 0
    t_first_submit: float = 0.0
    t_last_done: float = 0.0

    # -- lifecycle ------------------------------------------------------------
    def record_submitted(self, now: float) -> None:
        with self.lock:
            self.submitted += 1
            if self.t_first_submit == 0.0:
                self.t_first_submit = now

    def record_accepted(self) -> None:
        with self.lock:
            self.admitted += 1

    def record_shed(self) -> None:
        with self.lock:
            self.shed += 1

    def record_failed(self) -> None:
        with self.lock:
            self.failed += 1

    def record_ingested(self, latency_s: float) -> None:
        with self.lock:
            self.ingest_lat_s.append(latency_s)

    def record_first_token(self, latency_s: float) -> None:
        with self.lock:
            self.first_token_lat_s.append(latency_s)

    def record_completed(self, latency_s: float, new_tokens: int,
                         now: float) -> None:
        with self.lock:
            self.completed += 1
            self.generated_tokens += new_tokens
            self.e2e_lat_s.append(latency_s)
            self.t_last_done = max(self.t_last_done, now)

    # -- backpressure ----------------------------------------------------------
    def set_state(self, new: str) -> None:
        with self.lock:
            if new == self.state:
                return
            key = f"{self.state}->{new}"
            self.transitions[key] = self.transitions.get(key, 0) + 1
            self.state = new

    def record_busy(self) -> None:
        with self.lock:
            self.busy_events += 1

    def record_over_budget(self) -> None:
        with self.lock:
            self.over_budget_events += 1

    def record_queue_depth(self, depth: int) -> None:
        with self.lock:
            self.queue_depth_hwm = max(self.queue_depth_hwm, depth)

    def record_inflight_bytes(self, nbytes: int) -> None:
        with self.lock:
            self.inflight_bytes_hwm = max(self.inflight_bytes_hwm, nbytes)

    # -- decode loop -----------------------------------------------------------
    def record_step(self, occupied: int) -> None:
        with self.lock:
            self.steps += 1
            self.occupied_slot_steps += occupied

    def record_admission(self) -> None:
        with self.lock:
            self.admissions += 1

    def record_eviction(self) -> None:
        with self.lock:
            self.evictions += 1

    # -- Director observer -----------------------------------------------------
    def record_session(self, m: "SessionMetrics") -> None:
        with self.lock:
            self.ingest_sessions += 1
            self.ingest_bytes += m.bytes_read
            self.ingest_bytes_copied += m.bytes_copied
            if m.pooled:
                self.pooled_sessions += 1

    # -- folds -----------------------------------------------------------------
    def latency_percentiles(self, which: str) -> Dict[str, float]:
        with self.lock:
            vals = list(getattr(self, f"{which}_lat_s"))
        return {
            "p50": percentile(vals, 50.0),
            "p99": percentile(vals, 99.0),
            "p999": percentile(vals, 99.9),
        }

    def sessions_per_s(self) -> float:
        with self.lock:
            span = self.t_last_done - self.t_first_submit
            n = self.ingest_sessions
        return n / span if span > 0 else 0.0

    def mean_occupancy(self) -> float:
        with self.lock:
            if self.steps == 0 or self.slots == 0:
                return 0.0
            return self.occupied_slot_steps / (self.steps * self.slots)

    def summary(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for which in ("ingest", "first_token", "e2e"):
            for k, v in self.latency_percentiles(which).items():
                out[f"{which}_{k}_s"] = v
        with self.lock:
            out.update({
                "submitted": float(self.submitted),
                "admitted": float(self.admitted),
                "completed": float(self.completed),
                "shed": float(self.shed),
                "failed": float(self.failed),
                "generated_tokens": float(self.generated_tokens),
                "busy_events": float(self.busy_events),
                "over_budget_events": float(self.over_budget_events),
                "queue_depth_hwm": float(self.queue_depth_hwm),
                "inflight_bytes_hwm": float(self.inflight_bytes_hwm),
                "bp_transitions": float(sum(self.transitions.values())),
                "steps": float(self.steps),
                "admissions": float(self.admissions),
                "evictions": float(self.evictions),
                "ingest_sessions": float(self.ingest_sessions),
                "ingest_bytes": float(self.ingest_bytes),
                "ingest_bytes_copied": float(self.ingest_bytes_copied),
                "pooled_sessions": float(self.pooled_sessions),
            })
        out["sessions_per_s"] = self.sessions_per_s()
        out["mean_occupancy"] = self.mean_occupancy()
        return out
