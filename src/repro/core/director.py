"""Director + Manager groups (paper §III-C.1/2).

The Director is the singleton coordinator: it owns the file/session tables,
allocates ids ("tags"), runs the session-start broadcast, and performs any
global sequencing between sessions of distinct files (paper: reduce FS
contention by serializing sessions when asked). Managers are the per-PE
group members: each holds its PE's ReadAssembler and acks session broadcasts;
the last ack triggers the user's ``ready`` callback — mirroring the paper's
"once all the buffer chares have finished initiating their read".
"""
from __future__ import annotations

import itertools
import threading
import warnings
from typing import Callable, Dict, List, Optional

from repro.core.assembler import ReadAssembler
from repro.core.buffers import BufferReaderSet, ProcessReaderSet
from repro.core.futures import CkCallback
from repro.core.metrics import (
    LocalityMetrics,
    RecoveryMetrics,
    SessionMetrics,
    ShardMetrics,
)
from repro.core.placement import place_readers
from repro.core.scheduler import TaskScheduler
from repro.core.session import FileHandle, FileOptions, Session
from repro.core.autotune import (
    AutoTuner,
    QueueTuner,
    SplinterSizer,
    suggest_num_readers,
)
from repro.io.layout import plan_session
from repro.io.posix import DEFAULT_ALIGN, PosixFile


class Manager:
    """Per-PE service chare (group member)."""

    def __init__(self, sched: TaskScheduler, pe: int):
        self.pe = pe
        self.assembler = ReadAssembler(sched, pe)
        self.sessions: Dict[int, Session] = {}

    def register_session(self, session: Session) -> None:
        self.sessions[session.id] = session

    def forget_session(self, session_id: int) -> None:
        self.sessions.pop(session_id, None)


class Director:
    """Global coordinator chare."""

    def __init__(self, sched: TaskScheduler):
        self.sched = sched
        self.managers: List[Manager] = [
            Manager(sched, pe) for pe in range(sched.num_pes)
        ]
        self._file_ids = itertools.count()
        self._session_ids = itertools.count()
        self._lock = threading.Lock()
        self.files: Dict[int, FileHandle] = {}
        self.sessions: Dict[int, Session] = {}
        # optional global sequencing: serialize session *starts* per group key
        self._sequence_lock = threading.Lock()
        # One observation path for every knob controller: close_session feeds
        # each finished session's metrics to all of these (autotune §VI-A +
        # the streaming splinter-size controller). Extend by appending.
        self.tuner = AutoTuner(num_pes=sched.num_pes, num_nodes=sched.num_nodes)
        self.splinter_sizer = SplinterSizer()
        # Cold-path submission controller: hill-climbs (queue_depth,
        # readahead_bytes) from observed session throughput; consulted at
        # session start when FileOptions.adaptive_queue is set.
        self.queue_tuner = QueueTuner()
        self._observers = [self.tuner.record_session,
                           self.splinter_sizer.record_session,
                           self.queue_tuner.record_session]
        # Director-lifetime locality aggregate: each closing session's
        # per-session LocalityMetrics are merged here (cross-domain bytes,
        # per-reader splinter histograms) so benchmarks/drivers can read
        # one object after many sessions.
        self.locality = LocalityMetrics()
        # Director-lifetime fault-recovery aggregate (respawns, re-issued
        # splinters, I/O retries, degraded sessions) — same merge-on-close
        # pattern as ``locality``.
        self.recovery = RecoveryMetrics()
        # Director-lifetime FileSet aggregate: per-shard physical read
        # bytes, fed through the same observer path (the pipeline's
        # sharded-staging side also writes its own ShardMetrics).
        self.shards = ShardMetrics()
        self._observers.append(self.shards.merge_session)
        # Optional persistent reader service (ipc/service.py): when
        # attached, process-backend sessions run on its pooled workers /
        # recycled arenas instead of spawning per session.
        self.service = None

    def attach_service(self, service) -> None:
        """Attach a :class:`~repro.ipc.service.ReaderService`: subsequent
        ``backend="process"`` sessions check workers out of its pool
        (subject to ``FileOptions.use_service`` routing) and its
        :class:`~repro.core.metrics.ServiceMetrics` joins the observer
        path (per-session service fields fold into pool-level counters).
        The caller keeps ownership: ``service.shutdown()`` is not run by
        the Director."""
        if self.service is service:
            return
        self.service = service
        service.director = self
        self.add_observer(service.metrics.record_session)

    def add_observer(self, observe: Callable[[SessionMetrics], None]) -> None:
        """Register a session-close observer on the shared observation path
        (it receives every finished session's ``SessionMetrics``, exactly
        like the AutoTuner and SplinterSizer)."""
        self._observers.append(observe)

    # -- files ---------------------------------------------------------------
    def open_file(
        self, path: str, opts: FileOptions, opened: CkCallback
    ) -> None:
        def do_open() -> None:
            posix = PosixFile.open(path, direct_io=opts.direct_io)
            with self._lock:
                fid = next(self._file_ids)
                handle = FileHandle(id=fid, path=path, posix=posix, opts=opts)
                self.files[fid] = handle
            opened.send(self.sched, handle)

        # Opening is itself split-phase: runs as a task on PE 0.
        self.sched.enqueue(0, do_open, label="ckio-open")

    def open_fileset(
        self, fileset, opts: FileOptions, opened: CkCallback
    ) -> None:
        """Open a multi-shard manifest (``data/fileset.py FileSet``) as one
        logical file: the handle's ``posix`` is a ``ShardedFile`` over the
        manifest's global data byte space, so sessions/reads/streams work
        unchanged. The manifest is duck-typed (``sharded_file()`` +
        ``describe()``) — the core layer never imports the data layer."""

        def do_open() -> None:
            # Only pass the kwarg when asked: ``sharded_file`` is duck-typed
            # and pre-direct-io manifests keep working untouched.
            sharded = (fileset.sharded_file(direct_io=True)
                       if opts.direct_io else fileset.sharded_file())
            with self._lock:
                fid = next(self._file_ids)
                handle = FileHandle(
                    id=fid, path=sharded.path, posix=sharded, opts=opts,
                    fileset=fileset)
                self.files[fid] = handle
            opened.send(self.sched, handle)

        self.sched.enqueue(0, do_open, label="ckio-open-fileset")

    def close_file(self, handle: FileHandle, closed: CkCallback) -> None:
        def do_close() -> None:
            handle.posix.close()
            with self._lock:
                self.files.pop(handle.id, None)
            closed.send(self.sched)

        self.sched.enqueue(0, do_close, label="ckio-close")

    # -- sessions --------------------------------------------------------------
    def start_session(
        self,
        file: FileHandle,
        nbytes: int,
        offset: int,
        ready: CkCallback,
        consumer_pes: Optional[List[int]] = None,
        sequenced: bool = False,
    ) -> None:
        opts = file.opts
        num_readers = opts.num_readers or suggest_num_readers(
            nbytes, self.sched.num_pes, self.sched.num_nodes
        )
        # FileSet sessions: shard starts inside the window are HARD stripe
        # bounds (no stripe — so no splinter, so no single pread — may span
        # one). Segmenting needs >= one reader per shard segment; bump the
        # count BEFORE adaptive sizing so per-reader splinter sizes line up.
        bounds_in = getattr(file.posix, "bounds_in", None)
        hard_bounds = tuple(bounds_in(offset, nbytes)) if bounds_in else ()
        num_readers = max(num_readers, len(hard_bounds) + 1)

        def do_start() -> None:
            if sequenced:
                # Global coordination (paper §III-C.1): serialize the greedy
                # read kick-off of concurrent sessions on distinct files.
                self._sequence_lock.acquire()
            try:
                splinter_bytes = opts.splinter_bytes
                reader_sizes = None
                if opts.adaptive_splinters:
                    # Dynamic sizing: observed per-reader throughput (large
                    # on streaming stripes) shrunk by steal pressure (small
                    # near stolen tails); opts.splinter_bytes seeds the
                    # first session. Per-reader sizes (once per-stripe
                    # signal exists) let a straggling stripe alone run fine
                    # splinters.
                    splinter_bytes = self.splinter_sizer.suggest(
                        splinter_bytes)
                    reader_sizes = self.splinter_sizer.suggest_per_reader(
                        max(1, num_readers), splinter_bytes)
                plan = plan_session(
                    offset, nbytes, num_readers,
                    splinter_bytes=splinter_bytes,
                    reader_splinter_bytes=reader_sizes,
                    hard_bounds=hard_bounds or None,
                    # Stripe/splinter grid on the file's REAL block size
                    # (statvfs probe at open) — with direct_io this is what
                    # keeps every splinter offset O_DIRECT-legal.
                    align=getattr(file.posix, "block_size", DEFAULT_ALIGN),
                )
                reader_pes = place_readers(
                    opts.placement, plan.num_readers, self.sched,
                    consumer_pes, topology=opts.topology,
                )
                # Backend dispatch: same supervisor-facing interface,
                # different execution substrate (helper threads vs worker
                # processes over a shared-memory arena — core/buffers.py
                # ProcessReaderSet). A FileOptions whose process backend
                # already fell back (degraded mode is sticky per
                # FileOptions) goes straight to the thread backend without
                # re-attempting — and re-warning about — the spawn.
                ropts = opts.reader_options()
                if opts.adaptive_queue:
                    # Dynamic cold-path tuning: observed session throughput
                    # picks (queue_depth, readahead) via the QueueTuner's
                    # explore-then-exploit neighbourhood walk; the explicit
                    # FileOptions fields only seed the first session (an
                    # unset/blocking depth seeds at 8 so the walk starts in
                    # async territory).
                    seed_depth = (opts.queue_depth
                                  if opts.queue_depth >= 2 else 8)
                    depth, ra = self.queue_tuner.suggest(
                        seed_depth, opts.readahead_bytes)
                    ropts.queue_depth = depth
                    ropts.readahead_bytes = ra
                degraded = (opts.backend == "process"
                            and getattr(opts, "_fallback_active", False))
                if degraded:
                    ropts.backend = "thread"
                try:
                    session = self._build_session(
                        file, plan, reader_pes, opts, ropts)
                except Exception as exc:
                    # Graceful degradation (opt-in): a process-backend
                    # *setup* failure — spawn rejecting an unpicklable
                    # hook, shm exhaustion — downgrades to the in-process
                    # thread backend instead of failing the session.
                    # Post-start worker crashes are NOT handled here; they
                    # are the recovery layer's job (ReaderOptions.recovery).
                    if (ropts.backend != "process"
                            or opts.fallback_backend != "thread"):
                        raise
                    if not getattr(opts, "_warned_fallback", False):
                        opts._warned_fallback = True
                        warnings.warn(
                            f"process reader backend failed at session "
                            f"start ({exc}); falling back to "
                            f"backend='thread' for this file (degraded "
                            f"mode)", RuntimeWarning)
                    opts._fallback_active = True
                    degraded = True
                    ropts = opts.reader_options()
                    ropts.backend = "thread"
                    session = self._build_session(
                        file, plan, reader_pes, opts, ropts)
                if degraded:
                    session.metrics.recovery.mark_degraded()
            finally:
                # Always released — an exception above would otherwise
                # deadlock every future sequenced session start.
                if sequenced:
                    self._sequence_lock.release()

            # Broadcast to the Manager group; last ack fires `ready`.
            acks = {"n": 0}
            npes = self.sched.num_pes

            def make_register(pe: int) -> Callable[[], None]:
                def register() -> None:
                    self.managers[pe].register_session(session)
                    acks["n"] += 1
                    if acks["n"] == npes:
                        ready.send(self.sched, session)

                return register

            self.sched.enqueue_many(
                ((pe, make_register(pe)) for pe in range(npes)),
                label="ckio-bcast",
            )

        self.sched.enqueue(0, do_start, label="ckio-start-session")

    def close_session(self, session: Session, after: CkCallback) -> None:
        def do_close() -> None:
            # Feed the controllers before tearing the session down (the
            # shared observation path: AutoTuner + SplinterSizer + any
            # later-registered observer see identical metrics).
            for observe in self._observers:
                observe(session.metrics)
            self.locality.merge(session.readers.locality)
            session.readers.cancel()
            # Enforce the borrowed-view contract: views handed out by
            # read(dest=None) die with the session.
            session.readers.invalidate_borrows()
            # Backend teardown (no-op for threads; the process backend
            # joins its supervisor and unmaps the shm segments here).
            session.readers.release()
            # Merge AFTER release: the process backend's worker I/O
            # counters are folded into the session metrics by its
            # supervisor teardown, which release() joins.
            self.recovery.merge(session.metrics.recovery)
            session.closed = True
            with self._lock:
                self.sessions.pop(session.id, None)
            acks = {"n": 0}
            npes = self.sched.num_pes

            def make_forget(pe: int) -> Callable[[], None]:
                def forget() -> None:
                    self.managers[pe].forget_session(session.id)
                    acks["n"] += 1
                    if acks["n"] == npes:
                        after.send(self.sched)

                return forget

            self.sched.enqueue_many(
                ((pe, make_forget(pe)) for pe in range(npes)),
                label="ckio-close-bcast",
            )

        self.sched.enqueue(0, do_close, label="ckio-close-session")

    # -- session construction --------------------------------------------------
    def _build_session(self, file: FileHandle, plan, reader_pes: List[int],
                       opts: FileOptions, ropts) -> Session:
        """Backend dispatch + service routing. With a ReaderService
        attached, process-backend sessions run on the pool; a saturated
        service (ServiceBusy at admission) degrades to legacy per-session
        spawn when ``FileOptions.use_service`` is left at auto (None) and
        surfaces to the caller when the session was pinned (True)."""
        if (self.service is not None and ropts.backend == "process"
                and opts.use_service is not False):
            from repro.ipc.service import ServiceBusy
            try:
                return self._construct_session(
                    file, plan, reader_pes, opts, ropts,
                    service=self.service)
            except ServiceBusy:
                if opts.use_service:
                    raise
                # Auto mode: admission queue full — this session pays the
                # legacy spawn instead of waiting behind the pool.
        return self._construct_session(file, plan, reader_pes, opts, ropts)

    def _construct_session(self, file: FileHandle, plan,
                           reader_pes: List[int], opts: FileOptions, ropts,
                           service=None) -> Session:
        """Allocate an id, construct the reader set for ``ropts.backend``
        (or the attached service), register and start it. On any failure
        the half-created session is scrubbed from the tables and backend
        resources released before the exception propagates (so a fallback
        retry starts clean)."""
        with self._lock:
            sid = next(self._session_ids)
        readers = None
        try:
            if service is not None:
                from repro.ipc.service import ServiceReaderSet
                readers = ServiceReaderSet(file.posix, plan, self.sched,
                                           reader_pes, ropts,
                                           service=service,
                                           tenant=opts.tenant)
            else:
                reader_cls = (ProcessReaderSet
                              if ropts.backend == "process"
                              else BufferReaderSet)
                readers = reader_cls(file.posix, plan, self.sched,
                                     reader_pes, ropts)
            session = Session(
                id=sid,
                file=file,
                plan=plan,
                readers=readers,
                opts=opts,
                reader_pes=reader_pes,
                metrics=readers.metrics,
            )
            with self._lock:
                self.sessions[sid] = session
            # Greedy prefetch begins NOW — before any client request
            # exists.
            readers.start()
            return session
        except BaseException:
            with self._lock:
                self.sessions.pop(sid, None)
            if readers is not None:
                readers.release()
            raise
