"""POSIX file layer: pread-based stripe reads, layout math, NUMA helpers."""
from repro.io.posix import (
    PosixFile,
    write_file,
    DEFAULT_ALIGN,
    aligned_floor,
)
from repro.io.layout import (
    StripePlan,
    Splinter,
    plan_session,
    pieces_for_range,
)
from repro.io.numa import (
    detect_numa_domains,
    first_touch,
    parse_cpulist,
    pin_thread_to_cpus,
)

__all__ = [
    "PosixFile",
    "write_file",
    "DEFAULT_ALIGN",
    "aligned_floor",
    "StripePlan",
    "Splinter",
    "plan_session",
    "pieces_for_range",
    "detect_numa_domains",
    "first_touch",
    "parse_cpulist",
    "pin_thread_to_cpus",
]
