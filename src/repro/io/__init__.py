"""POSIX file layer: pread-based stripe reads, layout math, writers."""
from repro.io.posix import PosixFile, write_file, DEFAULT_ALIGN
from repro.io.layout import (
    StripePlan,
    Splinter,
    plan_session,
    pieces_for_range,
)

__all__ = [
    "PosixFile",
    "write_file",
    "DEFAULT_ALIGN",
    "StripePlan",
    "Splinter",
    "plan_session",
    "pieces_for_range",
]
