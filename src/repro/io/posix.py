"""Thin POSIX I/O wrapper used by CkIO buffer readers.

All reads are positional (``os.pread``) so a single file descriptor can be
shared by many reader threads without seek races — this mirrors the paper's
buffer chares each reading a disjoint section of one shared file. ``os.pread``
releases the GIL for the duration of the syscall, which is what lets helper
I/O threads overlap with host-side compute (paper §III-C.4).
"""
from __future__ import annotations

import os
import threading
from dataclasses import dataclass, field

# Typical FS block size; stripe/splinter boundaries are aligned to this when
# possible to avoid read-modify-write amplification on the storage side.
DEFAULT_ALIGN = 4096


def aligned_floor(nbytes: int, align: int = DEFAULT_ALIGN) -> int:
    """Largest multiple of ``align`` that is <= ``nbytes`` — but never below
    ``align`` itself. The zero-copy read path plans preadv offsets on
    splinter boundaries, so every dynamically-chosen splinter size must pass
    through this floor (a sub-block size would put read offsets off the FS
    block grid and re-introduce read-modify-write amplification)."""
    return max(align, (nbytes // align) * align)

# os.preadv reads straight into a caller-provided buffer (no intermediate
# bytes object); available on Linux/BSD since Python 3.7. When absent we fall
# back to the allocate-then-copy pread path (also used by benchmarks to
# measure the cost of that extra copy).
HAVE_PREADV = hasattr(os, "preadv")


@dataclass
class PosixFile:
    """A shared, positionally-read file handle.

    One instance is shared by every BufferReader of every session on this
    "node" — matching the paper's model where chares on a node share the file
    opened by the runtime.

    Multi-process fd hygiene (the ``backend="process"`` contract)
    -------------------------------------------------------------
    ``addref``/``close`` refcount the descriptor **within one process
    only** — the refcount is plain process memory, and an fd number means
    nothing in another process anyway. Reader worker processes therefore
    NEVER receive this object (or its fd) across ``spawn``: each worker
    calls ``PosixFile.open(path)`` itself (``ipc/worker.py``), getting a
    descriptor it alone owns and closes, so:

    * a worker crash cannot poison the parent's fd (no shared file table
      entry beyond the kernel's usual open-file object);
    * the parent may ``close()`` its handle while workers still read —
      each process's refcount covers exactly its own users;
    * fd-inheritance rules (``spawn`` closes fds by default; Python marks
      them non-inheritable) never enter the picture.

    Within a process, the rule stays: every sharer that outlives the
    opener must ``addref()`` and balance it with ``close()``; the last
    ``close`` releases the descriptor.
    """

    path: str
    fd: int = -1
    size: int = 0
    # When False (or when the platform lacks os.preadv) pread_into uses the
    # allocate-then-copy fallback; benchmarks flip this to quantify the copy.
    use_preadv: bool = True
    _refcount: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    @classmethod
    def open(cls, path: str) -> "PosixFile":
        fd = os.open(path, os.O_RDONLY)
        size = os.fstat(fd).st_size
        f = cls(path=path, fd=fd, size=size)
        f._refcount = 1
        return f

    def addref(self) -> None:
        with self._lock:
            self._refcount += 1

    def pread(self, offset: int, nbytes: int) -> bytes:
        """Positional read; safe from any thread; releases the GIL."""
        if nbytes <= 0:
            return b""
        return os.pread(self.fd, nbytes, offset)

    def pread_into(self, offset: int, view: memoryview) -> int:
        """Positional read into a caller-provided buffer — zero intermediate
        copies on the preadv path.

        Loops on short reads (the kernel may return fewer bytes than asked,
        e.g. across page-cache/readahead boundaries) and stops at EOF, so the
        return value is only < len(view) when the file genuinely ends inside
        the range. Safe from any thread; releases the GIL per syscall.
        """
        want = len(view)
        total = 0
        if self.use_preadv and HAVE_PREADV:
            while total < want:
                got = os.preadv(self.fd, [view[total:]], offset + total)
                if got <= 0:          # EOF (0); preadv never returns <0 in py
                    break
                total += got
            return total
        # Fallback: os.pread allocates a bytes object we must copy out of.
        while total < want:
            data = os.pread(self.fd, want - total, offset + total)
            if not data:              # EOF
                break
            view[total : total + len(data)] = data
            total += len(data)
        return total

    def advise_sequential(self, offset: int, nbytes: int) -> bool:
        """Hint the kernel that ``[offset, offset+nbytes)`` will be read
        sequentially and soon (``POSIX_FADV_SEQUENTIAL`` doubles readahead,
        ``WILLNEED`` starts it). Called once per reader stripe on session
        start; best-effort — returns False where unsupported."""
        try:
            os.posix_fadvise(
                self.fd, offset, nbytes, os.POSIX_FADV_SEQUENTIAL
            )
            os.posix_fadvise(self.fd, offset, nbytes, os.POSIX_FADV_WILLNEED)
            return True
        except (AttributeError, OSError):
            return False

    def close(self) -> None:
        with self._lock:
            self._refcount -= 1
            if self._refcount <= 0 and self.fd >= 0:
                os.close(self.fd)
                self.fd = -1

    @property
    def closed(self) -> bool:
        return self.fd < 0


def write_file(path: str, data: bytes, *, sync: bool = False) -> None:
    """Write a file in one shot (used by benchmarks / data generators)."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "wb") as f:
        f.write(data)
        if sync:
            f.flush()
            os.fsync(f.fileno())


def drop_page_cache(path: str) -> bool:
    """Best-effort eviction of a file from the OS page cache.

    Benchmarks call this between trials so that throughput numbers measure the
    storage path rather than DRAM. Uses ``posix_fadvise(DONTNEED)``; returns
    False when unsupported (results then measure warm-cache behaviour, which
    the benchmark records).
    """
    try:
        fd = os.open(path, os.O_RDONLY)
        try:
            os.posix_fadvise(fd, 0, 0, os.POSIX_FADV_DONTNEED)
        finally:
            os.close(fd)
        return True
    except (AttributeError, OSError):
        return False
