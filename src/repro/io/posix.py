"""Thin POSIX I/O wrapper used by CkIO buffer readers.

All reads are positional (``os.pread``) so a single file descriptor can be
shared by many reader threads without seek races — this mirrors the paper's
buffer chares each reading a disjoint section of one shared file. ``os.pread``
releases the GIL for the duration of the syscall, which is what lets helper
I/O threads overlap with host-side compute (paper §III-C.4).

Transient-error handling (the recovery layer's lowest rung)
-----------------------------------------------------------
At scale the dominant failure class is *transient* device/FS errors —
``EINTR``/``EAGAIN`` from signal/async plumbing and sporadic ``EIO`` from a
flaky path to storage. Every pread here therefore runs under a
:class:`RetryPolicy`: a failed syscall whose errno is in the policy's set is
retried with exponential backoff, capped both by a retry count and a
per-call wall-clock deadline, so a *persistently* failing device still
surfaces its error promptly instead of spinning. Retries are **counted,
never silent**: each call takes a ``stats`` sink (duck-typed —
``record_io_retry(errno)`` / ``record_suppressed(errno)``; the reader layer
passes its session's ``RecoveryMetrics``) falling back to the module-level
:data:`IO_EVENTS` aggregate so no suppression is ever dropped on the floor.

Fault injection: ``pread_into``/``pread`` consult an optional ``fault``
hook (``(offset, nbytes) -> Optional[int]``, may raise ``OSError``) before
each syscall — the deterministic short-read / flaky-EIO injection point
used by ``core/faults.py`` (picklable, so it also ships to reader worker
processes through ``WorkerSpec.io_fault``).

Direct I/O mode (the cold-cache contract)
-----------------------------------------
``PosixFile.open(path, direct_io=True)`` opens a second ``O_DIRECT``
descriptor next to the buffered one. Direct reads bypass the page cache
entirely — the kernel DMAs straight into the session arena — which is the
honest way to measure (and serve) the storage path the paper targets:
``drop_page_cache``-based eviction is advisory, but an O_DIRECT read can
never be satisfied from DRAM in the first place.

The price is alignment: file offset, request length, and the destination
buffer address must all be multiples of the filesystem block size (probed
per path via :func:`fs_block_size` — ``os.statvfs``, falling back to
:data:`DEFAULT_ALIGN`). The splinter grid already provides aligned offsets
(``aligned_floor`` over the probed block size) and NumPy/shm arenas are
page-aligned, so the steady-state read path satisfies this for free. The
two legal violations are handled, **counted, never silent**:

* a *tail* shorter than one block (end of a stripe/file) is read through
  the buffered descriptor and counted via ``record_direct_tail`` on the
  stats sink (falling back to :data:`IO_EVENTS`);
* anything structurally misaligned (arena base, session offset, shard
  ``file_base``) raises :class:`DirectIOError` with a descriptive message
  at open/start time — there is no silent fallback to buffered mode.

When to expect O_DIRECT to *lose*: warm-cache re-reads (buffered reads are
DRAM copies), tiny requests (per-request DMA setup dominates), and FSes
where the kernel's own readahead pipelines better than the submitted queue
depth. It wins on genuinely cold data, on memory-pressured nodes (no cache
pollution: a training epoch's worth of token shards never evicts the
model's pages), and wherever tail latency from page-cache writeback
interference matters.
"""
from __future__ import annotations

import ctypes
import errno
import os
import threading
import time
from bisect import bisect_right
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

# Typical FS block size; stripe/splinter boundaries are aligned to this when
# possible to avoid read-modify-write amplification on the storage side.
# Prefer :func:`fs_block_size` (statvfs probe) wherever a path is in hand —
# this constant is only the probe's fallback and the no-path default.
DEFAULT_ALIGN = 4096


def aligned_floor(nbytes: int, align: int = DEFAULT_ALIGN) -> int:
    """Largest multiple of ``align`` that is <= ``nbytes`` — but never below
    ``align`` itself. The zero-copy read path plans preadv offsets on
    splinter boundaries, so every dynamically-chosen splinter size must pass
    through this floor (a sub-block size would put read offsets off the FS
    block grid and re-introduce read-modify-write amplification)."""
    return max(align, (nbytes // align) * align)


def fs_block_size(path: str, fallback: int = DEFAULT_ALIGN) -> int:
    """Probe the filesystem block size backing ``path`` via ``os.statvfs``.

    Returns ``f_bsize`` (the preferred I/O block size — this is also the
    O_DIRECT alignment requirement on Linux for every mainstream FS) when it
    is a sane power of two in ``[512, 1 MiB]``; otherwise ``fallback``.
    A missing path is probed through its parent directory so callers can
    plan before the file exists."""
    p = path
    for _ in range(2):
        try:
            bs = int(os.statvfs(p).f_bsize)
            if 512 <= bs <= (1 << 20) and (bs & (bs - 1)) == 0:
                return bs
            return fallback
        except (OSError, AttributeError):
            p = os.path.dirname(p) or "."
    return fallback


class DirectIOError(OSError):
    """Raised when ``direct_io=True`` cannot be honoured.

    Deliberately an error, not a warning: the direct-I/O contract is
    "runs end-to-end or fails fast with the reason" — a silent fallback to
    buffered reads would report cold-cache numbers that are really DRAM."""


def _buf_addr(view: memoryview) -> int:
    """Virtual address of a writable buffer (for O_DIRECT alignment checks)."""
    return ctypes.addressof(ctypes.c_char.from_buffer(view))


def supports_direct_io(path: str) -> bool:
    """True when ``path``'s filesystem accepts ``O_DIRECT`` opens."""
    try:
        fd = os.open(path, os.O_RDONLY | os.O_DIRECT)
    except (OSError, AttributeError):
        return False
    os.close(fd)
    return True

# os.preadv reads straight into a caller-provided buffer (no intermediate
# bytes object); available on Linux/BSD since Python 3.7. When absent we fall
# back to the allocate-then-copy pread path (also used by benchmarks to
# measure the cost of that extra copy).
HAVE_PREADV = hasattr(os, "preadv")

# fadvise errnos that mean "this file/FS does not support the hint" — the
# only OSErrors the advisory helpers may swallow (counted, see IO_EVENTS).
# Anything else (EBADF — a closed/reused descriptor — above all) is a bug
# in the caller and propagates.
_FADVISE_SUPPRESS = (
    errno.EINVAL,
    errno.ESPIPE,
    errno.ENOSYS,
    errno.EOPNOTSUPP,
    getattr(errno, "ENOTSUP", errno.EOPNOTSUPP),
)


@dataclass(frozen=True)
class RetryPolicy:
    """Deadline-capped exponential backoff for transient I/O errors.

    A syscall failing with an errno in ``errnos`` is retried up to
    ``max_retries`` times, sleeping ``base_backoff_s`` doubled per attempt
    (capped at ``max_backoff_s``), but never past ``deadline_s`` of total
    wall time for one logical call — a dead device fails fast, a blip is
    absorbed. ``EINTR`` is included for completeness (Python retries it
    itself since PEP 475, but a custom signal handler raising keeps it
    reachable); ``EAGAIN`` covers O_NONBLOCK-ish paths; ``EIO`` is the
    transient-media class Cloud's survey names dominant at scale."""

    max_retries: int = 4
    base_backoff_s: float = 0.5e-3
    max_backoff_s: float = 20e-3
    deadline_s: float = 2.0
    errnos: Tuple[int, ...] = (errno.EINTR, errno.EAGAIN, errno.EIO)


class IOEventCounts:
    """Process-wide fallback sink for retry/suppression counts.

    Callers that have a session context pass their own sink (the session's
    ``RecoveryMetrics``); everything else lands here so no suppressed error
    or retry is ever silently dropped. Thread-safe."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.retries = 0
        self.suppressed = 0
        self.direct_tail_reads = 0
        self.direct_tail_bytes = 0
        self.by_errno: Dict[int, int] = {}

    def record_io_retry(self, err: Optional[int] = None) -> None:
        with self._lock:
            self.retries += 1
            if err is not None:
                self.by_errno[err] = self.by_errno.get(err, 0) + 1

    def record_suppressed(self, err: Optional[int] = None) -> None:
        with self._lock:
            self.suppressed += 1
            if err is not None:
                self.by_errno[err] = self.by_errno.get(err, 0) + 1

    def record_direct_tail(self, nbytes: int = 0) -> None:
        with self._lock:
            self.direct_tail_reads += 1
            self.direct_tail_bytes += int(nbytes)


IO_EVENTS = IOEventCounts()


@dataclass
class PosixFile:
    """A shared, positionally-read file handle.

    One instance is shared by every BufferReader of every session on this
    "node" — matching the paper's model where chares on a node share the file
    opened by the runtime.

    Multi-process fd hygiene (the ``backend="process"`` contract)
    -------------------------------------------------------------
    ``addref``/``close`` refcount the descriptor **within one process
    only** — the refcount is plain process memory, and an fd number means
    nothing in another process anyway. Reader worker processes therefore
    NEVER receive this object (or its fd) across ``spawn``: each worker
    calls ``PosixFile.open(path)`` itself (``ipc/worker.py``), getting a
    descriptor it alone owns and closes, so:

    * a worker crash cannot poison the parent's fd (no shared file table
      entry beyond the kernel's usual open-file object);
    * the parent may ``close()`` its handle while workers still read —
      each process's refcount covers exactly its own users;
    * fd-inheritance rules (``spawn`` closes fds by default; Python marks
      them non-inheritable) never enter the picture.

    Within a process, the rule stays: every sharer that outlives the
    opener must ``addref()`` and balance it with ``close()``; the last
    ``close`` releases the descriptor.
    """

    path: str
    fd: int = -1
    size: int = 0
    # When False (or when the platform lacks os.preadv) pread_into uses the
    # allocate-then-copy fallback; benchmarks flip this to quantify the copy.
    use_preadv: bool = True
    # Transient-error retry policy for every pread through this handle.
    retry: RetryPolicy = field(default_factory=RetryPolicy)
    # Test/bench fault hook consulted before each syscall:
    # ``(abs_offset, nbytes) -> Optional[int]`` — return a byte cap to force
    # a short read, raise OSError to inject a (possibly transient) failure.
    # Per-call ``fault=`` overrides this; reader workers set it from
    # ``WorkerSpec.io_fault`` (core/faults.py hooks are picklable).
    fault: Optional[object] = None
    # Direct-I/O mode: ``direct_fd`` is the O_DIRECT descriptor (body reads),
    # ``fd`` stays buffered (sub-block tails, advisory hints). ``block_size``
    # is the probed alignment every direct read must honour.
    direct_io: bool = False
    direct_fd: int = -1
    block_size: int = DEFAULT_ALIGN
    _refcount: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    @classmethod
    def open(cls, path: str, *, direct_io: bool = False) -> "PosixFile":
        fd = os.open(path, os.O_RDONLY)
        size = os.fstat(fd).st_size
        bs = fs_block_size(path)
        direct_fd = -1
        if direct_io:
            if not HAVE_PREADV:
                os.close(fd)
                raise DirectIOError(
                    f"direct_io=True needs os.preadv (read straight into an "
                    f"aligned arena view); this platform lacks it — cannot "
                    f"open {path!r} in direct mode")
            flag = getattr(os, "O_DIRECT", 0)
            if not flag:
                os.close(fd)
                raise DirectIOError(
                    f"direct_io=True: os.O_DIRECT is not available on this "
                    f"platform — cannot open {path!r} in direct mode")
            try:
                direct_fd = os.open(path, os.O_RDONLY | flag)
            except OSError as e:
                os.close(fd)
                raise DirectIOError(
                    f"direct_io=True: O_DIRECT open of {path!r} failed with "
                    f"errno {e.errno} ({os.strerror(e.errno or 0)}) — the "
                    f"filesystem does not support direct I/O") from e
        f = cls(path=path, fd=fd, size=size, direct_io=direct_io,
                direct_fd=direct_fd, block_size=bs)
        f._refcount = 1
        return f

    def addref(self) -> None:
        with self._lock:
            self._refcount += 1

    def pread(self, offset: int, nbytes: int, *, stats=None) -> bytes:
        """Positional read; safe from any thread; releases the GIL.
        Transient errors retry under ``self.retry`` (counted in ``stats``,
        default the module aggregate)."""
        if nbytes <= 0:
            return b""
        sink = stats if stats is not None else IO_EVENTS
        pol = self.retry
        attempts, pause, deadline = 0, pol.base_backoff_s, None
        while True:
            try:
                return os.pread(self.fd, nbytes, offset)
            except OSError as e:
                if e.errno not in pol.errnos:
                    raise
                if deadline is None:
                    deadline = time.monotonic() + pol.deadline_s
                attempts += 1
                if attempts > pol.max_retries or time.monotonic() > deadline:
                    raise
                sink.record_io_retry(e.errno)
                time.sleep(pause)
                pause = min(pause * 2.0, pol.max_backoff_s)

    def pread_into(self, offset: int, view: memoryview, *,
                   stats=None, fault=None) -> int:
        """Positional read into a caller-provided buffer — zero intermediate
        copies on the preadv path.

        Loops on short reads (the kernel may return fewer bytes than asked,
        e.g. across page-cache/readahead boundaries) and stops at EOF, so the
        return value is only < len(view) when the file genuinely ends inside
        the range. Safe from any thread; releases the GIL per syscall.

        Transient errors (``self.retry.errnos``) are retried per syscall
        with deadline-capped exponential backoff; each retry is counted in
        ``stats`` (``record_io_retry``), defaulting to :data:`IO_EVENTS`.
        ``fault`` (default ``self.fault``) is the injection hook — it may
        cap a syscall's length (short read) or raise ``OSError`` (which
        then flows through the same retry machinery a real error would).

        Direct mode: the aligned body of the request goes through the
        O_DIRECT descriptor; any sub-block fragment (a tail shorter than
        one block, or a grid re-sync after an injected/EOF short read)
        goes through the buffered descriptor and is counted via
        ``record_direct_tail`` on the stats sink. A structurally
        misaligned call (offset or buffer address off the probed block
        grid) raises :class:`DirectIOError` — never a silent fallback.
        """
        want = len(view)
        total = 0
        sink = stats if stats is not None else IO_EVENTS
        hook = fault if fault is not None else self.fault
        pol = self.retry
        use_v = self.use_preadv and HAVE_PREADV
        direct = self.direct_io and self.direct_fd >= 0
        bs = self.block_size
        if direct and want > 0:
            if offset % bs:
                raise DirectIOError(
                    f"direct read at offset {offset} is off the {bs}-byte "
                    f"block grid of {self.path!r} (offset % {bs} == "
                    f"{offset % bs}); plan splinters with "
                    f"align=fs_block_size(path)")
            addr = _buf_addr(view)
            if addr % bs:
                raise DirectIOError(
                    f"direct read destination buffer at 0x{addr:x} is not "
                    f"{bs}-byte aligned for {self.path!r}; the session "
                    f"arena must be allocated on the block grid")
        while total < want:
            attempts, pause, deadline = 0, pol.base_backoff_s, None
            tail_frag = False
            while True:
                cap = want - total
                try:
                    if hook is not None:
                        c = hook(offset + total, cap)
                        if c is not None:
                            cap = max(1, min(cap, int(c)))
                    pos = offset + total
                    tail_frag = False
                    if direct and pos % bs == 0 and cap >= bs:
                        # Aligned body — DMA straight into the arena view.
                        dcap = (cap // bs) * bs
                        got = os.preadv(
                            self.direct_fd, [view[total: total + dcap]], pos
                        )
                    elif direct:
                        # Sub-block fragment (tail, or re-sync to the grid
                        # after a short return) — buffered fd, counted.
                        frag = pos % bs
                        bcap = min(cap, bs - frag) if frag else cap
                        got = os.preadv(
                            self.fd, [view[total: total + bcap]], pos
                        )
                        tail_frag = True
                    elif use_v:
                        got = os.preadv(
                            self.fd, [view[total: total + cap]], offset + total
                        )
                    else:
                        # Fallback: os.pread allocates a bytes object we
                        # must copy out of.
                        data = os.pread(self.fd, cap, offset + total)
                        got = len(data)
                        if got:
                            view[total: total + got] = data
                    break
                except OSError as e:
                    if e.errno not in pol.errnos:
                        raise
                    if deadline is None:
                        deadline = time.monotonic() + pol.deadline_s
                    attempts += 1
                    if attempts > pol.max_retries or \
                            time.monotonic() > deadline:
                        raise
                    sink.record_io_retry(e.errno)
                    time.sleep(pause)
                    pause = min(pause * 2.0, pol.max_backoff_s)
            if got <= 0:              # EOF (preadv never returns <0 in py)
                break
            if tail_frag:
                rec = getattr(sink, "record_direct_tail", None)
                (rec if rec is not None else IO_EVENTS.record_direct_tail)(got)
            total += got
        return total

    def advise_sequential(self, offset: int, nbytes: int, *,
                          stats=None) -> bool:
        """Hint the kernel that ``[offset, offset+nbytes)`` will be read
        sequentially and soon (``POSIX_FADV_SEQUENTIAL`` doubles readahead,
        ``WILLNEED`` starts it). Called once per reader stripe on session
        start; best-effort — returns False where unsupported.

        Only the *intended* gaps are swallowed: a platform without
        ``posix_fadvise`` (AttributeError) and the does-not-support-hints
        errnos (counted in ``stats``/:data:`IO_EVENTS`, never silent).
        Anything else — ``EBADF`` above all — propagates: it means a bug,
        not an unsupported FS."""
        try:
            fadvise = os.posix_fadvise
        except AttributeError:        # platform gap — nothing to count
            return False
        sink = stats if stats is not None else IO_EVENTS
        try:
            fadvise(self.fd, offset, nbytes, os.POSIX_FADV_SEQUENTIAL)
            fadvise(self.fd, offset, nbytes, os.POSIX_FADV_WILLNEED)
            return True
        except OSError as e:
            if e.errno in _FADVISE_SUPPRESS:
                sink.record_suppressed(e.errno)
                return False
            raise

    def close(self) -> None:
        with self._lock:
            self._refcount -= 1
            if self._refcount <= 0 and self.fd >= 0:
                os.close(self.fd)
                self.fd = -1
                if self.direct_fd >= 0:
                    os.close(self.direct_fd)
                    self.direct_fd = -1

    @property
    def closed(self) -> bool:
        return self.fd < 0


class ShardedFile:
    """A ``PosixFile``-compatible handle over an ordered set of shard files.

    Presents N on-disk shards as ONE contiguous byte space so every layer
    above (stripe planning, buffer readers, borrowed views, the shm worker
    drain loop) works unchanged over a multi-file corpus. The byte space is
    whatever the segment table says it is — for token-file sets it is the
    concatenation of each shard's *data* region (headers excluded), built by
    ``data/fileset.py``.

    ``segments`` is a tuple of ``(path, global_start, file_base, nbytes,
    shard_id)``: bytes ``[global_start, global_start + nbytes)`` of the
    global space live at file offset ``file_base`` of ``path``. Segments
    must be ascending and contiguous (no gaps); zero-byte shards are simply
    omitted from the table (their ``shard_id``s stay reserved for
    attribution). The table is a plain tuple of primitives — picklable, so
    reader worker processes receive it through ``WorkerSpec.shards`` and
    open their own descriptors by path, exactly as the ``PosixFile``
    multi-process fd-hygiene contract mandates.

    Semantics mirror ``PosixFile``: positional reads from any thread,
    short-read/EOF behaviour (a torn shard body returns short, it does not
    raise), per-shard transient-error retry (each underlying handle keeps
    its own ``RetryPolicy``), refcounted ``addref``/``close``. The ``fault``
    injection hook is forwarded to the per-shard reads; note it then
    observes *shard-file* offsets, which keeps the count-based hooks in
    ``core/faults.py`` deterministic.
    """

    def __init__(self, segments: Sequence[Tuple[str, int, int, int, int]],
                 *, direct_io: bool = False):
        segs = tuple(
            (str(p), int(g), int(b), int(n), int(sid))
            for (p, g, b, n, sid) in segments
        )
        if not segs:
            raise ValueError("ShardedFile needs at least one segment")
        for i, (p, g, b, n, sid) in enumerate(segs):
            if n <= 0:
                raise ValueError(f"segment {i} ({p}): non-positive length {n}")
            if i and g != segs[i - 1][1] + segs[i - 1][3]:
                raise ValueError(
                    f"segment {i} ({p}): global space has a gap "
                    f"({segs[i - 1][1] + segs[i - 1][3]} != {g})")
        self.segments = segs
        self._starts = tuple(g for (_, g, _, _, _) in segs)
        self.offset = segs[0][1]
        self.size = segs[-1][1] + segs[-1][3]   # end of the global space
        self.path = (f"fileset[{len(segs)} shards: {segs[0][0]} .. "
                     f"{segs[-1][0]}]")
        self.fault: Optional[object] = None
        self.direct_io = bool(direct_io)
        self._lock = threading.Lock()
        self._refcount = 1
        # One descriptor per unique path (a path may legally back several
        # segments); opened here, owned by this handle alone.
        self._by_path: Dict[str, PosixFile] = {}
        try:
            for p, *_ in segs:
                if p not in self._by_path:
                    self._by_path[p] = PosixFile.open(p, direct_io=direct_io)
        except OSError:
            for f in self._by_path.values():
                f.close()
            raise
        self._files = tuple(self._by_path[p] for (p, *_ ) in segs)
        self.block_size = max(f.block_size for f in self._by_path.values())
        if direct_io:
            # A shard whose data region starts off the block grid would put
            # every global-aligned read at an unaligned file offset — reject
            # up front with the offender list, per the direct-I/O contract.
            bad = [(p, "file_base", b) for (p, g, b, n, sid) in segs
                   if b % self._by_path[p].block_size]
            # Interior shard starts become hard stripe bounds; if one is off
            # the grid, every splinter of that shard lands at an unaligned
            # arena position (buffer-address check would fail at read time).
            bad += [(p, "global_start", g) for (p, g, b, n, sid) in segs[1:]
                    if g % self.block_size]
            if bad:
                for f in self._by_path.values():
                    f.close()
                raise DirectIOError(
                    f"direct_io=True: {len(bad)} shard segment field(s) off "
                    f"the block grid (first: {bad[0][0]!r} {bad[0][1]}="
                    f"{bad[0][2]}); direct sharded sessions need "
                    f"block-aligned shard data regions and block-multiple "
                    f"shard sizes")

    @classmethod
    def from_segments(cls, segments, *, direct_io: bool = False
                      ) -> "ShardedFile":
        """Rebuild from a pickled segment table (worker-process side)."""
        return cls(segments, direct_io=direct_io)

    @property
    def worker_segments(self) -> Tuple[Tuple[str, int, int, int, int], ...]:
        """The picklable table a reader worker rebuilds this handle from."""
        return self.segments

    # -- shard resolution -------------------------------------------------
    def _seg_at(self, global_off: int) -> int:
        i = bisect_right(self._starts, global_off) - 1
        if i < 0:
            raise ValueError(
                f"offset {global_off} before global space start {self.offset}")
        return i

    def shard_of(self, global_off: int) -> int:
        """Shard id owning the byte at ``global_off`` (end maps to last)."""
        return self.segments[self._seg_at(min(global_off, self.size - 1))][4]

    def bounds_in(self, offset: int, nbytes: int) -> List[int]:
        """Interior shard-start offsets strictly inside
        ``(offset, offset + nbytes)`` — the hard stripe bounds a session
        plan over this handle must not let any stripe span."""
        end = offset + nbytes
        return [g for g in self._starts[1:] if offset < g < end]

    # -- PosixFile surface -------------------------------------------------
    def addref(self) -> None:
        with self._lock:
            self._refcount += 1

    def pread_into(self, offset: int, view: memoryview, *,
                   stats=None, fault=None) -> int:
        """Positional read of the global space into ``view``; loops across
        shard boundaries. Returns short only at genuine end-of-space or a
        torn shard body (per-shard EOF), mirroring ``PosixFile``."""
        want = len(view)
        if want <= 0:
            return 0
        hook = fault if fault is not None else self.fault
        total = 0
        i = self._seg_at(offset)
        while total < want and i < len(self.segments):
            _, g, b, n, _ = self.segments[i]
            seg_off = offset + total - g
            if seg_off >= n:            # past this segment: next one
                i += 1
                continue
            take = min(want - total, n - seg_off)
            got = self._files[i].pread_into(
                b + seg_off, view[total: total + take],
                stats=stats, fault=hook)
            total += got
            if got < take:              # torn shard body — stop short
                break
            i += 1
        return total

    def pread(self, offset: int, nbytes: int, *, stats=None) -> bytes:
        if nbytes <= 0:
            return b""
        buf = bytearray(min(nbytes, max(0, self.size - offset)))
        got = self.pread_into(offset, memoryview(buf), stats=stats)
        return bytes(buf[:got])

    def advise_sequential(self, offset: int, nbytes: int, *,
                          stats=None) -> bool:
        """Per-shard sequential/willneed hints over the intersected ranges."""
        ok = False
        end = offset + nbytes
        for (_, g, b, n, _), f in zip(self.segments, self._files):
            s, e = max(offset, g), min(end, g + n)
            if s < e:
                ok = f.advise_sequential(b + (s - g), e - s, stats=stats) or ok
        return ok

    def close(self) -> None:
        with self._lock:
            self._refcount -= 1
            if self._refcount > 0:
                return
        for f in self._by_path.values():
            f.close()
        self._by_path = {}
        self._files = ()

    @property
    def closed(self) -> bool:
        return not self._files


def write_file(path: str, data: bytes, *, sync: bool = False) -> None:
    """Write a file in one shot (used by benchmarks / data generators)."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "wb") as f:
        f.write(data)
        if sync:
            f.flush()
            os.fsync(f.fileno())


def drop_page_cache(path: str, *, stats=None) -> bool:
    """Best-effort eviction of a file from the OS page cache.

    Benchmarks call this between trials so that throughput numbers measure the
    storage path rather than DRAM. Uses ``posix_fadvise(DONTNEED)``; returns
    False when unsupported (results then measure warm-cache behaviour, which
    the benchmark records). Suppressed errors are counted (``stats`` /
    :data:`IO_EVENTS`): the swallowed set is the fadvise
    unsupported-hint errnos plus a missing/unreadable path — an unexpected
    errno propagates instead of masquerading as "cache not dropped".
    """
    sink = stats if stats is not None else IO_EVENTS
    try:
        fadvise = os.posix_fadvise
    except AttributeError:            # platform gap — nothing to count
        return False
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError as e:
        if e.errno in (errno.ENOENT, errno.EACCES, errno.EPERM, errno.EISDIR):
            sink.record_suppressed(e.errno)
            return False
        raise
    try:
        try:
            # DONTNEED cannot evict DIRTY pages — a file written moments
            # ago (every benchmark fixture) would silently stay resident.
            # fsync on a read-only fd is legal on Linux and flushes the
            # inode's dirty pages first; failure is advisory, not fatal.
            os.fsync(fd)
        except OSError as e:
            sink.record_suppressed(e.errno)
        try:
            fadvise(fd, 0, 0, os.POSIX_FADV_DONTNEED)
        except OSError as e:
            if e.errno in _FADVISE_SUPPRESS:
                sink.record_suppressed(e.errno)
                return False
            raise
    finally:
        os.close(fd)
    return True
