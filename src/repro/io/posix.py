"""Thin POSIX I/O wrapper used by CkIO buffer readers.

All reads are positional (``os.pread``) so a single file descriptor can be
shared by many reader threads without seek races — this mirrors the paper's
buffer chares each reading a disjoint section of one shared file. ``os.pread``
releases the GIL for the duration of the syscall, which is what lets helper
I/O threads overlap with host-side compute (paper §III-C.4).
"""
from __future__ import annotations

import os
import threading
from dataclasses import dataclass, field

# Typical FS block size; stripe/splinter boundaries are aligned to this when
# possible to avoid read-modify-write amplification on the storage side.
DEFAULT_ALIGN = 4096


@dataclass
class PosixFile:
    """A shared, positionally-read file handle.

    One instance is shared by every BufferReader of every session on this
    "node" — matching the paper's model where chares on a node share the file
    opened by the runtime.
    """

    path: str
    fd: int = -1
    size: int = 0
    _refcount: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    @classmethod
    def open(cls, path: str) -> "PosixFile":
        fd = os.open(path, os.O_RDONLY)
        size = os.fstat(fd).st_size
        f = cls(path=path, fd=fd, size=size)
        f._refcount = 1
        return f

    def addref(self) -> None:
        with self._lock:
            self._refcount += 1

    def pread(self, offset: int, nbytes: int) -> bytes:
        """Positional read; safe from any thread; releases the GIL."""
        if nbytes <= 0:
            return b""
        return os.pread(self.fd, nbytes, offset)

    def pread_into(self, offset: int, view: memoryview) -> int:
        """Positional read into a caller-provided buffer (one copy total)."""
        data = os.pread(self.fd, len(view), offset)
        n = len(data)
        view[:n] = data
        return n

    def close(self) -> None:
        with self._lock:
            self._refcount -= 1
            if self._refcount <= 0 and self.fd >= 0:
                os.close(self.fd)
                self.fd = -1

    @property
    def closed(self) -> bool:
        return self.fd < 0


def write_file(path: str, data: bytes, *, sync: bool = False) -> None:
    """Write a file in one shot (used by benchmarks / data generators)."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "wb") as f:
        f.write(data)
        if sync:
            f.flush()
            os.fsync(f.fileno())


def drop_page_cache(path: str) -> bool:
    """Best-effort eviction of a file from the OS page cache.

    Benchmarks call this between trials so that throughput numbers measure the
    storage path rather than DRAM. Uses ``posix_fadvise(DONTNEED)``; returns
    False when unsupported (results then measure warm-cache behaviour, which
    the benchmark records).
    """
    try:
        fd = os.open(path, os.O_RDONLY)
        try:
            os.posix_fadvise(fd, 0, 0, os.POSIX_FADV_DONTNEED)
        finally:
            os.close(fd)
        return True
    except (AttributeError, OSError):
        return False
