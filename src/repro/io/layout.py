"""Stripe / splinter layout math for read sessions.

A read session covers ``[offset, offset+nbytes)`` of one file. The session is
decomposed twice, mirroring the paper:

* **stripes** — one contiguous disjoint stripe per buffer reader (paper §III-C.4:
  "Each buffer chare reads a disjoint section of the file").
* **splinters** — fixed-size sub-chunks *within* a stripe (paper §VI-C,
  "Splintered I/O", implemented here): the unit of actual pread calls, early
  request fulfilment, and work stealing.

All functions here are pure and unit-tested (including hypothesis properties:
stripes partition the session; every byte belongs to exactly one splinter).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

from repro.io.posix import DEFAULT_ALIGN, aligned_floor


@dataclass(frozen=True)
class Splinter:
    """One unit of physical I/O within a reader's stripe."""

    reader: int        # owning reader index
    index: int         # splinter index within the session (global)
    offset: int        # absolute file offset
    nbytes: int

    @property
    def end(self) -> int:
        return self.offset + self.nbytes


@dataclass(frozen=True)
class StripePlan:
    """Full decomposition of a session across readers."""

    offset: int                      # session start (absolute)
    nbytes: int                      # session length
    num_readers: int
    splinter_bytes: int
    stripe_bounds: Tuple[Tuple[int, int], ...]   # per reader: (abs_start, abs_end)
    splinters: Tuple[Splinter, ...]              # global splinter list
    # Per-reader adaptive sizing: when set, reader r's stripe was cut into
    # reader_splinter_bytes[r]-sized splinters (splinter_bytes then only
    # records the session-level base size). None = uniform splinter_bytes.
    reader_splinter_bytes: Optional[Tuple[int, ...]] = None
    # Hard segmentation offsets the plan honoured (FileSet shard starts):
    # no stripe — hence no splinter, hence no single pread — spans one.
    hard_bounds: Optional[Tuple[int, ...]] = None

    @property
    def end(self) -> int:
        return self.offset + self.nbytes

    def reader_for(self, abs_off: int) -> int:
        """Reader owning the byte at ``abs_off`` (binary search over stripes)."""
        lo, hi = 0, self.num_readers - 1
        while lo < hi:
            mid = (lo + hi) // 2
            if self.stripe_bounds[mid][1] <= abs_off:
                lo = mid + 1
            else:
                hi = mid
        return lo

    def splinters_for_reader(self, r: int) -> List[Splinter]:
        return [s for s in self.splinters if s.reader == r]


def _align_up(x: int, a: int) -> int:
    return ((x + a - 1) // a) * a


def _cut_stripes(
    offset: int, nbytes: int, num_readers: int, align: int
) -> List[Tuple[int, int]]:
    """The classic stripe cut of ``[offset, offset+nbytes)`` over
    ``num_readers``: interior boundaries aligned up to ``align``, the final
    stripe absorbs the remainder, trailing readers may get empty stripes."""
    base = nbytes // num_readers
    stripe_len = _align_up(max(base, 1), align) if nbytes else 0
    bounds: List[Tuple[int, int]] = []
    cur = offset
    end = offset + nbytes
    for r in range(num_readers):
        if r == num_readers - 1:
            s, e = cur, end
        else:
            s, e = cur, min(cur + stripe_len, end)
        bounds.append((s, e))
        cur = e
    return bounds


def _readers_per_segment(
    seg_bytes: Sequence[int], num_readers: int
) -> List[int]:
    """Largest-remainder allocation of readers to segments: every segment
    gets >= 1 reader (a shard is never co-owned with its neighbour), extras
    go by byte share, deterministic tie-break on segment order."""
    nsegs = len(seg_bytes)
    alloc = [1] * nsegs
    extra = num_readers - nsegs
    total = sum(seg_bytes)
    if extra <= 0 or total == 0:
        return alloc
    shares = [extra * b / total for b in seg_bytes]
    floors = [int(sh) for sh in shares]
    for i, fl in enumerate(floors):
        alloc[i] += fl
    rest = extra - sum(floors)
    order = sorted(range(nsegs), key=lambda i: (-(shares[i] - floors[i]), i))
    for i in order[:rest]:
        alloc[i] += 1
    return alloc


def plan_session(
    offset: int,
    nbytes: int,
    num_readers: int,
    splinter_bytes: int = 8 * 1024 * 1024,
    align: int = DEFAULT_ALIGN,
    reader_splinter_bytes: Optional[Sequence[int]] = None,
    hard_bounds: Optional[Sequence[int]] = None,
) -> StripePlan:
    """Partition ``[offset, offset+nbytes)`` into stripes and splinters.

    Stripe boundaries are aligned to ``align`` (FS block size) except at the
    session edges; splinters are capped at ``splinter_bytes``. Degenerate
    cases (more readers than bytes) collapse gracefully: trailing readers get
    empty stripes.

    ``reader_splinter_bytes`` (per-reader adaptive sizing) overrides the
    splinter size per stripe: reader ``r`` reads in
    ``reader_splinter_bytes[r]`` units — a straggling stripe can run fine
    splinters (tight steal granularity) while healthy stripes stream large
    reads. Stripe *bounds* stay a function of ``num_readers`` alone, so
    per-reader sizes never change which reader owns a byte.

    ``hard_bounds`` (FileSet shard starts, in session byte-space) are
    offsets NO stripe may span: the session is first segmented at every
    hard bound strictly inside it, readers are distributed over segments by
    byte share (>= 1 each, largest-remainder), and each segment is striped
    independently. Since a splinter lives inside one stripe, no physical
    read ever crosses a shard boundary — each lands wholly in one shard
    file. Requires ``num_readers >= number of segments`` (the Director
    bumps the reader count before planning a FileSet session).
    """
    if nbytes < 0:
        raise ValueError(f"negative session length {nbytes}")
    num_readers = max(1, num_readers)
    # Floor every splinter size to an ``align`` multiple (not just a
    # minimum): a non-multiple size would put every subsequent splinter
    # offset in the stripe off the FS block grid — the read-modify-write
    # amplification the alignment contract exists to prevent. Enforced
    # here so every caller is covered, not only the SplinterSizer.
    splinter_bytes = aligned_floor(splinter_bytes, align)
    if reader_splinter_bytes is not None:
        if len(reader_splinter_bytes) != num_readers:
            raise ValueError(
                f"reader_splinter_bytes has {len(reader_splinter_bytes)} "
                f"entries for {num_readers} readers")
        reader_splinter_bytes = tuple(
            aligned_floor(int(s), align) for s in reader_splinter_bytes)

    end = offset + nbytes
    cuts = (sorted({int(b) for b in hard_bounds if offset < int(b) < end})
            if hard_bounds else [])

    if not cuts:
        bounds = _cut_stripes(offset, nbytes, num_readers, align)
    else:
        edges = [offset] + cuts + [end]
        segs = list(zip(edges[:-1], edges[1:]))
        if num_readers < len(segs):
            raise ValueError(
                f"{num_readers} readers cannot honour {len(segs)} hard "
                f"segments (need >= one reader per segment)")
        alloc = _readers_per_segment([e - s for s, e in segs], num_readers)
        bounds = []
        for (s, e), k in zip(segs, alloc):
            bounds.extend(_cut_stripes(s, e - s, k, align))

    splinters: List[Splinter] = []
    gidx = 0
    for r, (s, e) in enumerate(bounds):
        sb = (reader_splinter_bytes[r] if reader_splinter_bytes is not None
              else splinter_bytes)
        pos = s
        while pos < e:
            n = min(sb, e - pos)
            splinters.append(Splinter(reader=r, index=gidx, offset=pos, nbytes=n))
            gidx += 1
            pos += n

    return StripePlan(
        offset=offset,
        nbytes=nbytes,
        num_readers=num_readers,
        splinter_bytes=splinter_bytes,
        stripe_bounds=tuple(bounds),
        splinters=tuple(splinters),
        reader_splinter_bytes=reader_splinter_bytes,
        hard_bounds=tuple(cuts) if cuts else None,
    )


def pieces_for_range(
    plan: StripePlan,
    abs_off: int,
    nbytes: int,
    coalesce_key: Optional[Callable[[int], object]] = None,
) -> List[Tuple[int, int, int]]:
    """Split a client read ``[abs_off, abs_off+nbytes)`` into per-reader pieces.

    Returns ``[(reader, piece_abs_off, piece_nbytes), ...]`` in file order.
    The paper notes that given realistic over-decomposition each request
    touches 1–2 consecutive readers; this handles the general case.

    ``coalesce_key`` enables piece coalescing (Thakur-style request merging):
    contiguous pieces whose readers map to the same key — typically the
    reader's node, since the whole arena is addressable within a node — are
    merged into one piece attributed to the first reader of the run. A
    request spanning K stripes of co-located readers then costs one waiter,
    one scheduled task and one copy (or zero copies on the borrowed-view
    path) instead of K of each. ``None`` keeps the exact per-stripe split.
    """
    if abs_off < plan.offset or abs_off + nbytes > plan.end:
        raise ValueError(
            f"read [{abs_off}, {abs_off + nbytes}) outside session "
            f"[{plan.offset}, {plan.end})"
        )
    pieces: List[Tuple[int, int, int]] = []
    pos = abs_off
    end = abs_off + nbytes
    while pos < end:
        r = plan.reader_for(pos)
        _, stripe_end = plan.stripe_bounds[r]
        take = min(end, stripe_end) - pos
        if take <= 0:  # pragma: no cover - guarded by reader_for correctness
            raise RuntimeError("layout error: zero-length piece")
        if (
            coalesce_key is not None
            and pieces
            and coalesce_key(pieces[-1][0]) == coalesce_key(r)
        ):
            pr, po, pn = pieces[-1]
            pieces[-1] = (pr, po, pn + take)   # pieces are contiguous in file order
        else:
            pieces.append((r, pos, take))
        pos += take
    return pieces


def splinters_covering(
    plan: StripePlan, abs_off: int, nbytes: int
) -> List[Splinter]:
    """All splinters intersecting ``[abs_off, abs_off+nbytes)``."""
    end = abs_off + nbytes
    return [s for s in plan.splinters if s.offset < end and s.end > abs_off]
