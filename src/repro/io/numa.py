"""Host NUMA helpers: domain detection, thread pinning, first-touch faulting.

Pure OS-level utilities (no dependency on the core runtime) used by the
topology-aware reader layer:

* ``detect_numa_domains`` parses ``/sys/devices/system/node/node*/cpulist``
  into per-domain CPU sets, falling back to one domain spanning every CPU
  when the sysfs tree is absent (non-Linux, containers with masked /sys).
* ``pin_thread_to_cpus`` pins the *calling thread* (``sched_setaffinity``
  with pid 0 targets the caller on Linux) to a domain's CPUs — best-effort,
  returns False where unsupported so callers degrade instead of failing.
* ``first_touch`` faults every page of a buffer from the calling thread by
  writing one byte per page. Under Linux's first-touch policy the faulting
  thread's NUMA node gets the page, so a reader thread pinned to its domain
  and first-touching its own arena stripe places that stripe's memory
  locally — **without** the full zero-fill pass that would defeat the
  non-zero-filled ``np.empty`` session arena (every byte is overwritten by
  ``preadv`` anyway; only 1/page_size of the bytes are written here, and on
  the reader's own thread rather than the session-start critical path).
"""
from __future__ import annotations

import glob
import os
import re
from typing import List, Sequence, Set, Tuple

import numpy as np

_SYS_NODE_GLOB = "/sys/devices/system/node/node[0-9]*"

try:
    PAGE_BYTES = os.sysconf("SC_PAGE_SIZE")
except (AttributeError, ValueError, OSError):  # pragma: no cover - non-posix
    PAGE_BYTES = 4096


def parse_cpulist(text: str) -> Set[int]:
    """Parse a kernel cpulist (``"0-3,8,10-11"``) into a set of CPU ids."""
    cpus: Set[int] = set()
    for part in text.strip().split(","):
        part = part.strip()
        if not part:
            continue
        m = re.fullmatch(r"(\d+)(?:-(\d+))?", part)
        if not m:
            raise ValueError(f"bad cpulist component: {part!r}")
        lo = int(m.group(1))
        hi = int(m.group(2)) if m.group(2) else lo
        if hi < lo:
            raise ValueError(f"bad cpulist range: {part!r}")
        cpus.update(range(lo, hi + 1))
    return cpus


def detect_numa_domains() -> List[Tuple[int, ...]]:
    """CPU sets of the host's NUMA nodes, in node-id order.

    Always returns at least one domain: hosts without a sysfs NUMA tree
    (or non-Linux platforms) report a single domain spanning every CPU.
    """
    domains: List[Tuple[int, ...]] = []
    for node_dir in sorted(
        glob.glob(_SYS_NODE_GLOB),
        key=lambda p: int(re.search(r"node(\d+)$", p).group(1)),
    ):
        try:
            with open(os.path.join(node_dir, "cpulist")) as f:
                cpus = parse_cpulist(f.read())
        except (OSError, ValueError):
            continue
        if cpus:
            domains.append(tuple(sorted(cpus)))
    if not domains:
        domains.append(tuple(range(os.cpu_count() or 1)))
    return domains


def current_cpus() -> Set[int]:
    """Calling thread's CPU affinity (all CPUs where unsupported)."""
    try:
        return set(os.sched_getaffinity(0))
    except (AttributeError, OSError):  # pragma: no cover - non-linux
        return set(range(os.cpu_count() or 1))


def pin_thread_to_cpus(cpus: Sequence[int]) -> bool:
    """Pin the calling thread to ``cpus``. Best-effort: False on platforms
    without ``sched_setaffinity`` or when the mask is rejected (e.g. cgroup
    cpuset excludes them) — callers must treat pinning as advisory."""
    if not cpus:
        return False
    try:
        os.sched_setaffinity(0, set(cpus))
        return True
    except (AttributeError, OSError, ValueError):
        return False


def first_touch(buf, page_bytes: int = 0) -> int:
    """Fault every page of ``buf`` from the calling thread; returns pages.

    Writes a single byte per page (stride ``page_bytes``): enough to fault
    the page in — and, with the caller pinned to its NUMA domain, to place
    it there under first-touch — without a full memset of the buffer. The
    written bytes are scratch (the arena is filled by ``preadv`` afterwards).
    """
    page = page_bytes or PAGE_BYTES
    arr = np.frombuffer(buf, dtype=np.uint8) if not isinstance(
        buf, np.ndarray) else buf.view(np.uint8)
    if arr.size == 0:
        return 0
    touch = arr[::page]
    touch[:] = 0
    return int(touch.size)
