"""Queue-depth-managed asynchronous read submission (the cold-cache engine).

The synchronous reader loop (`core/buffers.py` / `ipc/worker.py`) issues one
blocking ``pread`` per splinter: on a warm cache that is a DRAM copy and the
loop is delivery-bound, but on a *cold* cache every splinter pays the full
storage round trip serially — the paper's whole point is that reader tasks
must be tuned to the file system, and a parallel FS (or even one NVMe queue)
wants many requests in flight. This module converts the blocking loop into
depth-managed submission, TASIO-style (Roca Nonell et al., PAPERS.md):

* :class:`IoUringSubmitter` — a ctypes ``io_uring`` ring (Linux 5.1+). SQEs
  carry ``IORING_OP_READ`` straight into the arena views; one
  ``io_uring_enter`` submits a batch and reaps completions. No libaio, no
  third-party package — raw syscalls 425/426.
* :class:`ThreadPoolSubmitter` — the portable fallback: a small worker pool
  draining a submit queue through ``PosixFile.pread_into`` (so the PR-6
  ``RetryPolicy``/fault hooks and the O_DIRECT tail accounting are reused
  verbatim), with ``fadvise(WILLNEED)`` issued at submit time so the kernel
  readahead pipeline runs ahead of the pool.

:func:`make_submitter` picks between them (``mode="auto"|"io_uring"|
"threads"``) and :class:`AsyncReadEngine` wraps either in the drain-loop
shape both reader backends share: keep ``queue_depth`` splinters in flight,
advise a ``readahead_bytes`` window ahead of the submission frontier, hand
completions to the caller as they land. Queue-depth is an *invariant*, not a
hint: the engine never has more than ``depth`` reads outstanding, and
``close()`` drains every outstanding read before returning.

Error/fault semantics match the synchronous path: transient errnos
(``RetryPolicy.errnos``) are retried (counted via ``record_io_retry`` on the
stats sink), fault hooks are consulted at submission with the same
``(offset, nbytes) -> Optional[cap]`` contract, short reads continue from
where they stopped, and EOF completes short. O_DIRECT files submit the
block-aligned body through the ring and finish sub-block tails through the
buffered descriptor — counted, never silent (``record_direct_tail``).
"""
from __future__ import annotations

import ctypes
import ctypes.util
import errno
import mmap
import os
import queue
import struct
import threading
import time
from typing import Callable, List, Optional, Tuple

from .posix import (
    IO_EVENTS,
    DirectIOError,
    PosixFile,
    _buf_addr,
)

# -- io_uring ABI (validated on this kernel: features 0x3ffff) ---------------
_SYS_io_uring_setup = 425
_SYS_io_uring_enter = 426
_IORING_OP_READ = 22
_IORING_ENTER_GETEVENTS = 1
_IORING_OFF_SQ_RING = 0
_IORING_OFF_CQ_RING = 0x8000000
_IORING_OFF_SQES = 0x10000000
_IORING_FEAT_SINGLE_MMAP = 0x1

# struct io_uring_params: 7 config u32 + resv[3] + sq_off (10 u32) +
# cq_off (10 u32) = 120 bytes.
_PARAMS_LEN = 120
# sq_off u32 indices within its block: head,tail,ring_mask,ring_entries,
# flags,dropped,array; cq_off: head,tail,ring_mask,ring_entries,overflow,cqes.
_SQ_OFF_BASE = 40
_CQ_OFF_BASE = 80
# First 40 bytes of an SQE: opcode,flags,ioprio,fd,off,addr,len,rw_flags,
# user_data; the remaining 24 are zero for plain reads.
_SQE_PACK = "<BBHiQQIIQ"
_SQE_SIZE = 64
_CQE_PACK = "<QiI"
_CQE_SIZE = 16

_libc = None


def _get_libc():
    global _libc
    if _libc is None:
        _libc = ctypes.CDLL(ctypes.util.find_library("c") or "libc.so.6",
                            use_errno=True)
    return _libc


_uring_probe: Optional[bool] = None
_uring_probe_lock = threading.Lock()


def io_uring_supported() -> bool:
    """One-shot probe: can this kernel/sandbox set up an io_uring?

    Seccomp policies commonly block the syscall (EPERM/ENOSYS), so the
    probe actually performs a tiny setup and closes it. Cached; the
    ``CKIO_NO_IOURING`` env var forces False (CI determinism)."""
    global _uring_probe
    if os.environ.get("CKIO_NO_IOURING"):
        return False
    with _uring_probe_lock:
        if _uring_probe is None:
            try:
                libc = _get_libc()
                params = ctypes.create_string_buffer(_PARAMS_LEN)
                fd = libc.syscall(_SYS_io_uring_setup, 2,
                                  ctypes.byref(params))
                if fd < 0:
                    _uring_probe = False
                else:
                    os.close(fd)
                    _uring_probe = True
            except Exception:
                _uring_probe = False
        return _uring_probe


class Completion:
    """One finished read: ``token`` is whatever the caller submitted with."""

    __slots__ = ("token", "nbytes", "error", "dt")

    def __init__(self, token, nbytes: int, error: Optional[BaseException],
                 dt: float):
        self.token = token
        self.nbytes = nbytes
        self.error = error
        self.dt = dt


class _SubmitterBase:
    """Shared bookkeeping: inflight count + high-water mark."""

    kind = "base"

    def __init__(self, file, depth: int, *, stats=None, fault=None):
        if depth < 1:
            raise ValueError(f"queue depth must be >= 1, got {depth}")
        self.file = file
        self.depth = int(depth)
        self.stats = stats if stats is not None else IO_EVENTS
        self.fault = fault
        self.max_inflight = 0
        self._inflight = 0
        self._lock = threading.Lock()

    def inflight(self) -> int:
        with self._lock:
            return self._inflight

    def can_submit(self) -> bool:
        with self._lock:
            return self._inflight < self.depth

    def _inc(self) -> None:
        with self._lock:
            # Reject BEFORE counting: a refused submit must not poison the
            # inflight ledger (close(drain=True) would wait on a phantom op).
            if self._inflight + 1 > self.depth:
                raise RuntimeError(
                    f"queue-depth invariant violated: {self._inflight + 1} "
                    f"> {self.depth}")
            self._inflight += 1
            if self._inflight > self.max_inflight:
                self.max_inflight = self._inflight

    def _dec(self, n: int = 1) -> None:
        with self._lock:
            self._inflight -= n

    def submit(self, token, offset: int, view: memoryview) -> None:
        raise NotImplementedError

    def wait(self, timeout: float) -> List[Completion]:
        raise NotImplementedError

    def close(self, drain: bool = True) -> None:
        raise NotImplementedError


class ThreadPoolSubmitter(_SubmitterBase):
    """preadv worker-pool fallback with WILLNEED pipelining.

    ``submit`` advises ``WILLNEED`` on the request range (kernel readahead
    starts fetching while the pool is busy on earlier splinters) and queues
    the read; pool threads run ``file.pread_into`` — which releases the GIL
    per syscall, so ``min(depth, 8)`` threads give real I/O concurrency and
    every retry/fault/direct-tail behaviour of the synchronous path is
    inherited unchanged. Optional ``delay`` (the benchmark cost model) runs
    ON the pool thread, so modeled request latencies overlap exactly like
    real ones."""

    kind = "threads"

    def __init__(self, file, depth: int, *, stats=None, fault=None,
                 delay: Optional[Callable[[object, int], None]] = None):
        super().__init__(file, depth, stats=stats, fault=fault)
        self._delay = delay
        self._work: "queue.Queue" = queue.Queue()
        self._done: "queue.Queue" = queue.Queue()
        self._stop = False
        n = max(1, min(self.depth, 8))
        self._threads = [
            threading.Thread(target=self._worker, name=f"ckio-submit-{i}",
                             daemon=True)
            for i in range(n)
        ]
        for t in self._threads:
            t.start()

    def _worker(self) -> None:
        while True:
            item = self._work.get()
            if item is None:
                return
            token, off, view, t0 = item
            nbytes, err = 0, None
            try:
                if self._delay is not None:
                    self._delay(token, len(view))
                nbytes = self.file.pread_into(
                    off, view, stats=self.stats, fault=self.fault)
            except BaseException as e:     # delivered, not swallowed
                err = e
            self._done.put(Completion(token, nbytes, err,
                                      time.perf_counter() - t0))

    def submit(self, token, offset: int, view: memoryview) -> None:
        self._inc()
        if not getattr(self.file, "direct_io", False):
            try:
                self.file.advise_sequential(offset, len(view),
                                            stats=self.stats)
            except OSError:
                pass                       # advisory only
        self._work.put((token, offset, view, time.perf_counter()))

    def wait(self, timeout: float) -> List[Completion]:
        out: List[Completion] = []
        try:
            out.append(self._done.get(timeout=timeout))
        except queue.Empty:
            return out
        while True:                        # opportunistic batch drain
            try:
                out.append(self._done.get_nowait())
            except queue.Empty:
                break
        self._dec(len(out))
        return out

    def close(self, drain: bool = True) -> None:
        if self._stop:
            return
        if drain:
            deadline = time.monotonic() + 60.0
            while self.inflight() > 0 and time.monotonic() < deadline:
                self.wait(0.05)
        self._stop = True
        for _ in self._threads:
            self._work.put(None)
        for t in self._threads:
            t.join(timeout=10.0)


class _Pending:
    """In-flight io_uring op: tracks continuation + retry state."""

    __slots__ = ("token", "offset", "view", "done", "attempts",
                 "deadline", "t0")

    def __init__(self, token, offset: int, view: memoryview, t0: float):
        self.token = token
        self.offset = offset               # file offset of view[0]
        self.view = view
        self.done = 0                      # bytes completed so far
        self.attempts = 0
        self.deadline: Optional[float] = None
        self.t0 = t0


class IoUringSubmitter(_SubmitterBase):
    """ctypes io_uring: batched async reads straight into arena views.

    Single-threaded by design — ``submit``/``wait`` must be called from one
    thread (each reader owns its own ring, mirroring "each buffer chare
    owns its section"). The kernel only reads the SQ during
    ``io_uring_enter`` (no SQPOLL), so the syscall doubles as the memory
    barrier and plain struct writes into the mapped rings are safe.

    Semantics parity with ``pread_into``: the fault hook is consulted at
    each (re)submission and may cap the length or raise; transient CQE
    errnos are resubmitted under the file's ``RetryPolicy`` budget (counted
    via ``record_io_retry``); short completions resubmit the remainder;
    ``res == 0`` is EOF. For O_DIRECT files the ring carries the
    block-aligned body (on ``direct_fd``) and the sub-block tail finishes
    through the buffered descriptor via ``file.pread_into`` — counted."""

    kind = "io_uring"

    def __init__(self, file, depth: int, *, stats=None, fault=None):
        super().__init__(file, depth, stats=stats, fault=fault)
        if not isinstance(file, PosixFile):
            raise ValueError(
                f"io_uring submitter needs a plain PosixFile (one fd per "
                f"ring); got {type(file).__name__} — use mode='threads'")
        self._direct = file.direct_io and file.direct_fd >= 0
        self._fd = file.direct_fd if self._direct else file.fd
        self._bs = file.block_size
        libc = _get_libc()
        entries = 1
        while entries < depth:
            entries <<= 1
        params = ctypes.create_string_buffer(_PARAMS_LEN)
        ring_fd = libc.syscall(_SYS_io_uring_setup, entries,
                               ctypes.byref(params))
        if ring_fd < 0:
            e = ctypes.get_errno()
            raise OSError(e, f"io_uring_setup failed: {os.strerror(e)}")
        self._ring_fd = ring_fd
        p = struct.unpack("<30I", params.raw)
        sq_entries, cq_entries, features = p[0], p[1], p[5]
        sq = p[_SQ_OFF_BASE // 4: _SQ_OFF_BASE // 4 + 10]
        cq = p[_CQ_OFF_BASE // 4: _CQ_OFF_BASE // 4 + 10]
        self._sq_head_off, self._sq_tail_off = sq[0], sq[1]
        self._sq_mask = None
        self._sq_array_off = sq[6]
        self._cq_head_off, self._cq_tail_off = cq[0], cq[1]
        self._cqes_off = cq[5]
        sq_sz = self._sq_array_off + sq_entries * 4
        cq_sz = self._cqes_off + cq_entries * _CQE_SIZE
        try:
            if features & _IORING_FEAT_SINGLE_MMAP:
                sz = max(sq_sz, cq_sz)
                self._sq_ring = mmap.mmap(ring_fd, sz,
                                          offset=_IORING_OFF_SQ_RING)
                self._cq_ring = self._sq_ring
            else:
                self._sq_ring = mmap.mmap(ring_fd, sq_sz,
                                          offset=_IORING_OFF_SQ_RING)
                self._cq_ring = mmap.mmap(ring_fd, cq_sz,
                                          offset=_IORING_OFF_CQ_RING)
            self._sqes = mmap.mmap(ring_fd, sq_entries * _SQE_SIZE,
                                   offset=_IORING_OFF_SQES)
        except OSError:
            os.close(ring_fd)
            raise
        self._sq_entries = sq_entries
        self._sq_mask = struct.unpack_from(
            "<I", self._sq_ring, sq[2])[0]
        self._cq_mask = struct.unpack_from(
            "<I", self._cq_ring, cq[2])[0]
        self._libc = libc
        self._pending: dict = {}           # id -> _Pending
        self._next_id = 1
        self._retry_q: List[_Pending] = []  # transient failures to resubmit
        self._closed = False

    # -- ring plumbing ----------------------------------------------------
    def _push_sqe(self, op_id: int, fd: int, off: int, addr: int,
                  nbytes: int) -> None:
        tail = struct.unpack_from("<I", self._sq_ring, self._sq_tail_off)[0]
        idx = tail & self._sq_mask
        sqe = struct.pack(_SQE_PACK, _IORING_OP_READ, 0, 0, fd,
                          off, addr, nbytes, 0, op_id)
        self._sqes[idx * _SQE_SIZE: idx * _SQE_SIZE + len(sqe)] = sqe
        self._sqes[idx * _SQE_SIZE + len(sqe):
                   (idx + 1) * _SQE_SIZE] = b"\x00" * (_SQE_SIZE - len(sqe))
        struct.pack_into("<I", self._sq_ring,
                         self._sq_array_off + idx * 4, idx)
        struct.pack_into("<I", self._sq_ring, self._sq_tail_off, tail + 1)

    def _enter(self, to_submit: int, min_complete: int, flags: int) -> int:
        while True:
            r = self._libc.syscall(_SYS_io_uring_enter, self._ring_fd,
                                   to_submit, min_complete, flags, None, 0)
            if r >= 0:
                return r
            e = ctypes.get_errno()
            if e != errno.EINTR:
                raise OSError(e, f"io_uring_enter: {os.strerror(e)}")

    def _issue(self, pend: _Pending) -> Optional[Completion]:
        """Push the next slice of ``pend`` onto the ring (fault hook applied).

        Returns a Completion when the op finishes synchronously instead
        (fault error past retry budget, or an all-tail direct read)."""
        remaining = len(pend.view) - pend.done
        pos = pend.offset + pend.done
        cap = remaining
        if self.fault is not None:
            try:
                c = self.fault(pos, cap)
                if c is not None:
                    cap = max(1, min(cap, int(c)))
            except OSError as e:
                comp = self._op_error(pend, e.errno)
                if comp is not None:
                    return comp
                self._retry_q.append(pend)   # resubmit on next wait()
                return None
        if self._direct:
            if pos % self._bs == 0 and cap >= self._bs:
                cap = (cap // self._bs) * self._bs
            else:
                # Sub-block fragment: finish synchronously through the
                # buffered fd (pread_into counts it via record_direct_tail).
                frag = min(cap, remaining)
                got = self.file.pread_into(
                    pos, pend.view[pend.done: pend.done + frag],
                    stats=self.stats)
                pend.done += got
                if got < frag or pend.done >= len(pend.view):
                    return Completion(pend.token, pend.done, None,
                                      time.perf_counter() - pend.t0)
                return self._issue(pend)
        op_id = self._next_id
        self._next_id += 1
        self._pending[op_id] = pend
        addr = _buf_addr(pend.view) + pend.done
        self._push_sqe(op_id, self._fd, pos, addr, cap)
        self._enter(1, 0, 0)
        return None

    def _op_error(self, pend: _Pending, err: Optional[int]
                  ) -> Optional[Completion]:
        """Retry-budget accounting for one failed slice. None = retry OK."""
        pol = self.file.retry
        if err not in pol.errnos:
            return Completion(
                pend.token, pend.done,
                OSError(err or 0, os.strerror(err or 0)),
                time.perf_counter() - pend.t0)
        if pend.deadline is None:
            pend.deadline = time.monotonic() + pol.deadline_s
        pend.attempts += 1
        if pend.attempts > pol.max_retries or \
                time.monotonic() > pend.deadline:
            return Completion(
                pend.token, pend.done, OSError(err, os.strerror(err)),
                time.perf_counter() - pend.t0)
        self.stats.record_io_retry(err)
        return None

    # -- submitter surface -------------------------------------------------
    def submit(self, token, offset: int, view: memoryview) -> None:
        if self._direct and len(view) > 0:
            if offset % self._bs:
                raise DirectIOError(
                    f"direct async read at offset {offset} is off the "
                    f"{self._bs}-byte block grid of {self.file.path!r}")
            if _buf_addr(view) % self._bs:
                raise DirectIOError(
                    f"direct async read destination is not {self._bs}-byte "
                    f"aligned for {self.file.path!r}")
        self._inc()
        pend = _Pending(token, offset, view, time.perf_counter())
        comp = self._issue(pend)
        if comp is not None:
            self._retry_q.append(comp)     # deliver via next wait()

    def wait(self, timeout: float) -> List[Completion]:
        out: List[Completion] = []
        # Synchronously-finished ops and transient resubmissions first.
        # (_issue may append to _retry_q again — a fault hook raising on the
        # resubmission — so iterate a snapshot.)
        retries, self._retry_q = self._retry_q, []
        for item in retries:
            if isinstance(item, Completion):
                out.append(item)
            else:
                comp = self._issue(item)
                if comp is not None:
                    out.append(comp)
        if self._pending:
            # Reap; block for at least one CQE only when there is nothing
            # to deliver yet (enter returns at once if CQEs are ready).
            block = not out and timeout > 0
            self._enter(0, 1 if block else 0,
                        _IORING_ENTER_GETEVENTS if block else 0)
        head = struct.unpack_from("<I", self._cq_ring, self._cq_head_off)[0]
        tail = struct.unpack_from("<I", self._cq_ring, self._cq_tail_off)[0]
        while head != tail:
            idx = head & self._cq_mask
            user_data, res, _ = struct.unpack_from(
                _CQE_PACK, self._cq_ring, self._cqes_off + idx * _CQE_SIZE)
            head += 1
            pend = self._pending.pop(user_data, None)
            if pend is None:
                continue                   # stale (op already errored out)
            if res < 0:
                comp = self._op_error(pend, -res)
                if comp is not None:
                    out.append(comp)
                else:
                    comp = self._issue(pend)
                    if comp is not None:
                        out.append(comp)
            elif res == 0:                 # EOF — complete short
                out.append(Completion(pend.token, pend.done, None,
                                      time.perf_counter() - pend.t0))
            else:
                pend.done += res
                pend.attempts = 0
                pend.deadline = None
                if pend.done >= len(pend.view):
                    out.append(Completion(pend.token, pend.done, None,
                                          time.perf_counter() - pend.t0))
                else:
                    comp = self._issue(pend)
                    if comp is not None:
                        out.append(comp)
        struct.pack_into("<I", self._cq_ring, self._cq_head_off, head)
        if out:
            self._dec(len(out))
        return out

    def close(self, drain: bool = True) -> None:
        if self._closed:
            return
        if drain:
            deadline = time.monotonic() + 60.0
            while self.inflight() > 0 and time.monotonic() < deadline:
                self.wait(0.05)
        self._closed = True
        try:
            self._sqes.close()
            if self._cq_ring is not self._sq_ring:
                self._cq_ring.close()
            self._sq_ring.close()
        except BufferError:
            pass                           # pending exports; kernel fd close
        os.close(self._ring_fd)


def make_submitter(file, depth: int, *, mode: str = "auto", stats=None,
                   fault=None,
                   delay: Optional[Callable[[object, int], None]] = None
                   ) -> _SubmitterBase:
    """Pick a submission backend.

    ``mode="io_uring"`` demands the ring (raises with the reason when the
    kernel/sandbox or the file type cannot support it); ``"threads"`` forces
    the worker pool; ``"auto"`` uses the ring when supported for a plain
    ``PosixFile`` with no delay model, else the pool. The chosen backend is
    visible to callers as ``.kind`` (recorded into ``SessionMetrics`` as
    ``submit_backend`` — selection is observable, never silent)."""
    if mode not in ("auto", "io_uring", "threads"):
        raise ValueError(f"unknown submit mode {mode!r}")
    ring_ok = (isinstance(file, PosixFile) and delay is None
               and io_uring_supported())
    if mode == "io_uring":
        if not isinstance(file, PosixFile):
            raise ValueError(
                f"submit_mode='io_uring' needs a plain PosixFile, got "
                f"{type(file).__name__} (sharded handles use 'threads')")
        if delay is not None:
            raise ValueError(
                "submit_mode='io_uring' cannot host a delay model "
                "(modeled latencies need pool threads to overlap)")
        if not io_uring_supported():
            raise ValueError(
                "submit_mode='io_uring' but io_uring_setup is unavailable "
                "here (old kernel or seccomp) — use 'auto' or 'threads'")
        return IoUringSubmitter(file, depth, stats=stats, fault=fault)
    if mode == "threads" or not ring_ok:
        return ThreadPoolSubmitter(file, depth, stats=stats, fault=fault,
                                   delay=delay)
    return IoUringSubmitter(file, depth, stats=stats, fault=fault)


class AsyncReadEngine:
    """The depth-managed drain loop both reader backends share.

    ``run(next_item, on_complete, stop)`` pulls ``(token, offset, view)``
    tuples from ``next_item`` (None = source exhausted), keeps up to
    ``depth`` in flight, advises a ``readahead_bytes`` WILLNEED window ahead
    of the submission frontier (buffered files only — O_DIRECT bypasses the
    page cache, where queue depth IS the readahead), and calls
    ``on_complete(token, nbytes, dt)`` as reads land. A completion error is
    raised in the caller's thread after the engine stops submitting, exactly
    like a synchronous pread failure. ``stop()`` returning True drains
    what is in flight and returns early (splinters never marked done twice).
    """

    def __init__(self, file, depth: int, *, readahead_bytes: int = 0,
                 mode: str = "auto", stats=None, fault=None,
                 delay: Optional[Callable[[object, int], None]] = None):
        self.sub = make_submitter(file, depth, mode=mode, stats=stats,
                                  fault=fault, delay=delay)
        self.file = file
        self.readahead_bytes = max(0, int(readahead_bytes))
        self.stats = stats
        self._advised_to = -1

    @property
    def kind(self) -> str:
        return self.sub.kind

    @property
    def max_inflight(self) -> int:
        return self.sub.max_inflight

    def _advise_ahead(self, offset: int, nbytes: int) -> None:
        if self.readahead_bytes <= 0 or getattr(self.file, "direct_io",
                                                False):
            return
        lo = max(offset + nbytes, self._advised_to)
        hi = offset + nbytes + self.readahead_bytes
        size = getattr(self.file, "size", None)
        if size is not None:
            hi = min(hi, size)
        if hi > lo:
            try:
                self.file.advise_sequential(lo, hi - lo, stats=self.stats)
            except OSError:
                pass
            self._advised_to = hi

    def run(self,
            next_item: Callable[[], Optional[Tuple[object, int, memoryview]]],
            on_complete: Callable[[object, int, float], None],
            stop: Optional[Callable[[], bool]] = None,
            poll_s: float = 0.05) -> int:
        """Drain the source; returns the number of completed reads."""
        done = 0
        exhausted = False
        error: Optional[BaseException] = None
        try:
            while True:
                if stop is not None and stop():
                    break
                while not exhausted and error is None \
                        and self.sub.can_submit():
                    item = next_item()
                    if item is None:
                        exhausted = True
                        break
                    token, off, view = item
                    self._advise_ahead(off, len(view))
                    self.sub.submit(token, off, view)
                if self.sub.inflight() == 0:
                    if exhausted or error is not None:
                        break
                for comp in self.sub.wait(poll_s):
                    if comp.error is not None and error is None:
                        error = comp.error
                        continue
                    on_complete(comp.token, comp.nbytes, comp.dt)
                    done += 1
        finally:
            # The main loop only exits with inflight == 0 on clean/error
            # paths; this drain matters on the stop() path, where the
            # still-outstanding reads complete but are deliberately NOT
            # marked done (the session is being cancelled).
            self.sub.close(drain=True)
        if error is not None:
            raise error
        return done
