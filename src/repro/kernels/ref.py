"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,          # (B, H, Sq, hd)
    k: jax.Array,          # (B, K, Sk, hd)
    v: jax.Array,          # (B, K, Sk, hd)
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    """Dense softmax attention, GQA by head-group folding. fp32 accumulate."""
    B, H, Sq, hd = q.shape
    K = k.shape[1]
    G = H // K
    qf = q.reshape(B, K, G, Sq, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf) * (hd ** -0.5)
    Sk = k.shape[2]
    if causal:
        i = jnp.arange(Sq)[:, None] + (Sk - Sq)   # align ends
        j = jnp.arange(Sk)[None, :]
        m = j <= i
        if window > 0:
            m &= (i - j) < window
        s = jnp.where(m[None, None, None], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
    return o.reshape(B, H, Sq, hd).astype(q.dtype)


def ssm_scan_ref(
    Abar: jax.Array,       # (B, S, D, N) fp32
    Bx: jax.Array,         # (B, S, D, N) fp32
    C: jax.Array,          # (B, S, N) fp32
    h0: Optional[jax.Array] = None,
) -> jax.Array:
    """y_t = <h_t, C_t>, h_t = Abar_t * h_{t-1} + Bx_t. Returns (B, S, D)."""
    B, S, D, N = Abar.shape
    if h0 is None:
        h0 = jnp.zeros((B, D, N), jnp.float32)

    def step(h, xs):
        a, b, c = xs
        h = a * h + b
        return h, jnp.einsum("bdn,bn->bd", h, c)

    _, y = jax.lax.scan(
        step, h0,
        (Abar.swapaxes(0, 1), Bx.swapaxes(0, 1), C.swapaxes(0, 1)),
    )
    return y.swapaxes(0, 1)


def lru_scan_ref(
    a: jax.Array,          # (B, S, W) fp32 decay in (0,1)
    b: jax.Array,          # (B, S, W) fp32 input
    h0: Optional[jax.Array] = None,
) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t elementwise. Returns all h (B, S, W)."""
    B, S, W = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)

    def step(h, xs):
        at, bt = xs
        h = at * h + bt
        return h, h

    _, h = jax.lax.scan(step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return h.swapaxes(0, 1)


def reassemble_ref(src: jax.Array, idx: jax.Array) -> jax.Array:
    """Block-gather: src (NB, ...), idx (NBo,) -> out (NBo, ...)."""
    return jnp.take(src, idx, axis=0)


def window_batch_ref(
    linear: jax.Array,         # (L,) file-order tokens
    *,
    global_batch: int,
    seq_len: int,
    window_tok_off: int = 0,
    valid_limit: int | None = None,
    pad_id: int = 0,
):
    """Oracle for ``reassemble_window_pallas``: fused batch-major + shift.

    Pure slice/reshape (no gather) — every split point is static, so XLA
    lowers this to two strided copies; the pad and the tail mask only
    materialize for remainder windows (the full-window hot path does no
    extra device copy of the staged buffer)."""
    B, S = global_batch, seq_len
    S1 = S + 1
    w0 = window_tok_off
    full_limit = w0 + B * S1
    if valid_limit is None:
        valid_limit = full_limit
    L = linear.shape[0]
    if L < full_limit:
        linear = jnp.pad(linear, (0, full_limit - L), constant_values=pad_id)
    seqs = linear[w0:w0 + B * S1].reshape(B, S1)
    inputs = seqs[:, :S]
    labels = seqs[:, 1:]
    if valid_limit < full_limit:
        pad = jnp.asarray(pad_id, dtype=linear.dtype)
        pos = (w0 + jnp.arange(B)[:, None] * S1 + jnp.arange(S)[None, :])
        inputs = jnp.where(pos < valid_limit, inputs, pad)
        labels = jnp.where(pos + 1 < valid_limit, labels, pad)
    return inputs, labels


def tokens_gather_ref(
    staged: jax.Array, row_idx: jax.Array, *, pad_id: int = 0
):
    """Oracle for ``reassemble_tokens_pallas`` (row_idx < 0 pads)."""
    S = row_idx.shape[1] - 1
    safe = jnp.clip(row_idx, 0, staged.shape[0] - 1)
    rows = jnp.take(staged, safe, axis=0)
    pad = jnp.asarray(pad_id, dtype=staged.dtype)
    inputs = jnp.where(row_idx[:, :S] >= 0, rows[:, :S], pad)
    labels = jnp.where(row_idx[:, 1:S + 1] >= 0, rows[:, 1:S + 1], pad)
    return inputs, labels
