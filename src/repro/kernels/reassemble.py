"""Pallas TPU kernels: CkIO phase-2 data permutation, on device.

The paper's second phase permutes reader-striped data to consumer order in
host DRAM. On TPU the right place for that permutation is on-device: the
staged session buffer is DMA'd to HBM **once**, in whatever order the bytes
arrived, and these kernels reassemble batch-major training arrays at HBM
bandwidth. Three kernels cover the ingest pipeline:

``reassemble_pallas``
    Uniform block gather ``out[i] = src[idx[i]]`` over the leading axis
    (``src`` may be 2-D ``(NB, T)`` token blocks or N-D row blocks). The
    splinter->destination map is a scalar-prefetch operand parametrizing the
    *source* BlockSpec index map, so each output block is one aligned
    HBM->VMEM->HBM copy — a pure-bandwidth kernel with no compute, exactly
    the roofline shape of the paper's "data permutation" cost centre (§V-B).
    Used to restore file order from an arrival-ordered staging when splinter
    boundaries are block-uniform.

``reassemble_window_pallas``
    Fused batch-major reassembly of an LM step window: a file-order token
    buffer (at any token offset ``window_tok_off``) becomes ``(inputs,
    labels)`` of shape ``(B, S)`` in one kernel — the label shift-by-one
    rides the same gather, and remainder windows (``valid_limit``) are
    padded with ``pad_id`` on device. Each output row touches at most two
    consecutive ``(S+1)``-token blocks of the source, so the kernel needs no
    dynamic slicing: the split point ``r = window_tok_off % (S+1)`` is
    static per call.

``reassemble_tokens_pallas``
    General token-level gather for staged layouts whose splinter boundaries
    do *not* align to uniform blocks: per output row a precomputed
    ``(B, S+1)`` index row gathers from the full staged buffer (``-1`` =
    pad). The staged buffer is materialized whole per grid step, so this
    path is bounded by VMEM (fine for per-host step windows); the block
    kernels above are preferred whenever the layout permits.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, src_ref, out_ref):
    del idx_ref  # consumed by the index map
    out_ref[...] = src_ref[...]


def reassemble_pallas(
    src: jax.Array,           # (NB, ...) — uniform blocks over axis 0
    idx: jax.Array,           # (NBo,) int32, values in [0, NB)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Block gather ``out[i] = src[idx[i]]`` over the leading axis."""
    if src.ndim < 2:
        raise ValueError(f"src must have >= 2 dims (got shape {src.shape})")
    rest = src.shape[1:]
    NBo = idx.shape[0]
    zeros = (0,) * len(rest)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(NBo,),
        in_specs=[
            pl.BlockSpec((1,) + rest, lambda i, idx_ref: (idx_ref[i],) + zeros),
        ],
        out_specs=pl.BlockSpec((1,) + rest, lambda i, idx_ref: (i,) + zeros),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((NBo,) + rest, src.dtype),
        interpret=interpret,
    )(idx, src)


def reassemble_window_pallas(
    linear: jax.Array,        # (L,) file-order tokens (session coordinates)
    *,
    global_batch: int,
    seq_len: int,
    window_tok_off: int = 0,
    valid_limit: int | None = None,
    pad_id: int = 0,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """File-order token buffer -> batch-major ``(inputs, labels)``, fused.

    Output row ``b`` covers flat positions ``window_tok_off + b*(S+1) + j``;
    ``labels`` are the same gather shifted by one token. Positions at or
    beyond ``valid_limit`` (absolute, in ``linear`` coordinates — remainder
    final windows) read as ``pad_id``. All split points are static, so each
    row is assembled from two consecutive ``(S+1)``-token source blocks with
    no dynamic slicing.
    """
    B, S = global_batch, seq_len
    S1 = S + 1
    q0, r = divmod(window_tok_off, S1)
    full_limit = window_tok_off + B * S1
    if valid_limit is None:
        valid_limit = full_limit
    mask_tail = valid_limit < full_limit

    def masked(i, inp, lab):
        if not mask_tail:
            return inp, lab
        pad = jnp.asarray(pad_id, dtype=inp.dtype)
        base = window_tok_off + i * S1
        pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, S), 1)
        return (jnp.where(pos < valid_limit, inp, pad),
                jnp.where(pos + 1 < valid_limit, lab, pad))

    out = jax.ShapeDtypeStruct((B, S), linear.dtype)
    out_specs = [
        pl.BlockSpec((1, S), lambda b: (b, 0)),
        pl.BlockSpec((1, S), lambda b: (b, 0)),
    ]
    L = linear.shape[0]

    if r == 0:
        # Row-aligned window (the pipeline hot path): each output row is
        # exactly one source block — no second block, and no pad copy
        # unless this is a remainder window.
        need = (q0 + B) * S1
        if L < need:
            linear = jnp.pad(linear, (0, need - L), constant_values=pad_id)
        lin2 = linear[:need].reshape(q0 + B, S1)

        def kern1(a_ref, inp_ref, lab_ref):
            i = pl.program_id(0)
            seg = a_ref[...]                                   # (1, S1)
            inp_ref[...], lab_ref[...] = masked(i, seg[:, :S], seg[:, 1:])

        return pl.pallas_call(
            kern1,
            grid=(B,),
            in_specs=[pl.BlockSpec((1, S1), lambda b: (q0 + b, 0))],
            out_specs=out_specs,
            out_shape=[out, out],
            interpret=interpret,
        )(lin2)

    # Unaligned window: row b spans source blocks q0+b and q0+b+1; pad so
    # the +1 block exists.
    need = (q0 + B + 1) * S1
    if L < need:
        linear = jnp.pad(linear, (0, need - L), constant_values=pad_id)
    lin2 = linear[:need].reshape(q0 + B + 1, S1)

    def kern2(a_ref, b_ref, inp_ref, lab_ref):
        i = pl.program_id(0)
        cat = jnp.concatenate([a_ref[...], b_ref[...]], axis=1)  # (1, 2*S1)
        seg = cat[:, r : r + S1 + 1]                             # (1, S1+1)
        inp_ref[...], lab_ref[...] = masked(i, seg[:, :S], seg[:, 1 : S + 1])

    return pl.pallas_call(
        kern2,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, S1), lambda b: (q0 + b, 0)),
            pl.BlockSpec((1, S1), lambda b: (q0 + b + 1, 0)),
        ],
        out_specs=out_specs,
        out_shape=[out, out],
        interpret=interpret,
    )(lin2, lin2)


def reassemble_tokens_pallas(
    staged: jax.Array,        # (L,) staged tokens, arbitrary layout
    row_idx: jax.Array,       # (B, S+1) int32 staged positions; -1 = pad
    *,
    pad_id: int = 0,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Token-level gather: row ``b`` of the window is ``staged[row_idx[b]]``.

    ``row_idx[b, j]`` is the staged position of window flat token
    ``b*(S+1)+j`` (``j`` in ``[0, S+1)`` — the last column only feeds the
    label shift); negative entries pad. The whole staged buffer is resident
    per grid step, so sizing is VMEM-bounded — use the block kernels when
    the staged layout is block-uniform.
    """
    B, S2 = row_idx.shape
    S = S2 - 1
    L = staged.shape[0]

    def kern(idx_ref, st_ref, inp_ref, lab_ref):
        idx = idx_ref[...]                                     # (1, S+1)
        safe = jnp.clip(idx, 0, L - 1)
        row = jnp.take(st_ref[...], safe[0], axis=0)[None, :]  # (1, S+1)
        pad = jnp.asarray(pad_id, dtype=row.dtype)
        inp_ref[...] = jnp.where(idx[:, :S] >= 0, row[:, :S], pad)
        lab_ref[...] = jnp.where(idx[:, 1 : S + 1] >= 0, row[:, 1 : S + 1], pad)

    out = jax.ShapeDtypeStruct((B, S), staged.dtype)
    return pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, S2), lambda b: (b, 0)),
            pl.BlockSpec((L,), lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, S), lambda b: (b, 0)),
            pl.BlockSpec((1, S), lambda b: (b, 0)),
        ],
        out_shape=[out, out],
        interpret=interpret,
    )(row_idx, staged)
