"""Pallas TPU block-gather: CkIO phase-2 data permutation, on device.

The paper's second phase permutes reader-striped data to consumer order in
host DRAM. On TPU the right place for that permutation is on-device: the
striped session buffer is DMA'd to HBM in arrival order, and this kernel
gathers splinter-sized row blocks into batch-major order at HBM bandwidth.

The splinter->destination map is a scalar-prefetch operand: it parametrizes
the *source* BlockSpec index map, so each output block is produced by one
aligned HBM->VMEM->HBM copy of its source block — a pure-bandwidth kernel
with no compute, which is exactly the roofline shape of the paper's
"data permutation" cost centre (§V-B).

src (NB, rows, d), idx (NBo,) int32, out (NBo, rows, d): out[i] = src[idx[i]].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, src_ref, out_ref):
    del idx_ref  # consumed by the index map
    out_ref[...] = src_ref[...]


def reassemble_pallas(
    src: jax.Array,           # (NB, rows, d)
    idx: jax.Array,           # (NBo,) int32, values in [0, NB)
    *,
    interpret: bool = False,
) -> jax.Array:
    NB, rows, d = src.shape
    NBo = idx.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(NBo,),
        in_specs=[
            pl.BlockSpec((1, rows, d), lambda i, idx_ref: (idx_ref[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, rows, d), lambda i, idx_ref: (i, 0, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((NBo, rows, d), src.dtype),
        interpret=interpret,
    )(idx, src)
