"""Pallas TPU kernels for the compute/bandwidth hot spots.

flash_attention — causal/sliding/GQA online-softmax tiling (8/10 archs)
mamba_scan      — chunked selective scan, carry in VMEM (falcon-mamba)
rglru_scan      — chunked gated linear recurrence (recurrentgemma)
reassemble      — CkIO phase-2 block-gather permutation at HBM bandwidth

Each has a pure-jnp oracle in ``ref.py`` and a jit'd wrapper in ``ops.py``
(TPU: native Pallas; CPU: interpret mode or the reference path).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
