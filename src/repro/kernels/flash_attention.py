"""Pallas TPU flash attention (causal / sliding-window / GQA).

Online-softmax tiling: grid (B, H, nq, nk) with the K axis innermost
("arbitrary" = sequential on TPU), running max/denominator/accumulator live
in VMEM scratch across the K sweep. Block sizes are MXU-aligned (multiples
of 128 in production shapes; smaller in tests). GQA folds q-head groups onto
their kv head through the k/v index maps — kv blocks are fetched once per
group, not per q head.

Layouts: q (B, H, Sq, hd), k/v (B, K, Sk, hd) — ``ops.flash_attention``
handles the (B, S, H, hd) <-> (B, H, S, hd) transposes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _fa_kernel(
    q_ref, k_ref, v_ref,           # blocks: (1,1,bq,hd), (1,1,bk,hd)
    o_ref,                          # (1,1,bq,hd)
    m_scr, l_scr, acc_scr,          # VMEM scratch: (bq,1), (bq,1), (bq,hd)
    *,
    bq: int,
    bk: int,
    nk: int,
    scale: float,
    causal: bool,
    window: int,
    sq: int,
    sk: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                       # (bq, bk)

    # absolute positions (query ends aligned with key ends for decode-style)
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (sk - sq)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                             # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows: keep exp argument finite
    p = jnp.exp(s - jnp.where(m_new <= NEG_INF / 2, 0.0, m_new))
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(
        jnp.where(m_prev <= NEG_INF / 2, NEG_INF, m_prev - m_new)
    )
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,                  # (B, H, Sq, hd)
    k: jax.Array,                  # (B, K, Sk, hd)
    v: jax.Array,                  # (B, K, Sk, hd)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk

    kernel = functools.partial(
        _fa_kernel, bq=bq, bk=bk, nk=nk, scale=hd ** -0.5,
        causal=causal, window=window, sq=Sq, sk=Sk,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denominator
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
