"""Jit'd public wrappers for the Pallas kernels.

On a TPU backend the Pallas path compiles natively; everywhere else (this
CPU container, the dry-run's host platform) ``interpret=True`` executes the
kernel body for correctness, or the pure-jnp reference is used directly via
``use_pallas=False`` (the default on CPU for speed — interpret mode runs the
grid in Python). The models call the reference path; kernel tests sweep the
Pallas path against the oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.reassemble import (
    reassemble_pallas,
    reassemble_tokens_pallas,
    reassemble_window_pallas,
)
from repro.kernels.rglru_scan import rglru_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "use_pallas")
)
def flash_attention(
    q: jax.Array,              # (B, S, H, hd)
    k: jax.Array,              # (B, S, K, hd)
    v: jax.Array,              # (B, S, K, hd)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: bool | None = None,
) -> jax.Array:
    """Returns (B, S, H, hd)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    if use:
        out = flash_attention_bhsd(
            qt, kt, vt, causal=causal, window=window,
            block_q=block_q, block_k=block_k, interpret=not _on_tpu(),
        )
    else:
        out = ref.attention_ref(qt, kt, vt, causal=causal, window=window)
    return out.swapaxes(1, 2)


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "use_pallas"))
def mamba_scan(
    Abar: jax.Array, Bx: jax.Array, C: jax.Array,
    *, chunk: int = 128, block_d: int = 256, use_pallas: bool | None = None,
) -> jax.Array:
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return mamba_scan_pallas(
            Abar, Bx, C, chunk=chunk, block_d=block_d, interpret=not _on_tpu()
        )
    return ref.ssm_scan_ref(Abar, Bx, C)


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "use_pallas"))
def rglru_scan(
    a: jax.Array, b: jax.Array,
    *, chunk: int = 256, block_w: int = 512, use_pallas: bool | None = None,
) -> jax.Array:
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return rglru_scan_pallas(
            a, b, chunk=chunk, block_w=block_w, interpret=not _on_tpu()
        )
    return ref.lru_scan_ref(a, b)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def reassemble(
    src: jax.Array, idx: jax.Array, *, use_pallas: bool | None = None
) -> jax.Array:
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return reassemble_pallas(src, idx, interpret=not _on_tpu())
    return ref.reassemble_ref(src, idx)


@functools.partial(
    jax.jit,
    static_argnames=("global_batch", "seq_len", "window_tok_off",
                     "valid_limit", "pad_id", "use_pallas"),
)
def reassemble_window(
    linear: jax.Array,
    *,
    global_batch: int,
    seq_len: int,
    window_tok_off: int = 0,
    valid_limit: int | None = None,
    pad_id: int = 0,
    use_pallas: bool | None = None,
):
    """File-order token buffer -> batch-major (inputs, labels) on device."""
    use = _on_tpu() if use_pallas is None else use_pallas
    kw = dict(global_batch=global_batch, seq_len=seq_len,
              window_tok_off=window_tok_off, valid_limit=valid_limit,
              pad_id=pad_id)
    if use:
        return reassemble_window_pallas(linear, interpret=not _on_tpu(), **kw)
    return ref.window_batch_ref(linear, **kw)


@functools.partial(jax.jit, static_argnames=("pad_id", "use_pallas"))
def reassemble_tokens(
    staged: jax.Array, row_idx: jax.Array, *, pad_id: int = 0,
    use_pallas: bool | None = None,
):
    """Token-level gather fallback (non-block-uniform staged layouts)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return reassemble_tokens_pallas(staged, row_idx, pad_id=pad_id,
                                        interpret=not _on_tpu())
    return ref.tokens_gather_ref(staged, row_idx, pad_id=pad_id)


def staged_concat(chunks):
    """Concatenate streamed staging chunks into one device-resident buffer.

    ``chunks`` are the per-``device_put`` token arrays the streaming stager
    shipped in arrival order; their concatenation *is* the arrival-ordered
    staged layout the gather index maps describe. Runs on device (XLA
    concatenate) — no token byte returns to the host.
    """
    if not chunks:
        raise ValueError("staged_concat: no chunks")
    if len(chunks) == 1:
        return chunks[0]
    return jnp.concatenate(chunks)


# -- streamed-chunk ingest (single fused dispatch per step) -------------------
#
# The streaming pipeline holds the step as a *list* of arrival-order chunk
# arrays (one per splinter). Concatenating, unpermuting, and window-gathering
# as separate eager ops would cost three executable dispatches and two
# materialized window-size intermediates per step; these entry points fuse
# the whole consume tail into one jit call (XLA folds the concatenate into
# the gather), keyed on the chunk-count/shape signature — stable across
# steps for a uniform-splinter plan, whatever the arrival permutation.

@functools.partial(
    jax.jit,
    static_argnames=("global_batch", "seq_len", "window_tok_off",
                     "valid_limit", "pad_id", "use_pallas"),
)
def ingest_chunks_window(
    chunks,
    *,
    global_batch: int,
    seq_len: int,
    window_tok_off: int = 0,
    valid_limit: int | None = None,
    pad_id: int = 0,
    use_pallas: bool | None = None,
):
    """File-order chunk list -> (inputs, labels): fused concat + window."""
    return reassemble_window(
        staged_concat(list(chunks)), global_batch=global_batch,
        seq_len=seq_len, window_tok_off=window_tok_off,
        valid_limit=valid_limit, pad_id=pad_id, use_pallas=use_pallas)


@functools.partial(
    jax.jit,
    static_argnames=("global_batch", "seq_len", "window_tok_off",
                     "valid_limit", "pad_id", "use_pallas"),
)
def ingest_chunks_block(
    chunks,
    perm: jax.Array,              # (NB,) file-order block -> staged block
    *,
    global_batch: int,
    seq_len: int,
    window_tok_off: int = 0,
    valid_limit: int | None = None,
    pad_id: int = 0,
    use_pallas: bool | None = None,
):
    """Uniform-block arrival-order chunks -> (inputs, labels), one dispatch:
    concat + block unpermute + fused window reassembly."""
    staged = staged_concat(list(chunks))
    nb = perm.shape[0]
    T = staged.shape[0] // nb
    linear = reassemble(
        staged[: nb * T].reshape(nb, T), perm, use_pallas=use_pallas
    ).reshape(-1)
    return reassemble_window(
        linear, global_batch=global_batch, seq_len=seq_len,
        window_tok_off=window_tok_off, valid_limit=valid_limit,
        pad_id=pad_id, use_pallas=use_pallas)


@functools.partial(jax.jit, static_argnames=("pad_id", "use_pallas"))
def ingest_chunks_tokens(
    chunks,
    row_idx: jax.Array,
    *,
    pad_id: int = 0,
    use_pallas: bool | None = None,
):
    """Non-uniform arrival-order chunks -> (inputs, labels) via the
    token-level gather, fused with the concat."""
    return reassemble_tokens(
        staged_concat(list(chunks)), row_idx, pad_id=pad_id,
        use_pallas=use_pallas)


def device_ingest(
    staged: jax.Array,            # (L,) staged tokens on device
    gather=None,                  # np.ndarray token map or None (file order)
    *,
    global_batch: int,
    seq_len: int,
    window_tok_off: int = 0,
    valid_tokens: int | None = None,
    pad_id: int = 0,
    block_tokens: int = 0,
    use_pallas: bool | None = None,
):
    """One-transfer device reassembly: staged tokens -> (inputs, labels).

    ``gather`` (host NumPy, from ``data.packing.token_gather_from_pieces``)
    describes the staged layout: ``None`` means file order (the pipeline's
    whole-window arena view), otherwise it is the arrival-order→file-order
    token map. Layout dispatch happens on host metadata only:

    * file order        -> fused window kernel directly;
    * block permutation -> block-gather unpermute, then window kernel;
    * anything else     -> token-level gather kernel.
    """
    S1 = seq_len + 1
    if valid_tokens is None:
        valid_tokens = global_batch * S1
    valid_limit = window_tok_off + valid_tokens
    if gather is None:
        return reassemble_window(
            staged, global_batch=global_batch, seq_len=seq_len,
            window_tok_off=window_tok_off, valid_limit=valid_limit,
            pad_id=pad_id, use_pallas=use_pallas)

    from repro.data.packing import as_block_permutation, row_gather_index

    perm = (as_block_permutation(gather, block_tokens)
            if block_tokens else None)
    if perm is not None:
        T = block_tokens
        blocks = staged[: perm.shape[0] * T].reshape(perm.shape[0], T)
        linear = reassemble(
            blocks, jnp.asarray(perm), use_pallas=use_pallas
        ).reshape(-1)
        return reassemble_window(
            linear, global_batch=global_batch, seq_len=seq_len,
            window_tok_off=window_tok_off, valid_limit=valid_limit,
            pad_id=pad_id, use_pallas=use_pallas)
    row_idx = row_gather_index(
        gather, global_batch=global_batch, seq_len=seq_len,
        window_tok_off=window_tok_off, valid_tokens=valid_tokens)
    return reassemble_tokens(staged, jnp.asarray(row_idx), pad_id=pad_id,
                             use_pallas=use_pallas)
