"""Jit'd public wrappers for the Pallas kernels.

On a TPU backend the Pallas path compiles natively; everywhere else (this
CPU container, the dry-run's host platform) ``interpret=True`` executes the
kernel body for correctness, or the pure-jnp reference is used directly via
``use_pallas=False`` (the default on CPU for speed — interpret mode runs the
grid in Python). The models call the reference path; kernel tests sweep the
Pallas path against the oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.reassemble import reassemble_pallas
from repro.kernels.rglru_scan import rglru_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "use_pallas")
)
def flash_attention(
    q: jax.Array,              # (B, S, H, hd)
    k: jax.Array,              # (B, S, K, hd)
    v: jax.Array,              # (B, S, K, hd)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: bool | None = None,
) -> jax.Array:
    """Returns (B, S, H, hd)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    if use:
        out = flash_attention_bhsd(
            qt, kt, vt, causal=causal, window=window,
            block_q=block_q, block_k=block_k, interpret=not _on_tpu(),
        )
    else:
        out = ref.attention_ref(qt, kt, vt, causal=causal, window=window)
    return out.swapaxes(1, 2)


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "use_pallas"))
def mamba_scan(
    Abar: jax.Array, Bx: jax.Array, C: jax.Array,
    *, chunk: int = 128, block_d: int = 256, use_pallas: bool | None = None,
) -> jax.Array:
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return mamba_scan_pallas(
            Abar, Bx, C, chunk=chunk, block_d=block_d, interpret=not _on_tpu()
        )
    return ref.ssm_scan_ref(Abar, Bx, C)


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "use_pallas"))
def rglru_scan(
    a: jax.Array, b: jax.Array,
    *, chunk: int = 256, block_w: int = 512, use_pallas: bool | None = None,
) -> jax.Array:
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return rglru_scan_pallas(
            a, b, chunk=chunk, block_w=block_w, interpret=not _on_tpu()
        )
    return ref.lru_scan_ref(a, b)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def reassemble(
    src: jax.Array, idx: jax.Array, *, use_pallas: bool | None = None
) -> jax.Array:
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return reassemble_pallas(src, idx, interpret=not _on_tpu())
    return ref.reassemble_ref(src, idx)
