"""Pallas TPU chunked gated-linear-recurrence (RG-LRU / Griffin).

h_t = a_t ⊙ h_{t-1} + b_t, elementwise over the channel dim. Same carry-in-
VMEM structure as ``mamba_scan``: channel tiles are the parallel grid dim,
sequence chunks sweep sequentially with the (bw,) state held in scratch.
Emits every h_t (the Griffin block consumes the full recurrent trace).

Grid: (B, nw, nc); blocks a/b/h: (1, Q, bw).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lru_kernel(a_ref, b_ref, h_ref, h_scr, *, q: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _reset():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)          # (Q, bw)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        h_ref[0, t, :] = h.astype(h_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, q, step, h_scr[...])


def rglru_scan_pallas(
    a: jax.Array,             # (B, S, W) fp32
    b: jax.Array,             # (B, S, W) fp32
    *,
    chunk: int = 256,
    block_w: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, S, W = a.shape
    q = min(chunk, S)
    bw = min(block_w, W)
    assert S % q == 0 and W % bw == 0, (S, q, W, bw)
    nc, nw = S // q, W // bw

    kernel = functools.partial(_lru_kernel, q=q)
    return pl.pallas_call(
        kernel,
        grid=(B, nw, nc),
        in_specs=[
            pl.BlockSpec((1, q, bw), lambda b_, w, c: (b_, c, w)),
            pl.BlockSpec((1, q, bw), lambda b_, w, c: (b_, c, w)),
        ],
        out_specs=pl.BlockSpec((1, q, bw), lambda b_, w, c: (b_, c, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(a, b)
