"""Pallas TPU chunked selective-scan (Mamba-1 recurrence + output readout).

Computes  h_t = Abar_t ⊙ h_{t-1} + Bx_t  (diagonal per (d, n) state) and
y_t = Σ_n h_t[d, n] · C_t[n]   over the sequence.

TPU adaptation (vs the paper's CUDA warp-parallel scan): the state carry
lives in VMEM scratch and the sequence is swept in chunks by the innermost
("arbitrary" = sequential) grid dimension, so HBM traffic is one pass over
(Abar, Bx, C) and one write of y — the recurrence never round-trips through
HBM. The channel dimension is tiled (parallel grid dim) to bound the VMEM
working set: per step the kernel holds (Q, bd, n) blocks + an (bd, n) carry.

Grid: (B, nd, nc); blocks: Abar/Bx (1, Q, bd, n), C (1, Q, n), y (1, Q, bd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(a_ref, b_ref, c_ref, y_ref, h_scr, *, q: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _reset():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)          # (Q, bd, n)
    b = b_ref[0].astype(jnp.float32)
    c = c_ref[0].astype(jnp.float32)          # (Q, n)

    def step(t, h):
        h = a[t] * h + b[t]                   # (bd, n)
        y_ref[0, t, :] = jnp.sum(h * c[t][None, :], axis=1).astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, q, step, h_scr[...])


def mamba_scan_pallas(
    Abar: jax.Array,          # (B, S, D, N) fp32
    Bx: jax.Array,            # (B, S, D, N) fp32
    C: jax.Array,             # (B, S, N)    fp32
    *,
    chunk: int = 128,
    block_d: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, S, D, N = Abar.shape
    q = min(chunk, S)
    bd = min(block_d, D)
    assert S % q == 0 and D % bd == 0, (S, q, D, bd)
    nc, nd = S // q, D // bd

    kernel = functools.partial(_scan_kernel, q=q)
    return pl.pallas_call(
        kernel,
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, q, bd, N), lambda b, d, c: (b, c, d, 0)),
            pl.BlockSpec((1, q, bd, N), lambda b, d, c: (b, c, d, 0)),
            pl.BlockSpec((1, q, N), lambda b, d, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, bd), lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(Abar, Bx, C)
