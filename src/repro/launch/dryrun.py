import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch × shape) on the production
# meshes, extract roofline inputs (FLOPs, bytes, per-device collective bytes,
# memory analysis), persist JSONL.
#
# The two lines above MUST run before any jax import — jax locks the device
# count at first init. Everything else (smoke tests, benches) sees 1 device.
#
# Usage:
#   python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k --mesh multipod
#   python -m repro.launch.dryrun --all --mesh both --out benchmarks/results/dryrun.jsonl

import argparse
import json
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES_BY_NAME, ShapeConfig
from repro.configs.registry import LONG_CONTEXT_ARCHS, cells, get_config
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes
from repro.launch import sharding as shd
from repro.models import build_model
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"\b(pred|[a-z]?f8e4m3fn|f8e5m2|bf16|f16|f32|f64|s8|s16|s32|s64|u8|u16|u32|u64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes of every collective op in a (post-SPMD, per-device)
    HLO module, keyed by op kind. Result bytes ~ payload per device; ring
    algorithms move up to 2x this per all-reduce — a modeling choice noted in
    EXPERIMENTS.md §Roofline."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if "= " not in s:
            continue
        for op in _COLLECTIVES:
            marker = f" {op}("
            # exclude -start/-done duplicates (count the -start only)
            if f" {op}-done(" in s:
                continue
            if marker in s or f" {op}-start(" in s:
                lhs = s.split(marker)[0] if marker in s else s.split(f" {op}-start(")[0]
                # result type(s) appear after '=' on the lhs
                rhs_types = lhs.split("= ", 1)[-1]
                out[op] += _shape_bytes(rhs_types)
                out["count"] += 1
                break
    return out


def _batch_abstract(model, shape: ShapeConfig, mesh):
    specs = model.input_specs(shape)
    p = shd.batch_specs(specs, mesh)
    named = shd.to_named(p, mesh)
    return jax.tree.map(
        lambda sds, s: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=s),
        specs, named,
    )


def _with_sharding(abstract: Any, spec_tree: Any, mesh) -> Any:
    named = shd.to_named(spec_tree, mesh)
    return jax.tree.map(
        lambda sds, s: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=s),
        abstract, named,
    )


def pick_num_microbatches(shape: ShapeConfig, mesh, requested: Optional[int]) -> int:
    if shape.kind != "train":
        return 1
    if requested:
        return requested
    sizes = mesh_axis_sizes(mesh)
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    return max(1, min(16, shape.global_batch // dp))


def lower_cell(
    arch: str,
    shape: ShapeConfig,
    mesh,
    *,
    num_microbatches: Optional[int] = None,
    remat: Optional[str] = None,
    accum_dtype: str = "float32",
    compression: Optional[str] = None,
    param_dtype: Optional[str] = None,
    master_weights: bool = False,
    unroll: bool = False,
    num_layers_override: Optional[int] = None,
    overrides: Optional[Dict[str, Any]] = None,
    extra_tag: str = "",
):
    cfg = get_config(arch)
    if remat:
        cfg = cfg.replace(remat_policy=remat)
    if param_dtype:
        cfg = cfg.replace(param_dtype=param_dtype)
    if overrides:
        cfg = cfg.replace(**overrides)
    if unroll:
        # exact-cost analysis pass: scan bodies are counted once by XLA's
        # cost analysis, so unroll layers and skip microbatching (flop and
        # collective totals are microbatch-invariant; memory comes from the
        # scanned pass)
        cfg = cfg.replace(scan_layers=False)
        num_microbatches = 1
    if num_layers_override:
        cfg = cfg.replace(num_layers=num_layers_override)
    model = build_model(cfg)
    p_abs = model.abstract_params()
    p_specs = shd.param_specs(p_abs, mesh)
    p_in = _with_sharding(p_abs, p_specs, mesh)
    batch_in = _batch_abstract(model, shape, mesh)

    # jax >= 0.5 exposes jax.sharding.set_mesh; earlier versions enter the
    # mesh context via the Mesh object itself.
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    with (set_mesh(mesh) if set_mesh is not None else mesh):
        if shape.kind == "train":
            nmb = pick_num_microbatches(shape, mesh, num_microbatches)
            opt_abs = jax.eval_shape(
                lambda p: init_opt_state(p, master_weights=master_weights), p_abs
            )
            o_specs = shd.opt_state_specs(p_abs, p_specs, mesh,
                                          master_weights=master_weights)
            o_in = _with_sharding(opt_abs, o_specs, mesh)
            step = make_train_step(
                model, OptConfig(), num_microbatches=nmb,
                accum_dtype=jnp.dtype(accum_dtype), compression=compression,
            )
            jitted = jax.jit(
                step,
                in_shardings=(shd.to_named(p_specs, mesh),
                              shd.to_named(o_specs, mesh),
                              None),
                out_shardings=(shd.to_named(p_specs, mesh),
                               shd.to_named(o_specs, mesh),
                               None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(p_in, o_in, batch_in)
        elif shape.kind == "prefill":
            jitted = jax.jit(
                lambda p, b: model.prefill_logits(p, b),
                in_shardings=(shd.to_named(p_specs, mesh), None),
                out_shardings=shd.to_named(
                    shd.logits_spec(mesh, shape.global_batch, cfg.vocab_size), mesh),
            )
            lowered = jitted.lower(p_in, batch_in)
        else:  # decode
            st_abs = model.decode_state_specs(shape)
            st_specs = shd.decode_state_specs(st_abs, mesh, cfg)
            st_in = _with_sharding(st_abs, st_specs, mesh)
            jitted = jax.jit(
                lambda p, s, b: model.decode(p, s, b),
                in_shardings=(shd.to_named(p_specs, mesh),
                              shd.to_named(st_specs, mesh),
                              None),
                out_shardings=(shd.to_named(
                                   shd.logits_spec(mesh, shape.global_batch,
                                                   cfg.vocab_size),
                                   mesh),
                               shd.to_named(st_specs, mesh)),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(p_in, st_in, batch_in)
    return cfg, lowered


def _lower_and_measure(arch, shape, mesh, *, compile_: bool, **kw) -> Dict[str, Any]:
    t0 = time.time()
    cfg, lowered = lower_cell(arch, shape, mesh, **kw)
    out: Dict[str, Any] = {"t_lower_s": round(time.time() - t0, 2)}
    try:
        ca = lowered.cost_analysis() or {}
        out["hlo_flops"] = float(ca.get("flops", -1.0))
        out["hlo_bytes"] = float(ca.get("bytes accessed", -1.0))
    except Exception as e:  # pragma: no cover
        out["cost_analysis_error"] = repr(e)
    if compile_:
        t0 = time.time()
        compiled = lowered.compile()
        out["t_compile_s"] = round(time.time() - t0, 2)
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                for attr in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                ):
                    v = getattr(ma, attr, None)
                    if v is not None:
                        out[attr] = int(v)
        except Exception as e:  # pragma: no cover
            out["memory_analysis_error"] = repr(e)
        try:
            cca = compiled.cost_analysis() or {}
            # post-fusion, per-device program (SPMD module)
            if "flops" in cca:
                out["compiled_flops"] = float(cca["flops"])
            if "bytes accessed" in cca:
                out["compiled_bytes"] = float(cca["bytes accessed"])
        except Exception:
            pass
        text = compiled.as_text()
        out["hlo_text_bytes"] = len(text)
        out["collectives"] = collective_bytes_from_hlo(text)
    return out


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    *,
    compile_: bool = True,
    analyze: bool = True,
    **lower_kw,
) -> Dict[str, Any]:
    """Three-pass cell analysis.

    A) exact global FLOPs/bytes: unrolled full model, lower only (XLA cost
       analysis counts scan bodies once, so scans must be unrolled; compile
       not needed for HLO-level cost analysis).
    B) per-device collective bytes: unrolled *reduced-depth* compiles at
       nb=2 and nb=4 blocks, extrapolated linearly to the full depth —
       exact because every block is structurally identical and optimizer/
       gradient collectives are linear in block count too.
    C) memory + compile-success proof: the production configuration
       (scanned, microbatched) compiled at full depth.
    """
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(mesh.devices.size)
    cfg = get_config(arch)
    pat = len(cfg.block_pattern)
    tail = cfg.num_layers % pat
    nb_full = cfg.num_layers // pat

    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "tag": lower_kw.pop("extra_tag", ""),
    }

    # -- pass C: production compile (memory + proof) --------------------------
    prod = _lower_and_measure(arch, shape, mesh, compile_=compile_, **lower_kw)
    for k in ("t_lower_s", "t_compile_s", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "generated_code_size_in_bytes", "memory_analysis_error"):
        if k in prod:
            rec[k] = prod[k]
    rec["scanned_collectives"] = prod.get("collectives")

    if analyze:
        # -- pass A: exact flops/bytes --------------------------------------
        ex = _lower_and_measure(
            arch, shape, mesh, compile_=False, unroll=True, **lower_kw
        )
        rec["hlo_flops"] = ex.get("hlo_flops")
        rec["hlo_bytes"] = ex.get("hlo_bytes")
        rec["t_lower_unrolled_s"] = ex.get("t_lower_s")

        # -- pass B: collective + post-fusion byte extrapolation ----------------
        if compile_ and nb_full > 4:
            m2 = _lower_and_measure(
                arch, shape, mesh, compile_=True, unroll=True,
                num_layers_override=2 * pat + tail, **lower_kw
            )
            m4 = _lower_and_measure(
                arch, shape, mesh, compile_=True, unroll=True,
                num_layers_override=4 * pat + tail, **lower_kw
            )
            c2, c4 = m2["collectives"], m4["collectives"]
            coll = {}
            for k in c4:
                slope = (c4[k] - c2[k]) / 2.0
                coll[k] = int(c4[k] + slope * (nb_full - 4))
            rec["collectives"] = coll
            rec["collectives_method"] = "extrapolated(nb=2,4)"
            for key, name in (("compiled_bytes", "device_bytes"),
                              ("compiled_flops", "device_flops")):
                if key in m2 and key in m4:
                    slope = (m4[key] - m2[key]) / 2.0
                    rec[name] = float(m4[key] + slope * (nb_full - 4))
        elif compile_:
            full = _lower_and_measure(
                arch, shape, mesh, compile_=True, unroll=True, **lower_kw
            )
            rec["collectives"] = full["collectives"]
            rec["collectives_method"] = "exact(unrolled)"
            if "compiled_bytes" in full:
                rec["device_bytes"] = full["compiled_bytes"]
            if "compiled_flops" in full:
                rec["device_flops"] = full["compiled_flops"]
    else:
        rec["hlo_flops"] = prod.get("hlo_flops")
        rec["hlo_bytes"] = prod.get("hlo_bytes")
        rec["collectives"] = prod.get("collectives")
        rec["collectives_method"] = "scanned(undercounted)"

    pc = cfg.param_counts()
    rec["params_total"] = pc["total"]
    rec["params_active"] = pc["active"]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    factor = 6 if shape.kind == "train" else 2
    rec["model_flops"] = factor * pc["active"] * tokens
    rec["tokens_per_step"] = tokens
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--accum-dtype", default="float32")
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--master-weights", action="store_true")
    ap.add_argument("--compression", default=None)
    ap.add_argument("--no-analyze", action="store_true",
                    help="skip exact-flop + collective-extrapolation passes")
    ap.add_argument("--override", action="append", default=[],
                    help="ModelConfig field override, e.g. xent_mode=onehot")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="benchmarks/results/dryrun.jsonl")
    args = ap.parse_args()

    if args.all:
        todo = list(cells())
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, SHAPES_BY_NAME[args.shape])]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            import ast
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    for arch, shape in todo:
        for mp in meshes:
            print(f"=== {arch} × {shape.name} × {'2x16x16' if mp else '16x16'} ===",
                  flush=True)
            try:
                rec = run_cell(
                    arch, shape.name, mp,
                    compile_=not args.no_compile,
                    analyze=not args.no_analyze,
                    num_microbatches=args.microbatches,
                    remat=args.remat,
                    accum_dtype=args.accum_dtype,
                    param_dtype=args.param_dtype,
                    master_weights=args.master_weights,
                    compression=args.compression,
                    overrides=overrides,
                    extra_tag=args.tag,
                )
            except Exception as e:
                rec = {
                    "arch": arch, "shape": shape.name,
                    "mesh": "2x16x16" if mp else "16x16",
                    "error": repr(e)[:500], "tag": args.tag,
                }
                print(f"  FAILED: {rec['error']}", flush=True)
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
            if "error" not in rec:
                coll = rec.get("collectives") or {}
                csum = sum(v for k, v in coll.items() if k != "count")
                print(
                    f"  ok: lower {rec.get('t_lower_s')}s compile "
                    f"{rec.get('t_compile_s', '-')}s "
                    f"flops={rec.get('hlo_flops') or -1:.3e} coll={csum:.3e}B",
                    flush=True,
                )


if __name__ == "__main__":
    main()
