"""End-to-end training driver: CkIO input pipeline + supervised train loop.

This is the "ChaNGa integration" path run for real (CPU-sized): synthetic
corpus -> CkIO read sessions -> double-buffered batches -> jitted microbatched
train step -> async checkpoints -> fault-tolerant supervisor. On a pod, the
same driver runs with the production mesh (per-host pipelines feeding
device_put with NamedSharding).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-moe-a2.7b \
      --smoke --steps 50 --global-batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, smoke_config
from repro.core import CkIO, FileOptions, Topology
from repro.data import CkIOPipeline, make_token_file
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.train import (
    AsyncCheckpointer,
    OptConfig,
    StepSupervisor,
    init_opt_state,
    make_train_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--num-readers", type=int, default=4)
    ap.add_argument("--num-consumers", type=int, default=16)
    ap.add_argument("--data", nargs="+",
                    default=["/tmp/repro_train_tokens.bin"],
                    help="token file path(s); more than one path opens the"
                         " list as a FileSet — one logical global row space"
                         " over all shards (data/fileset.py), read through"
                         " one shard-aware session per step window")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpts")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compression", default=None, choices=[None, "bf16"])
    ap.add_argument("--device-ingest", action="store_true",
                    help="one device_put of the whole step window + on-device"
                         " batch reassembly (kernels/reassemble.py) instead"
                         " of host-side batch construction")
    ap.add_argument("--streaming", action="store_true",
                    help="event-driven splinter streaming: stage each"
                         " splinter host->device as its read completes and"
                         " reassemble from arrival order on device (implies"
                         " --device-ingest; StreamMetrics in the final"
                         " summary prove the read/staging overlap)")
    ap.add_argument("--topology", default=None,
                    help="NUMA topology for the reader runtime: 'auto'"
                         " detects the host's NUMA nodes from sysfs (with"
                         " CPU sets for --numa-pin); an integer subdivides"
                         " each logical node into that many memory domains."
                         " Enables domain-coalesced pieces, cross-domain"
                         " delivery accounting, and first-touch arena"
                         " striping (each reader thread faults its own"
                         " stripe's pages on its own domain)")
    ap.add_argument("--numa-pin", action="store_true",
                    help="pin each reader I/O thread to the host CPUs of"
                         " its stripe's NUMA domain (requires --topology"
                         " auto for the CPU map; best-effort — outcomes"
                         " are counted in the locality summary)")
    ap.add_argument("--placement", default="node_spread",
                    choices=["round_robin", "node_spread", "domain_spread",
                             "near_consumers"],
                    help="reader->PE placement policy (core/placement.py);"
                         " near_consumers/domain_spread use --topology"
                         " when given")
    ap.add_argument("--backend", default="thread",
                    choices=["thread", "process"],
                    help="reader backend: 'thread' (helper I/O threads in"
                         " this process) or 'process' (real reader worker"
                         " processes preadv-ing into a shared-memory arena,"
                         " splinter events over cross-process rings —"
                         " src/repro/ipc). Zero-copy delivery and streaming"
                         " work identically; with --numa-pin the workers"
                         " sched_setaffinity-pin themselves, so pinning"
                         " spans real CPU sets")
    ap.add_argument("--max-workers", type=int, default=4,
                    help="process backend: cap on reader worker processes"
                         " per session")
    ap.add_argument("--service", action="store_true",
                    help="process backend: run every step session on a"
                         " persistent reader service (ipc/service.py) —"
                         " pooled long-lived workers re-armed per session"
                         " through shm mailboxes and recycled prefaulted"
                         " arenas, instead of spawning processes and"
                         " creating a fresh segment each step. Implies"
                         " --backend process")
    ap.add_argument("--pool-workers", type=int, default=4,
                    help="--service: persistent workers in the pool"
                         " (sessions check workers out per step; sizing it"
                         " at --max-workers keeps a step fully parallel)")
    ap.add_argument("--adaptive-splinters", action="store_true",
                    help="size splinters per session from observed"
                         " per-reader throughput + steal pressure"
                         " (core/autotune.py SplinterSizer); with"
                         " --streaming each size change retraces the fused"
                         " ingest once until the EMA converges")
    ap.add_argument("--tuned-env", action="store_true",
                    help="re-exec this driver through scripts/env.sh first"
                         " (tcmalloc LD_PRELOAD when the host ships it,"
                         " quiet TF/XLA logging, single intra-op XLA"
                         " thread); every knob degrades silently, so this"
                         " is safe on any host")
    ap.add_argument("--direct-io", action="store_true",
                    help="open the corpus O_DIRECT: reads bypass the page"
                         " cache and DMA into the session arena (cold-cache"
                         " read engine, io/submit.py). Misaligned windows"
                         " fail fast with a DirectIOError — never a silent"
                         " buffered fallback")
    ap.add_argument("--queue-depth", type=int, default=0,
                    help="in-flight splinter reads per reader: 0/1 = the"
                         " blocking loop, >= 2 = depth-managed async"
                         " submission (io_uring when available, else a"
                         " preadv pool)")
    ap.add_argument("--readahead-mb", type=int, default=0,
                    help="WILLNEED window (MB) advised ahead of the async"
                         " submission frontier (buffered files only)")
    ap.add_argument("--submit-mode", default="auto",
                    choices=["auto", "io_uring", "threads"],
                    help="async submission backend selection")
    ap.add_argument("--adaptive-queue", action="store_true",
                    help="let the Director's QueueTuner pick (queue-depth,"
                         " readahead) per session from observed throughput;"
                         " the explicit flags then only seed the first"
                         " session")
    args = ap.parse_args()
    if args.tuned_env and not os.environ.get("CKIO_TUNED_ENV"):
        # Re-exec through the env script so LD_PRELOAD (allocator) and
        # XLA_FLAGS exist before the interpreter and jax start. env.sh
        # exports CKIO_TUNED_ENV=1, which breaks the exec loop.
        root = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                            "..", "..", ".."))
        env_sh = os.path.join(root, "scripts", "env.sh")
        if os.path.exists(env_sh):
            argv = [sys.executable, "-m", "repro.launch.train",
                    *sys.argv[1:]]
            refs = " ".join(
                ['"$0"'] + [f'"${{{i}}}"' for i in range(1, len(argv))])
            os.execvp("bash", [
                "bash", "-c", f'source "{env_sh}" && exec {refs}', *argv])
        print(f"--tuned-env: {env_sh} not found; continuing untuned",
              file=sys.stderr)
    if args.numa_pin and not args.topology:
        ap.error("--numa-pin requires --topology (the topology supplies "
                 "the domain->CPU map; without it nothing would be pinned)")
    if args.service:
        args.backend = "process"
    if args.streaming:
        args.device_ingest = True

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    model = build_model(cfg)
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"params≈{cfg.param_counts()['total']/1e6:.1f}M")

    # -- corpus + CkIO pipeline ------------------------------------------------
    need = args.steps * args.global_batch * (args.seq + 1) + 1024
    per_shard = (need + len(args.data) - 1) // len(args.data)
    for i, p in enumerate(args.data):
        if not os.path.exists(p):
            print(f"writing synthetic corpus shard: {p} ({per_shard} tokens)")
            make_token_file(p, per_shard, cfg.vocab_size, seed=i)
    if len(args.data) > 1:
        # Multi-shard corpus: one FileSet manifest = one logical row space;
        # the pipeline below is unchanged (shard starts become hard stripe
        # bounds inside each session plan).
        from repro.data import FileSet

        data_source = FileSet.build(args.data)
        print(f"fileset: {data_source.describe()}")
    else:
        data_source = args.data[0]
    # One host: a single scheduler node of num_pes PEs, so the NUMA
    # topology's node grid matches the scheduler's (a mismatched grid is
    # rejected by place_readers at session start).
    num_pes = 4
    ckio = CkIO(num_pes=num_pes, pes_per_node=num_pes)
    topology = (Topology.from_spec(args.topology, num_pes=num_pes,
                                   pes_per_node=num_pes)
                if args.topology else None)
    service = None
    if args.service:
        from repro.ipc.service import ReaderService, ServiceOptions

        service = ReaderService(ServiceOptions(
            pool_workers=args.pool_workers))
        print(f"reader service: pool of {args.pool_workers} persistent "
              f"workers (steady-state sessions re-arm, not respawn)")
    pipe = CkIOPipeline(
        data_source, args.global_batch, args.seq,
        ckio=ckio, num_consumers=args.num_consumers,
        file_opts=FileOptions(num_readers=args.num_readers,
                              adaptive_splinters=args.adaptive_splinters,
                              placement=args.placement,
                              topology=topology,
                              numa_pin=args.numa_pin,
                              prefault_arena=(topology is not None
                                              or args.backend == "process"),
                              backend=args.backend,
                              max_workers=args.max_workers,
                              direct_io=args.direct_io,
                              queue_depth=args.queue_depth,
                              readahead_bytes=args.readahead_mb * (1 << 20),
                              submit_mode=args.submit_mode,
                              adaptive_queue=args.adaptive_queue),
        service=service,
        streaming=args.streaming,
    )

    # -- state -----------------------------------------------------------------
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    opt_cfg = OptConfig(peak_lr=args.lr, warmup_steps=max(2, args.steps // 10),
                        decay_steps=args.steps)
    step_jit = jax.jit(make_train_step(
        model, opt_cfg, num_microbatches=args.microbatches,
        compression=args.compression,
    ))

    def step_fn(state, batch):
        p, o, metrics = step_jit(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, metrics

    def batch_for(step: int):
        if args.device_ingest:
            # Device path: one host→device transfer of the whole window,
            # batch-major reassembly + label shift on device.
            x, y = pipe.get_batch_device(step % pipe.num_steps)
            return {"tokens": x, "labels": y}
        x, y = pipe.get_batch(step % pipe.num_steps)
        return {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)}

    ck = AsyncCheckpointer(args.ckpt_dir, keep=3)
    sup = StepSupervisor(step_fn, ck, ckpt_every=args.ckpt_every)

    state = {"params": params, "opt": opt}
    start = 0
    if args.resume and ck.latest():
        from repro.train import restore_tree

        state, start = restore_tree(ck.latest(), state)
        print(f"resumed from step {start}")

    log = []
    t0 = time.time()

    def on_metrics(step, m):
        loss = float(m["loss"])
        log.append({"step": step, "loss": loss})
        if step % 10 == 0 or step == args.steps:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({(time.time()-t0)/max(step-start,1):.2f}s/step)")

    state = sup.run(state, batch_for, args.steps, start_step=start,
                    on_metrics=on_metrics)
    ck.shutdown()
    pipe.close()
    if service is not None:
        service.shutdown()
    summary = pipe.ck  # ckio instance
    print(json.dumps({
        "final_loss": log[-1]["loss"] if log else None,
        "first_loss": log[0]["loss"] if log else None,
        "steps": sup.stats.steps_run,
        "failures": sup.stats.failures,
        "sched_tasks": summary.sched.stats,
        "ingest": pipe.ingest.summary(),
        "stream": pipe.stream.summary() if args.streaming else None,
        "locality": (summary.director.locality.summary()
                     if topology is not None else None),
        "shards": (summary.director.shards.summary()
                   if len(args.data) > 1 else None),
        "service": (service.metrics.summary() if service is not None
                    else None),
    }, indent=2))


if __name__ == "__main__":
    main()
