"""Production mesh definitions.

v5e pod = 16×16 = 256 chips; the multi-pod mesh adds a leading "pod" axis
(2 pods = 512 chips). Function, not module-level constant, so importing this
module never touches jax device state (the dry-run must set XLA_FLAGS before
any device query).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (tests / examples): 1-D data mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def mesh_axis_sizes(mesh) -> dict:
    # works for both concrete Mesh and AbstractMesh (shape is an OrderedDict)
    return dict(mesh.shape)
