"""Roofline analysis over dry-run records (EXPERIMENTS.md §Roofline).

Three terms per (arch × shape × mesh), from the compiled dry-run artifacts:

    compute_s    = HLO_FLOPs_global / (chips × PEAK_FLOPS)
    memory_s     = HLO_bytes_global / (chips × HBM_BW)
    collective_s = per-device collective bytes / LINK_BW
                   (equivalently: global collective bytes / (chips × LINK_BW))

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Notes on sourcing: FLOPs/bytes come from the *unrolled* lowering's HLO cost
analysis (scan bodies are otherwise counted once); collective bytes are the
result-operand sums over the post-SPMD per-device module, measured at
reduced depth and extrapolated linearly in block count (validated exact on
qwen2-vl: extrapolated 2.220e11 == measured 2.220e11). All-reduce counts
payload bytes once; a ring all-reduce moves ~2× that per link, so the
collective term is a lower bound within 2× — consistent across iterations,
which is what the perf loop needs.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
LINK_BW = 50e9            # bytes/s / ICI link
HBM_PER_CHIP = 16 * 2**30  # v5e: 16 GiB


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float          # MODEL_FLOPS / HLO_FLOPS
    step_s: float                # max of the three terms (no-overlap model)
    roofline_frac: float         # compute_s / step_s  ("how close to compute roof")
    hbm_fit: Optional[bool]
    hbm_used_bytes: Optional[int]
    tag: str = ""

    def row(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio, "step_s": self.step_s,
            "roofline_frac": self.roofline_frac, "hbm_fit": self.hbm_fit,
            "tag": self.tag,
        }


def analyze_record(rec: Dict[str, Any]) -> Optional[Roofline]:
    if "error" in rec or rec.get("hlo_flops") in (None, -1.0):
        return None
    chips = rec["chips"]
    flops = float(rec["hlo_flops"])
    coll = rec.get("collectives") or {}
    coll_dev = float(sum(v for k, v in coll.items() if k != "count"))

    compute_s = flops / (chips * PEAK_FLOPS)
    # memory term:
    #  * decode: one pass over resident per-device state (params + caches +
    #    temps) — the compiled-bytes path overcounts stacked-cache updates
    #    (each dynamic_update_index is charged the full buffer), so buffer
    #    sizes from memory_analysis are the honest traffic model;
    #  * train/prefill: per-device post-fusion bytes, extrapolated from the
    #    reduced-depth compiled modules (pre-fusion HLO bytes overcount by
    #    the fusion factor and are kept only as a fallback).
    if rec.get("kind") == "decode" and "temp_size_in_bytes" in rec:
        resident = (rec.get("argument_size_in_bytes", 0)
                    + rec.get("temp_size_in_bytes", 0)
                    + rec.get("output_size_in_bytes", 0))
        memory_s = resident / HBM_BW
    else:
        # spec-prescribed: HLO bytes accessed / (chips x HBM bw). Pre-fusion,
        # so an upper bound on fused HBM traffic (every op materialized);
        # consistent across §Perf iterations, which is what the loop needs.
        # (The compiled per-device metric was evaluated and rejected: CPU
        # cost analysis charges each dynamic-update/slice the full buffer,
        # inflating scan/map-heavy modules ~100x.)
        memory_s = float(rec.get("hlo_bytes") or 0.0) / (chips * HBM_BW)
    collective_s = coll_dev / LINK_BW

    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=lambda k: terms[k])
    step_s = max(terms.values())

    used = None
    fit = None
    if "temp_size_in_bytes" in rec:
        used = int(rec.get("argument_size_in_bytes", 0)) \
            + int(rec.get("temp_size_in_bytes", 0))
        fit = used <= HBM_PER_CHIP

    mf = float(rec.get("model_flops", 0.0))
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"], chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=mf, hlo_flops=flops,
        useful_ratio=(mf / flops) if flops > 0 else 0.0,
        step_s=step_s,
        roofline_frac=(compute_s / step_s) if step_s > 0 else 0.0,
        hbm_fit=fit, hbm_used_bytes=used,
    )


def load_records(path: str) -> List[Dict[str, Any]]:
    recs = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                recs.append(json.loads(line))
    return recs


def latest_by_cell(recs: List[Dict[str, Any]], tag: str = "") -> Dict[tuple, Dict]:
    """Last record per (arch, shape, mesh) with the given tag wins."""
    out: Dict[tuple, Dict] = {}
    for r in recs:
        if r.get("tag", "") != tag:
            continue
        out[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return out


def format_table(rows: List[Roofline]) -> str:
    hdr = (f"{'arch':<20} {'shape':<12} {'mesh':<8} "
           f"{'compute_s':>10} {'memory_s':>10} {'collect_s':>10} "
           f"{'dominant':>10} {'useful':>7} {'roof%':>6} {'fit':>4}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:<20} {r.shape:<12} {r.mesh:<8} "
            f"{r.compute_s:>10.4g} {r.memory_s:>10.4g} {r.collective_s:>10.4g} "
            f"{r.dominant:>10} {r.useful_ratio:>7.2f} "
            f"{100*r.roofline_frac:>5.1f}% "
            f"{'' if r.hbm_fit is None else ('ok' if r.hbm_fit else 'OOM'):>4}"
        )
    return "\n".join(lines)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="benchmarks/results/dryrun.jsonl")
    ap.add_argument("--tag", default="")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    recs = load_records(args.inp)
    cells = latest_by_cell(recs, args.tag)
    rows = []
    for (_, _, mesh), rec in sorted(cells.items()):
        if args.mesh and mesh != args.mesh:
            continue
        r = analyze_record(rec)
        if r:
            rows.append(r)
    print(format_table(rows))


if __name__ == "__main__":
    main()
