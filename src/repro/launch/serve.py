"""Serving driver: batched request serving with CkIO-loaded prompts.

  PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b \
      --smoke --requests 12 --batch 4
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.registry import get_config, smoke_config
from repro.core import CkIO, FileOptions
from repro.data import make_token_file, read_meta, decode_rows
from repro.models import build_model
from repro.serve import BatchServer, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--data", default="/tmp/repro_serve_prompts.bin")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if cfg.is_encdec or cfg.input_mode == "embeddings":
        raise SystemExit("serving example targets token-input archs")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # prompts arrive through CkIO (the request file is one large shared file)
    n_tokens = args.requests * args.prompt_len
    make_token_file(args.data, n_tokens, cfg.vocab_size, seed=7)
    meta = read_meta(args.data)
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(args.data, FileOptions(num_readers=2))
    off, nbytes = meta.byte_range_for_rows(0, n_tokens)
    sess = ck.start_read_session_sync(fh, nbytes, off)
    buf = np.empty(n_tokens, dtype=meta.dtype)
    msg = ck.read_sync(sess, nbytes, off, memoryview(buf).cast("B"))
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)
    prompts = buf.reshape(args.requests, args.prompt_len).astype(np.int32)

    reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=args.max_new)
            for i in range(args.requests)]
    server = BatchServer(model, params, batch_size=args.batch)
    t0 = time.time()
    done = server.serve(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.result) for r in done)
    print(json.dumps({
        "requests": len(done),
        "total_s": round(dt, 3),
        "new_tokens": total_new,
        "tok_per_s": round(total_new / dt, 1),
        "all_completed": all(r.result is not None for r in done),
    }, indent=2))


if __name__ == "__main__":
    main()
