"""Serving driver: request serving with CkIO-loaded prompts.

Static mode (default) runs the legacy pad-to-bucket ``BatchServer`` over
one bulk prompt read. Continuous mode (``--continuous``) runs the real
serving subsystem: per-request sessions out of a sharded ``FileSet``
(optionally through a pooled ``ReaderService``), a ``RequestIngester``
with bounded-queue backpressure, and the ``ContinuousBatcher`` decode loop
over a per-slot ``ModelEngine`` — ending with a ``ServeMetrics`` summary
table (arrival→ingested / →first-token / →e2e p50/p99/p999, occupancy,
sessions/sec, backpressure counters).

  PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b \
      --smoke --requests 12 --batch 4
  PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b \
      --smoke --continuous --service --pool-workers 2 --arrival-rate 50
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.registry import get_config, smoke_config
from repro.core import CkIO, FileOptions, ServeMetrics
from repro.data import make_token_file, read_meta
from repro.data.fileset import FileSet, write_token_shards
from repro.models import build_model
from repro.serve import (
    BatchServer,
    ContinuousBatcher,
    ModelEngine,
    Request,
    RequestIngester,
    ServeOverloaded,
    ServeRequest,
)


def _print_metrics_table(metrics: ServeMetrics) -> None:
    s = metrics.summary()
    print("\nServeMetrics")
    print(f"  {'metric':<26} {'value':>14}")
    for k in sorted(s):
        v = s[k]
        print(f"  {k:<26} {v:>14.6g}")
    if metrics.transitions:
        print("  backpressure transitions:",
              ", ".join(f"{k}×{v}" for k, v in metrics.transitions.items()))


def _serve_static(args, model, params, cfg) -> None:
    # prompts arrive through CkIO (the request file is one large shared file)
    n_tokens = args.requests * args.prompt_len
    make_token_file(args.data, n_tokens, cfg.vocab_size, seed=7)
    meta = read_meta(args.data)
    ck = CkIO(num_pes=2)
    fh = ck.open_sync(args.data, FileOptions(num_readers=2))
    off, nbytes = meta.byte_range_for_rows(0, n_tokens)
    sess = ck.start_read_session_sync(fh, nbytes, off)
    buf = np.empty(n_tokens, dtype=meta.dtype)
    ck.read_sync(sess, nbytes, off, memoryview(buf).cast("B"))
    ck.close_read_session_sync(sess)
    ck.close_sync(fh)
    prompts = buf.reshape(args.requests, args.prompt_len).astype(np.int32)

    reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=args.max_new)
            for i in range(args.requests)]
    server = BatchServer(model, params, batch_size=args.batch)
    t0 = time.time()
    done = server.serve(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.result) for r in done)
    lats = sorted(r.latency_s for r in done)
    print(json.dumps({
        "mode": "static",
        "requests": len(done),
        "total_s": round(dt, 3),
        "new_tokens": total_new,
        "tok_per_s": round(total_new / dt, 1),
        "latency_p50_s": round(lats[len(lats) // 2], 4),
        "latency_max_s": round(lats[-1], 4),
        "all_completed": all(r.result is not None for r in done),
    }, indent=2))


def _serve_continuous(args, model, params, cfg) -> None:
    n_tokens = args.requests * args.prompt_len
    # prompt corpus as a sharded FileSet — the production corpus shape
    rng = np.random.default_rng(7)
    tokens = rng.integers(0, cfg.vocab_size, size=(n_tokens,),
                          dtype=np.int32)
    shard_dir = args.data + ".shards"
    per = n_tokens // max(1, args.shards)
    counts = [per] * (args.shards - 1) + [n_tokens - per * (args.shards - 1)]
    fs = FileSet.build(write_token_shards(shard_dir, tokens, counts))

    ck = CkIO(num_pes=2)
    metrics = ServeMetrics()
    ck.director.add_observer(metrics.record_session)
    service = None
    if args.service:
        from repro.ipc.service import ReaderService, ServiceOptions

        service = ReaderService(ServiceOptions(
            pool_workers=args.pool_workers))
        ck.director.attach_service(service)
    opts = FileOptions(
        num_readers=2,
        backend="process" if args.service else "thread",
        max_workers=2,
        use_service=True if args.service else None,
    )
    fh = ck.open_fileset_sync(fs, opts)
    ingester = RequestIngester(
        ck, fh, fs, metrics,
        max_pending=max(8, args.requests),
        max_inflight_bytes=int(args.max_inflight_mb * (1 << 20)),
        service=service,
    )
    engine = ModelEngine(model, params, slots=args.batch,
                         seq_budget=args.prompt_len + args.max_new + 8)
    batcher = ContinuousBatcher(engine, ingester)

    reqs = [ServeRequest(rid=i, row_start=i * args.prompt_len,
                         num_rows=args.prompt_len,
                         max_new_tokens=args.max_new)
            for i in range(args.requests)]
    if args.arrival_rate > 0:
        gaps = rng.exponential(1.0 / args.arrival_rate, size=len(reqs))
        arrivals = np.cumsum(gaps)
    else:
        arrivals = np.zeros(len(reqs))
    shed = []
    state = {"idx": 0, "t0": time.perf_counter()}

    def pump() -> bool:
        now = time.perf_counter() - state["t0"]
        while state["idx"] < len(reqs) and arrivals[state["idx"]] <= now:
            try:
                ingester.submit(reqs[state["idx"]])
            except ServeOverloaded:
                shed.append(reqs[state["idx"]].rid)
            state["idx"] += 1
        return state["idx"] < len(reqs)

    t0 = time.time()
    done = batcher.run(pump)
    dt = time.time() - t0
    ck.close_sync(fh)
    if service is not None:
        service.shutdown()
    total_new = sum(len(r.result) for r in done)
    print(json.dumps({
        "mode": "continuous",
        "requests": len(done),
        "shed": len(shed),
        "total_s": round(dt, 3),
        "new_tokens": total_new,
        "tok_per_s": round(total_new / dt, 1),
        "all_completed": len(done) + len(shed) == args.requests,
    }, indent=2))
    _print_metrics_table(metrics)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4,
                    help="static batch size / continuous decode slots")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--data", default="/tmp/repro_serve_prompts.bin")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over per-request sessions")
    ap.add_argument("--service", action="store_true",
                    help="route ingest through a pooled ReaderService")
    ap.add_argument("--pool-workers", type=int, default=2)
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrival rate in req/s (0 = all at once)")
    ap.add_argument("--max-inflight-mb", type=float, default=64.0,
                    help="ingest backpressure budget (open session bytes)")
    ap.add_argument("--shards", type=int, default=3,
                    help="prompt FileSet shard count (continuous mode)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if cfg.is_encdec or cfg.input_mode == "embeddings":
        raise SystemExit("serving example targets token-input archs")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.continuous:
        _serve_continuous(args, model, params, cfg)
    else:
        _serve_static(args, model, params, cfg)


if __name__ == "__main__":
    main()
