"""Per-architecture sharding rules (DP/TP/EP/ZeRO-1 over the production mesh).

Conventions:
  * ``model`` axis: tensor/expert parallelism — vocab, heads, d_ff, experts,
    d_inner, lru_width.
  * ``data`` (+ ``pod``) axes: batch data parallelism; ZeRO-1 additionally
    shards optimizer moments over ``data`` on each param's largest
    still-unsharded divisible dim.
  * dims are sharded over an axis only when divisible OR at least 2× the
    axis size (GSPMD pads; the padding waste is called out per arch in
    EXPERIMENTS.md §Roofline — phi3's 40/10 heads, qwen2-moe's 60 experts,
    whisper's 51865 vocab).
  * kv-head dims smaller than the axis (qwen2-vl kv=2, phi3 kv=10,
    recurrentgemma kv=1) stay replicated.

Specs are built from *abstract* trees (eval_shape) — no allocation — and
keyed off leaf path names, mirroring how MaxText-style logical axis rules
work but without a separate annotation pass.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import mesh_axis_sizes

BATCH_AXES = ("pod", "data")


def _batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in BATCH_AXES if a in mesh.axis_names)


def _batch_size(mesh) -> int:
    sizes = mesh_axis_sizes(mesh)
    n = 1
    for a in _batch_axes(mesh):
        n *= sizes[a]
    return n


def _maybe(dim: int, axis: str, axis_size: int) -> Optional[str]:
    """Shard ``dim`` over ``axis`` only when divisible — jit argument
    shardings must divide evenly (unlike intermediate constraints)."""
    if dim >= axis_size and dim % axis_size == 0:
        return axis
    return None


def param_leaf_spec(path: str, shape: Tuple[int, ...], mesh) -> P:
    m = mesh_axis_sizes(mesh).get("model", 1)
    stacked = any(
        f"['{k}']" in path for k in ("blocks", "enc_blocks", "dec_blocks")
    )
    nd = len(shape) - (1 if stacked else 0)
    trail = shape[len(shape) - nd:]
    name = path.rsplit("['", 1)[-1].rstrip("']")

    def spec(*axes) -> P:
        axes = tuple(axes)
        assert len(axes) == nd, (path, shape, axes)
        return P(*((None,) + axes)) if stacked else P(*axes)

    # embeddings / unembedding
    if name == "table":
        v = _maybe(trail[0], "model", m)
        if v:
            return spec(v, None)
        # odd vocab (whisper 51865): replicate — sharding d_model instead
        # breaks the SPMD gather partitioner on the 3-axis multi-pod mesh
        return spec(None, None)
    if path.endswith("['lm_head']['w']"):
        return spec(None, _maybe(trail[1], "model", m))
    if name in ("enc_pos", "dec_pos"):
        return spec(None, None)

    # attention — shard heads when divisible, else fall back to head_dim
    # (phi3's 40/10 heads, qwen2-vl's kv=2, recurrentgemma's kv=1)
    if name in ("wq", "wk", "wv") and nd == 3:
        h = _maybe(trail[1], "model", m)
        if h:
            return spec(None, h, None)
        return spec(None, None, _maybe(trail[2], "model", m))
    if name == "wo" and nd == 3:
        h = _maybe(trail[0], "model", m)
        if h:
            return spec(h, None, None)
        return spec(None, _maybe(trail[1], "model", m), None)
    if name in ("bq", "bk", "bv"):
        h = _maybe(trail[0], "model", m)
        if h:
            return spec(h, None)
        return spec(None, _maybe(trail[1], "model", m))

    # MoE experts (3-D) before dense GLU (2-D)
    if name in ("gate", "up", "down") and nd == 3:
        return spec(_maybe(trail[0], "model", m), None, None)
    if name in ("gate", "up", "shared_gate", "shared_up", "fc1") and nd == 2:
        return spec(None, _maybe(trail[1], "model", m))
    if name in ("down", "shared_down", "fc2") and nd == 2:
        return spec(_maybe(trail[0], "model", m), None)
    if name == "fc1_b":
        return spec(_maybe(trail[0], "model", m))
    if name == "router":
        return spec(None, None)

    # mamba
    if name == "in_proj":
        return spec(None, _maybe(trail[1], "model", m))
    if name == "x_proj":
        return spec(_maybe(trail[0], "model", m), None)
    if name == "dt_proj":
        return spec(None, _maybe(trail[1], "model", m))
    if name in ("dt_bias", "D", "conv_b"):
        return spec(_maybe(trail[0], "model", m))
    if name == "A_log":
        return spec(_maybe(trail[0], "model", m), None)
    if name == "conv_w":
        return spec(None, _maybe(trail[1], "model", m))
    if name == "out_proj":
        return spec(_maybe(trail[0], "model", m), None)

    # rg-lru
    if name in ("wx", "wy"):
        return spec(None, _maybe(trail[1], "model", m))
    if name in ("w_r", "w_i"):
        return spec(None, _maybe(trail[1], "model", m))
    if name in ("b_r", "b_i", "lam"):
        return spec(_maybe(trail[0], "model", m))
    if name == "wo" and nd == 2:   # rg-lru out projection (w, d)
        return spec(_maybe(trail[0], "model", m), None)

    # norms, scalars, everything small: replicate
    return spec(*([None] * nd))


def param_specs(abstract_params: Any, mesh) -> Any:
    def one(path, leaf):
        return param_leaf_spec(jax.tree_util.keystr(path), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, abstract_params)


def zero1_specs(abstract_params: Any, p_specs: Any, mesh) -> Any:
    """Moment sharding: param spec + 'data' on the largest free divisible dim."""
    d = mesh_axis_sizes(mesh).get("data", 1)

    def one(leaf, spec: P) -> P:
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        best, best_size = None, 0
        for i, (dim, s) in enumerate(zip(leaf.shape, parts)):
            if s is None and dim % d == 0 and dim > best_size and dim >= d:
                best, best_size = i, dim
            elif s == "data":
                return P(*parts)
        if best is not None:
            parts[best] = "data"
        return P(*parts)

    return jax.tree.map(one, abstract_params, p_specs,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(abstract_params: Any, p_specs: Any, mesh,
                    master_weights: bool = False) -> Any:
    z = zero1_specs(abstract_params, p_specs, mesh)
    out = {"mu": z, "nu": z, "step": P()}
    if master_weights:
        out["master"] = z
    return out


def batch_specs(abstract_batch: Any, mesh) -> Any:
    baxes = _batch_axes(mesh)
    bsize = _batch_size(mesh)

    def one(leaf):
        if leaf.shape and leaf.shape[0] % bsize == 0 and leaf.shape[0] > 0:
            return P(baxes, *([None] * (len(leaf.shape) - 1)))
        return P(*([None] * len(leaf.shape)))

    return jax.tree.map(one, abstract_batch)


def decode_state_specs(abstract_state: Any, mesh, cfg=None) -> Any:
    """KV caches: batch over data axes, kv-heads over model when divisible;
    SSM/LRU states: batch over data, channel dim over model."""
    m = mesh_axis_sizes(mesh).get("model", 1)
    baxes = _batch_axes(mesh)
    bsize = _batch_size(mesh)

    def one(path, leaf):
        path_s = jax.tree_util.keystr(path)
        shape = leaf.shape
        # stacked-over-blocks states have a leading nb dim inside 'blocks'
        stacked = "blocks" in path_s or "self_caches" in path_s or "cross_kv" in path_s
        lead = (None,) if stacked else ()
        nd = len(shape) - len(lead)
        tshape = shape[len(lead):]
        if nd == 0:
            return P(*lead)
        parts = [None] * nd
        if tshape[0] % bsize == 0 and tshape[0] >= bsize:
            parts[0] = baxes
        if nd == 4:                      # (B, C, K, hd) kv cache
            kvh = _maybe(tshape[2], "model", m)
            if kvh:
                parts[2] = kvh
            else:                        # MQA-ish: shard head_dim instead
                parts[3] = _maybe(tshape[3], "model", m)
        elif nd == 3:                    # (B, di, n) ssm or (B, cw-1, di) conv
            if tshape[1] % m == 0 and tshape[1] >= 2 * m:
                parts[1] = "model"       # (B, di, n)
            elif tshape[2] % m == 0 and tshape[2] >= 2 * m:
                parts[2] = "model"       # (B, cw-1, di)
        elif nd == 2 and tshape[1] % m == 0 and tshape[1] >= 2 * m:
            parts[1] = "model"           # (B, w) lru state
        return P(*(lead + tuple(parts)))

    return jax.tree_util.tree_map_with_path(one, abstract_state)


def logits_spec(mesh, batch_size: int = 0, vocab: int = 0) -> P:
    b = _batch_axes(mesh)
    if len(b) == 1:
        b = b[0]                       # canonical bare-axis form ("data",) -> "data"
    if batch_size and batch_size % _batch_size(mesh) != 0:
        b = None                       # e.g. long_500k batch=1
    m = mesh_axis_sizes(mesh).get("model", 1)
    v = "model" if (not vocab or vocab % m == 0) else None  # whisper vocab 51865
    return P(b, None, v)


def to_named(spec_tree: Any, mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
