"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B].

32L d_model=4096 32H (MHA kv=32) d_ff=13440 vocab=92416, qwen1.5 arch
(QKV bias, 1M rope theta for 64k context).
"""
from repro.configs.base import ATTN, DENSE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    block_pattern=(LayerSpec(mixer=ATTN, ffn=DENSE),),
    rope_theta=1_000_000.0,
    attn_bias=True,
    tie_embeddings=False,
    source="hf:Qwen/CodeQwen1.5-7B",
)
