"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16) vocab=151936; MoE: 60 routed experts top-4
+ 4 shared experts, per-expert d_ff=1408.
"""
from repro.configs.base import ATTN, MOE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    block_pattern=(LayerSpec(mixer=ATTN, ffn=MOE),),
    num_experts=60,
    expert_pad=4,                # physical 64 experts for EP-16 divisibility;
                                 # the 4 padded experts are masked from routing
    num_shared_experts=4,
    top_k=4,
    moe_d_ff=1408,
    rope_theta=1_000_000.0,
    attn_bias=True,
    tie_embeddings=False,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
