"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427].

26L d_model=2560 10H (MQA kv=1, head_dim 256) d_ff=7680 vocab=256000;
pattern (RG-LRU, RG-LRU, local-attn window 2048) — 8 scanned blocks + 2
unrolled RG-LRU tail layers; lru_width=2560, GeGLU MLPs. Runs long_500k
(O(1)/token recurrent state + O(window) local-attn cache).
"""
from repro.configs.base import ATTN_LOCAL, DENSE, RGLRU, LayerSpec, ModelConfig

_REC = LayerSpec(mixer=RGLRU, ffn=DENSE)
_LOC = LayerSpec(mixer=ATTN_LOCAL, ffn=DENSE, window=2048)

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=(_REC, _REC, _LOC),
    window=2048,
    lru_width=2560,
    conv_width=4,
    rope_theta=10_000.0,
    act="gelu_glu",
    tie_embeddings=True,
    source="arXiv:2402.19427",
)
