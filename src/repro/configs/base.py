"""Model / run configuration system.

``ModelConfig`` is the single source of truth for an architecture. Each
assigned arch gets one file in this package instantiating it with the exact
published dimensions. Layer heterogeneity (gemma3's 5:1 local:global,
recurrentgemma's 1:2 attn:recurrent) is expressed as a repeating
``block_pattern`` of ``LayerSpec`` entries; the model stack scans over whole
blocks and unrolls the remainder (`tail`), keeping compile time and HLO size
bounded for 62-layer models.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

# Mixer kinds
ATTN = "attn"            # global causal (or bidirectional in encoders)
ATTN_LOCAL = "attn_local"  # sliding-window causal
RGLRU = "rglru"          # Griffin recurrent block
MAMBA = "mamba"          # Mamba-1 selective SSM block (no separate FFN)

# FFN kinds
DENSE = "dense"
MOE = "moe"
NONE = "none"


@dataclass(frozen=True)
class LayerSpec:
    mixer: str = ATTN
    ffn: str = DENSE
    window: int = 0          # >0 for attn_local

    def __post_init__(self):
        assert self.mixer in (ATTN, ATTN_LOCAL, RGLRU, MAMBA), self.mixer
        assert self.ffn in (DENSE, MOE, NONE), self.ffn


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str                  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|vlm|audio|ssm|hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # layer schedule: pattern repeated, remainder unrolled
    block_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    expert_pad: int = 0          # physical padding to a multiple of the EP axis
                                 # (padded experts are masked out of routing)

    # positional
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) in rope pairs
    use_rope: bool = True                  # whisper uses learned abs positions

    # local attention
    window: int = 0

    # q-chunked attention (XLA-native flash equivalent): sequences longer
    # than this are processed in q-chunks with per-chunk remat, bounding the
    # score tensor to (B, K, G, chunk, S) — required for 32k+ prefill to fit
    attn_q_chunk: int = 2048

    # SSM (mamba1)
    ssm_state: int = 0
    d_inner: int = 0
    conv_width: int = 4
    dt_rank: int = 0
    ssm_chunk: int = 256

    # RG-LRU
    lru_width: int = 0

    # encoder-decoder (whisper): encoder layers use bidirectional attention
    encoder_layers: int = 0
    encoder_seq: int = 0                 # fixed encoder length (1500 frames)

    # misc arch
    norm_eps: float = 1e-6
    act: str = "silu"                    # silu/gelu_glu (GLU) | gelu (plain MLP)
    attn_bias: bool = False              # qwen-family QKV bias
    qk_norm: bool = False                # gemma3/olmoe query-key RMSNorm
    attn_logit_softcap: float = 0.0
    tie_embeddings: bool = True

    # input stub mode: "tokens" | "embeddings" (vlm/audio frontends)
    input_mode: str = "tokens"

    # runtime
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat_policy: str = "dots"           # none|dots|full
    scan_layers: bool = True             # False -> unroll (exact HLO cost analysis)
    max_position: int = 1_048_576
    # §Perf levers (baseline defaults; see EXPERIMENTS.md for the iterations)
    xent_mode: str = "gather"            # gather | onehot (sharded-vocab friendly)
    ssm_impl: str = "materialized"       # materialized | fused (per-chunk discretize)
    # physical head padding (0 = none): pad (H, K) to TP-divisible counts
    # with the SAME group ratio G=H/K; padded slices are zero-initialized and
    # stay zero under gradient flow — exact math, eliminates the head_dim-
    # sharding fallback's score-psum collectives (§Perf B)
    num_heads_phys: int = 0
    num_kv_heads_phys: int = 0

    # citation (source of the numbers)
    source: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return all(s.mixer in (MAMBA, RGLRU) for s in self.block_pattern)

    def layer_schedule(self) -> List[LayerSpec]:
        """Full per-layer schedule (pattern cycled to num_layers)."""
        p = self.block_pattern
        return [p[i % len(p)] for i in range(self.num_layers)]

    def scan_split(self) -> Tuple[Tuple[LayerSpec, ...], int, Tuple[LayerSpec, ...]]:
        """(block_pattern, num_full_blocks, tail_layers)."""
        p = self.block_pattern
        nb = self.num_layers // len(p)
        tail = tuple(self.layer_schedule()[nb * len(p):])
        return p, nb, tail

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (for MODEL_FLOPS = 6·N·D) --------------------------
    def param_counts(self) -> dict:
        """Analytic parameter counts: total and active-per-token."""
        d, hd = self.d_model, self.resolved_head_dim
        H, K = self.num_heads, self.num_kv_heads
        embed = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d

        def attn_params() -> int:
            return d * H * hd + 2 * d * K * hd + H * hd * d

        def dense_ffn(ff: int) -> int:
            if self.act in ("silu", "gelu_glu"):
                return 3 * d * ff        # GLU: gate, up, down
            return 2 * d * ff            # plain MLP

        def mamba_params() -> int:
            di, n, r = self.d_inner, self.ssm_state, self.dt_rank
            return (
                d * 2 * di               # in_proj
                + di * self.conv_width   # depthwise conv
                + di * (r + 2 * n)       # x_proj
                + r * di + di            # dt_proj
                + di * n + di            # A_log, D
                + di * d                 # out_proj
            )

        def rglru_block() -> int:
            w = self.lru_width
            return (
                2 * d * w                # gate & recurrent input projections
                + w * self.conv_width    # temporal conv
                + 2 * w                  # a-gate params (Lambda, input gate)
                + 2 * w * w // 1         # rg-lru input/recurrence gates (per-head dense approx)
                + w * d                  # out proj
            )

        total = embed + head
        active = embed + head
        for spec in self.layer_schedule():
            if spec.mixer in (ATTN, ATTN_LOCAL):
                total += attn_params(); active += attn_params()
            elif spec.mixer == MAMBA:
                total += mamba_params(); active += mamba_params()
            elif spec.mixer == RGLRU:
                total += rglru_block(); active += rglru_block()
            if spec.ffn == DENSE:
                total += dense_ffn(self.d_ff); active += dense_ffn(self.d_ff)
            elif spec.ffn == MOE:
                per_expert = dense_ffn(self.moe_d_ff)
                total += self.num_experts * per_expert
                total += self.num_shared_experts * per_expert
                total += d * self.num_experts            # router
                active += self.top_k * per_expert
                active += self.num_shared_experts * per_expert
                active += d * self.num_experts
            total += 2 * d               # norms
            active += 2 * d
        if self.is_encdec:
            # encoder stack: bidirectional attn + dense ffn (+ cross-attn in decoder
            # counted as one extra attn per decoder layer)
            enc = self.encoder_layers * (attn_params() + dense_ffn(self.d_ff) + 2 * d)
            cross = self.num_layers * attn_params()
            total += enc + cross
            active += enc + cross
        return {"total": total, "active": active}
