"""Phi-3-medium-14B [arXiv:2404.14219].

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352, RoPE + SwiGLU.
Note: 40 q-heads / 10 kv-heads are not divisible by the model=16 mesh axis;
GSPMD pads the head dimension (documented in EXPERIMENTS.md §Roofline).
"""
from repro.configs.base import ATTN, DENSE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100352,
    block_pattern=(LayerSpec(mixer=ATTN, ffn=DENSE),),
    rope_theta=10_000.0,
    tie_embeddings=False,
    source="arXiv:2404.14219",
)
