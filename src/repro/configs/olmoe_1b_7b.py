"""OLMoE-1B-7B [arXiv:2409.02060].

16L d_model=2048 16H (GQA kv=16) vocab=50304; MoE: 64 experts top-8,
per-expert d_ff=1024, QK-norm.
"""
from repro.configs.base import ATTN, MOE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    block_pattern=(LayerSpec(mixer=ATTN, ffn=MOE),),
    num_experts=64,
    num_shared_experts=0,
    top_k=8,
    moe_d_ff=1024,
    rope_theta=10_000.0,
    qk_norm=True,
    tie_embeddings=False,
    source="arXiv:2409.02060",
)
