"""Qwen2-VL-2B [arXiv:2409.12191].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936; M-RoPE with
(t, h, w) sections over the rotary dims; dynamic-resolution vision frontend
is a STUB — input_specs() supplies precomputed patch embeddings.
"""
from repro.configs.base import ATTN, DENSE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    block_pattern=(LayerSpec(mixer=ATTN, ffn=DENSE),),
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),     # t/h/w sections, sum = head_dim//2
    attn_bias=True,
    tie_embeddings=True,
    input_mode="embeddings",
    source="arXiv:2409.12191",
)
