"""Whisper-medium [arXiv:2212.04356].

24L enc + 24L dec, d_model=1024 16H (kv=16) d_ff=4096 vocab=51865; learned
absolute positions, GELU MLPs, conv/mel frontend STUBBED (input_specs()
supplies precomputed frame embeddings, 1500 frames = 30 s audio).
"""
from repro.configs.base import ATTN, DENSE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,              # decoder layers
    encoder_layers=24,
    encoder_seq=1500,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    block_pattern=(LayerSpec(mixer=ATTN, ffn=DENSE),),
    use_rope=False,
    act="gelu",
    norm_eps=1e-5,
    tie_embeddings=True,
    input_mode="embeddings",
    max_position=40_960,        # learned decoder positions (covers decode_32k)
    source="arXiv:2212.04356",
)
