"""Architecture registry + smoke-config reducer.

``get_config(name)`` returns the exact published config; ``smoke_config``
shrinks any config to a CPU-runnable size *of the same family* (same block
pattern, same mixer kinds, few layers, tiny widths) for the per-arch smoke
tests — the full configs are exercised only through the dry run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import ModelConfig, SHAPES, SHAPES_BY_NAME, ShapeConfig
from repro.configs.codeqwen1_5_7b import CONFIG as _codeqwen
from repro.configs.falcon_mamba_7b import CONFIG as _falcon_mamba
from repro.configs.gemma3_27b import CONFIG as _gemma3
from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.phi3_medium_14b import CONFIG as _phi3
from repro.configs.phi4_mini_3_8b import CONFIG as _phi4
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen2_moe
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2_vl
from repro.configs.recurrentgemma_2b import CONFIG as _recurrentgemma
from repro.configs.whisper_medium import CONFIG as _whisper

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _qwen2_moe,
        _olmoe,
        _qwen2_vl,
        _codeqwen,
        _phi4,
        _phi3,
        _gemma3,
        _whisper,
        _falcon_mamba,
        _recurrentgemma,
    )
}

# long_500k applicability: only sub-quadratic decode families run it
LONG_CONTEXT_ARCHS = ("falcon-mamba-7b", "recurrentgemma-2b")


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> List[str]:
    return sorted(ARCHS)


def cells(include_long_for_all: bool = False):
    """Yield every assigned (arch, shape) cell, honouring the long_500k rule."""
    for name in list_archs():
        for shape in SHAPES:
            if (
                shape.name == "long_500k"
                and not include_long_for_all
                and name not in LONG_CONTEXT_ARCHS
            ):
                continue
            yield name, shape


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: 2 scan blocks + original tail remainder."""
    pattern = cfg.block_pattern
    tail_len = cfg.num_layers % len(pattern)
    num_layers = 2 * len(pattern) + tail_len
    hd = 16
    heads = max(2, min(4, cfg.num_heads or 2))
    kv = 1 if cfg.num_kv_heads <= 1 else 2
    # keep M-RoPE sections proportional: sum must equal hd//2
    mrope = (2, 3, 3) if cfg.mrope_sections else ()
    kw = dict(
        num_layers=num_layers,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv if cfg.num_kv_heads else 0,
        head_dim=hd,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        mrope_sections=mrope,
        window=min(cfg.window, 16) if cfg.window else 0,
        max_position=4096,
    )
    if cfg.num_experts:
        kw.update(num_experts=8, top_k=min(cfg.top_k, 2), moe_d_ff=32,
                  num_shared_experts=min(cfg.num_shared_experts, 2))
    if cfg.ssm_state:
        kw.update(d_inner=128, ssm_state=4, dt_rank=8, ssm_chunk=16)
    if cfg.lru_width:
        kw.update(lru_width=64)
    if cfg.encoder_layers:
        kw.update(encoder_layers=2, encoder_seq=32)
    # rebuild block pattern with the reduced window
    if cfg.window:
        new_pattern = tuple(
            dataclasses.replace(s, window=min(s.window, 16) if s.window else 0)
            for s in pattern
        )
        kw["block_pattern"] = new_pattern
    return cfg.replace(**kw)


def smoke_shape(shape: ShapeConfig) -> ShapeConfig:
    """Tiny shape of the same kind for CPU smoke runs."""
    return ShapeConfig(
        name=f"smoke_{shape.name}",
        seq_len=32,
        global_batch=2,
        kind=shape.kind,
    )
