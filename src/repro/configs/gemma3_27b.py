"""Gemma-3-27B [hf:google/gemma-3-1b-pt family].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144; 5:1 local:global
attention pattern (local window 1024), QK-norm, GeGLU. 62 = 10 scanned
blocks of (5 local + 1 global) + 2 unrolled local tail layers.
"""
from repro.configs.base import ATTN, ATTN_LOCAL, DENSE, LayerSpec, ModelConfig

_LOCAL = LayerSpec(mixer=ATTN_LOCAL, ffn=DENSE, window=1024)
_GLOBAL = LayerSpec(mixer=ATTN, ffn=DENSE)

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    block_pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    window=1024,
    rope_theta=1_000_000.0,
    qk_norm=True,
    act="gelu_glu",
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt (27B dims)",
)
