"""Falcon-Mamba-7B [arXiv:2410.05355].

64L d_model=4096, attention-free Mamba-1 blocks: d_inner=8192, ssm_state=16,
dt_rank=256, conv width 4; vocab=65024. Runs the long_500k cell (O(1)/token
decode state).
"""
from repro.configs.base import MAMBA, NONE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    block_pattern=(LayerSpec(mixer=MAMBA, ffn=NONE),),
    ssm_state=16,
    d_inner=8192,
    dt_rank=256,
    conv_width=4,
    ssm_chunk=256,
    use_rope=False,
    tie_embeddings=False,
    source="arXiv:2410.05355",
)
