"""Mixture-of-Experts FFN: top-k routing, capacity-based sort+gather dispatch,
shared experts (Qwen-MoE style), Switch-style load-balancing auxiliary loss.

Dispatch strategy (TPU/SPMD-native, flop-sane):
  routing is done *per sequence group* (the batch row), so no cross-shard
  sort is required; token slots are assigned with an argsort over S·k
  elements per row; expert inputs are built by gather into an (E, C, d)
  capacity buffer; expert FFNs run as batched einsums with the expert axis
  sharded over `model` (expert parallelism). XLA inserts the dispatch/combine
  gathers as the EP collectives. Dominant FLOPs = capacity_factor × ideal
  active FLOPs (vs the T² blow-up of naive one-hot dispatch einsums — see
  EXPERIMENTS.md §Perf for the measured comparison).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import MODEL_AXIS, fan_in_init, shard_act


def moe_init(key, d: int, num_experts: int, moe_ff: int, num_shared: int,
             dtype, expert_pad: int = 0) -> dict:
    ks = jax.random.split(key, 5)
    ep = num_experts + expert_pad    # physical experts (EP divisibility)
    p = {
        "router": fan_in_init(ks[0], (d, ep), d, dtype),
        "gate": fan_in_init(ks[1], (ep, d, moe_ff), d, dtype),
        "up": fan_in_init(ks[2], (ep, d, moe_ff), d, dtype),
        "down": fan_in_init(ks[3], (ep, moe_ff, d), moe_ff, dtype),
    }
    if num_shared > 0:
        ff_sh = num_shared * moe_ff
        kg, ku, kd = jax.random.split(ks[4], 3)
        p["shared_gate"] = fan_in_init(kg, (d, ff_sh), d, dtype)
        p["shared_up"] = fan_in_init(ku, (d, ff_sh), d, dtype)
        p["shared_down"] = fan_in_init(kd, (ff_sh, d), ff_sh, dtype)
    return p


def _route(
    logits: jax.Array,       # (B, S, E) fp32
    top_k: int,
    capacity: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Per-row slot assignment.

    Returns:
      idx_table (B, E*C) int32 — token index feeding each expert slot
                                 (S = sentinel → zero row),
      slot_of   (B, S, k) int32 — expert slot per (token, choice),
                                  E*C = sentinel (dropped),
      weight    (B, S, k) fp32  — router weight per choice,
      probs     (B, S, E) fp32  — full router probabilities (for aux loss).
    """
    B, S, E = logits.shape
    k = top_k
    C = capacity
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                  # (B,S,k)

    eid = top_e.reshape(B, S * k)
    # stable sort by expert id so earlier tokens win capacity (Switch rule)
    order = jnp.argsort(eid, axis=-1, stable=True)          # (B, S*k)
    eid_sorted = jnp.take_along_axis(eid, order, axis=-1)
    tok_sorted = order // k                                  # token of each entry

    # position within the expert segment
    seg_start = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E), side="left")
    )(eid_sorted)                                            # (B, E)
    start_of = jnp.take_along_axis(seg_start, eid_sorted, axis=-1)
    pos = jnp.arange(S * k)[None, :] - start_of              # (B, S*k)
    keep = pos < C

    dest = eid_sorted * C + pos                              # (B, S*k)
    dest_safe = jnp.where(keep, dest, E * C)                 # sentinel slot

    # expert-slot -> token table (scatter; sentinel token index = S)
    def scatter_row(tok_row, dest_row):
        t = jnp.full((E * C + 1,), S, dtype=jnp.int32)
        return t.at[dest_row].set(tok_row.astype(jnp.int32))[: E * C]

    idx_table = jax.vmap(scatter_row)(tok_sorted, dest_safe)  # (B, E*C)

    # token -> slot back-map (unsort)
    def unsort_row(dest_row, order_row):
        out = jnp.zeros((S * k,), dtype=jnp.int32)
        return out.at[order_row].set(dest_row.astype(jnp.int32))

    slot_of = jax.vmap(unsort_row)(dest_safe, order).reshape(B, S, k)
    return idx_table, slot_of, top_w, probs


def load_balance_loss(probs: jax.Array, slot_of: jax.Array, num_experts: int,
                      top_k: int, capacity: int) -> jax.Array:
    """Switch-Transformer aux loss: E * sum_e f_e * P_e."""
    B, S, E = probs.shape
    served = slot_of < E * capacity                          # (B,S,k) kept
    expert_of_slot = jnp.clip(slot_of // capacity, 0, E - 1)
    onehot = jax.nn.one_hot(expert_of_slot, E, dtype=jnp.float32) * served[
        ..., None
    ].astype(jnp.float32)
    f = onehot.sum(axis=(1, 2)) / jnp.maximum(S * top_k, 1)  # (B,E) token fraction
    p = probs.mean(axis=1)                                   # (B,E) prob fraction
    return jnp.mean(jnp.sum(f * p, axis=-1)) * E


def moe_apply(
    params: dict,
    x: jax.Array,               # (B, S, d)
    *,
    top_k: int,
    capacity_factor: float,
    dtype,
    norm_topk: bool = False,
    num_real_experts: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,d), aux_loss scalar fp32)."""
    B, S, d = x.shape
    E = params["router"].shape[1]      # physical (possibly padded) experts
    n_real = num_real_experts or E
    C = max(1, int(capacity_factor * top_k * S / max(n_real, 1) + 0.5))

    router_logits = (x.astype(jnp.float32)
                     @ params["router"].astype(jnp.float32))  # (B,S,E)
    if n_real < E:   # mask padded experts out of routing
        pad_mask = jnp.arange(E) >= n_real
        router_logits = jnp.where(pad_mask[None, None], -1e30, router_logits)
    idx_table, slot_of, top_w, probs = _route(router_logits, top_k, C)
    aux = load_balance_loss(probs, slot_of, E, top_k, C)

    if norm_topk:
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # dispatch: gather expert inputs (sentinel row S -> zeros)
    xp = jnp.concatenate([x, jnp.zeros((B, 1, d), dtype=x.dtype)], axis=1)
    xe = jnp.take_along_axis(xp, idx_table[..., None], axis=1)  # (B, E*C, d)
    xe = xe.reshape(B, E, C, d)
    xe = shard_act(xe, "batch", MODEL_AXIS, None, None)

    g = jnp.einsum("becd,edf->becf", xe, params["gate"].astype(dtype))
    u = jnp.einsum("becd,edf->becf", xe, params["up"].astype(dtype))
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("becf,efd->becd", h, params["down"].astype(dtype))
    ye = shard_act(ye, "batch", MODEL_AXIS, None, None)

    # combine: gather each token's k slot outputs, weighted sum
    ye_flat = ye.reshape(B, E * C, d)
    yp = jnp.concatenate([ye_flat, jnp.zeros((B, 1, d), dtype=ye.dtype)], axis=1)
    slot_safe = jnp.minimum(slot_of, E * C)                  # sentinel -> zeros
    picked = jnp.take_along_axis(
        yp, slot_safe.reshape(B, S * top_k)[..., None], axis=1
    ).reshape(B, S, top_k, d)
    out = jnp.sum(picked * top_w[..., None].astype(picked.dtype), axis=2)

    # shared experts (always-on dense path, Qwen-MoE style)
    if "shared_gate" in params:
        sg = x @ params["shared_gate"].astype(dtype)
        su = x @ params["shared_up"].astype(dtype)
        sh = jax.nn.silu(sg) * su
        sh = shard_act(sh, "batch", None, MODEL_AXIS)
        out = out + sh @ params["shared_down"].astype(dtype)

    return out.astype(x.dtype), aux
