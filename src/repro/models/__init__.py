"""Model substrate: 10 assigned architectures over shared building blocks."""
from repro.models.model_zoo import Model, build_model

__all__ = ["Model", "build_model"]
