"""Encoder-decoder backbone (whisper-medium, arXiv:2212.04356).

Backbone only, per the assignment: the conv/mel frontend is a stub —
``input_specs()`` supplies precomputed frame embeddings (B, S_enc, d).
Whisper idioms kept: pre-LN LayerNorm (with bias), GELU MLPs, learned
absolute position embeddings (no RoPE), bidirectional encoder self-attention,
decoder causal self-attention + cross-attention. The decode_32k cell is
lowered mechanically on this backbone (real Whisper caps target length at
448 — noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.attention import KVCache, attn_init, init_cache
from repro.models.layers import (
    embed_init,
    embed_lookup,
    layernorm,
    layernorm_init,
    mlp_apply,
    mlp_init,
    normal_init,
    shard_act,
    softmax_xent,
    unembed_logits,
)


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _pd(cfg):
    return jnp.dtype(cfg.param_dtype)


def _zero_rope(B, S, hd):
    # identity rotation: cos=1, sin=0 (whisper has no rope)
    return jnp.ones((B, S, hd // 2), jnp.float32), jnp.zeros((B, S, hd // 2), jnp.float32)


def _scan_or_unroll(cfg, f, init, xs):
    """lax.scan, or a python unroll when cfg.scan_layers=False (exact HLO
    cost analysis for the dry run — scan bodies are counted once by XLA)."""
    if cfg.scan_layers:
        return jax.lax.scan(f, init, xs)
    carry = init
    ys: list = []
    n = jax.tree.leaves(xs)[0].shape[0]
    for i in range(n):
        x_i = jax.tree.map(lambda p: p[i], xs)
        carry, y = f(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        stacked = None
    return carry, stacked


def init_enc_layer(key, cfg: ModelConfig) -> Dict[str, Any]:
    pd = _pd(cfg)
    ks = jax.random.split(key, 2)
    return {
        "norm1": layernorm_init(cfg.d_model, pd),
        "attn": attn_init(ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                          cfg.resolved_head_dim, pd, bias=True),
        "norm2": layernorm_init(cfg.d_model, pd),
        "ffn": mlp_init(ks[1], cfg.d_model, cfg.d_ff, "gelu", pd),
    }


def init_dec_layer(key, cfg: ModelConfig) -> Dict[str, Any]:
    pd = _pd(cfg)
    ks = jax.random.split(key, 3)
    return {
        "norm1": layernorm_init(cfg.d_model, pd),
        "self_attn": attn_init(ks[0], cfg.d_model, cfg.num_heads,
                               cfg.num_kv_heads, cfg.resolved_head_dim, pd,
                               bias=True),
        "norm2": layernorm_init(cfg.d_model, pd),
        "cross_attn": attn_init(ks[1], cfg.d_model, cfg.num_heads,
                                cfg.num_kv_heads, cfg.resolved_head_dim, pd,
                                bias=True),
        "norm3": layernorm_init(cfg.d_model, pd),
        "ffn": mlp_init(ks[2], cfg.d_model, cfg.d_ff, "gelu", pd),
    }


def init_model(key, cfg: ModelConfig) -> Dict[str, Any]:
    pd = _pd(cfg)
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[2], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[3], cfg.num_layers)
    return {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, pd),
        "enc_pos": normal_init(ks[1], (cfg.encoder_seq, cfg.d_model), 0.02, pd),
        "dec_pos": normal_init(ks[4], (cfg.max_position, cfg.d_model), 0.02, pd),
        "enc_blocks": jax.vmap(lambda k: init_enc_layer(k, cfg))(enc_keys),
        "dec_blocks": jax.vmap(lambda k: init_dec_layer(k, cfg))(dec_keys),
        "enc_final": layernorm_init(cfg.d_model, pd),
        "dec_final": layernorm_init(cfg.d_model, pd),
    }


def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, d) precomputed frontend embeddings."""
    dt = _dt(cfg)
    eps = cfg.norm_eps
    B, S, _ = frames.shape
    x = frames.astype(dt) + params["enc_pos"][:S].astype(dt)
    x = shard_act(x, "batch", None, None)
    cos, sin = _zero_rope(B, S, cfg.resolved_head_dim)

    def layer(h, lp):
        a = attn_mod.attention_train(
            lp["attn"], layernorm(lp["norm1"], h, eps), cos, sin,
            dtype=dt, eps=eps, causal=False, use_rope=True,
        )
        h = h + a
        f = mlp_apply(lp["ffn"], layernorm(lp["norm2"], h, eps), "gelu", dt)
        return h + f, None

    x, _ = _scan_or_unroll(cfg, layer, x, params["enc_blocks"])
    return layernorm(params["enc_final"], x, eps)


def decode_train(params, cfg: ModelConfig, tokens: jax.Array,
                 enc_out: jax.Array, last_only: bool = False) -> jax.Array:
    """Teacher-forced decoder forward -> logits (B, S_dec, V)."""
    dt = _dt(cfg)
    eps = cfg.norm_eps
    B, S = tokens.shape
    x = embed_lookup(params["embed"], tokens, dt)
    x = x + params["dec_pos"][:S].astype(dt)
    cos, sin = _zero_rope(B, S, cfg.resolved_head_dim)

    def layer(h, lp):
        a = attn_mod.attention_train(
            lp["self_attn"], layernorm(lp["norm1"], h, eps), cos, sin,
            dtype=dt, eps=eps, causal=True, use_rope=True,
            q_chunk=cfg.attn_q_chunk,
        )
        h = h + a
        kv = attn_mod.cross_kv(lp["cross_attn"], enc_out, dt)
        c = attn_mod.cross_attention(
            lp["cross_attn"], layernorm(lp["norm2"], h, eps), kv, dtype=dt
        )
        h = h + c
        f = mlp_apply(lp["ffn"], layernorm(lp["norm3"], h, eps), "gelu", dt)
        return h + f, None

    x, _ = _scan_or_unroll(cfg, layer, x, params["dec_blocks"])
    x = layernorm(params["dec_final"], x, eps)
    if last_only:
        x = x[:, -1:]     # slice BEFORE unembedding: the full (B, S, V)
                          # logits tensor is 7 GB/device at 32k prefill
    return unembed_logits(params["embed"], x, dt)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    enc = encode(params, cfg, batch["embeds"])
    logits = decode_train(params, cfg, batch["tokens"], enc)
    xent = softmax_xent(logits, batch["labels"], mode=cfg.xent_mode)
    return xent, {"xent": xent, "aux": jnp.zeros((), jnp.float32)}


def forward_logits(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                   last_only: bool = True) -> jax.Array:
    enc = encode(params, cfg, batch["embeds"])
    return decode_train(params, cfg, batch["tokens"], enc,
                        last_only=last_only)


# -- incremental decode ---------------------------------------------------------
class EncDecState(NamedTuple):
    self_caches: Any       # stacked KVCache over decoder layers
    cross_kv: Any          # stacked (k, v) over decoder layers
    pos: jax.Array


def init_decode_state(params, cfg: ModelConfig, frames: jax.Array,
                      seq_budget: int) -> EncDecState:
    """Run the encoder, precompute per-layer cross K/V, allocate self caches."""
    dt = _dt(cfg)
    enc = encode(params, cfg, frames)
    B = frames.shape[0]

    def layer_kv(_, lp):
        return None, attn_mod.cross_kv(lp["cross_attn"], enc, dt)

    _, cross = _scan_or_unroll(cfg, layer_kv, None, params["dec_blocks"])

    def one_cache(_):
        return init_cache(B, seq_budget, cfg.num_kv_heads,
                          cfg.resolved_head_dim, dt)

    caches = jax.vmap(one_cache)(jnp.arange(cfg.num_layers))
    return EncDecState(self_caches=caches, cross_kv=cross,
                       pos=jnp.asarray(0, jnp.int32))


def decode_step(params, cfg: ModelConfig, state: EncDecState,
                batch: Dict[str, jax.Array]) -> Tuple[jax.Array, EncDecState]:
    dt = _dt(cfg)
    eps = cfg.norm_eps
    tokens = batch["tokens"]                      # (B, 1)
    B = tokens.shape[0]
    pos = state.pos
    x = embed_lookup(params["embed"], tokens, dt)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["dec_pos"].astype(dt), pos, 1, axis=0
    )
    cos, sin = _zero_rope(B, 1, cfg.resolved_head_dim)

    def apply_layer(h, lp, cache, ckv):
        a, new_cache = attn_mod.attention_decode(
            lp["self_attn"], layernorm(lp["norm1"], h, eps), cache, pos,
            cos, sin, dtype=dt, eps=eps, use_rope=True,
        )
        h = h + a
        c = attn_mod.cross_attention(
            lp["cross_attn"], layernorm(lp["norm2"], h, eps), ckv, dtype=dt
        )
        h = h + c
        f = mlp_apply(lp["ffn"], layernorm(lp["norm3"], h, eps), "gelu", dt)
        return h + f, new_cache

    # caches ride in the scan carry, updated in place (see transformer.py)
    if cfg.scan_layers:
        def layer(carry, xs):
            h, caches = carry
            lp, ckv, li = xs
            cache = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, li, 0,
                                                       keepdims=False), caches)
            h, new_cache = apply_layer(h, lp, cache, ckv)
            caches = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), li, 0), caches, new_cache)
            return (h, caches), None

        (x, new_caches), _ = jax.lax.scan(
            layer, (x, state.self_caches),
            (params["dec_blocks"], state.cross_kv,
             jnp.arange(cfg.num_layers)),
        )
    else:
        new_caches = state.self_caches
        for li in range(cfg.num_layers):
            lp = jax.tree.map(lambda p: p[li], params["dec_blocks"])
            ckv = jax.tree.map(lambda p: p[li], state.cross_kv)
            cache = jax.tree.map(lambda c: c[li], new_caches)
            x, nc = apply_layer(x, lp, cache, ckv)
            new_caches = jax.tree.map(
                lambda c, n: c.at[li].set(n.astype(c.dtype)), new_caches, nc)
    x = layernorm(params["dec_final"], x, eps)
    logits = unembed_logits(params["embed"], x, dt)
    return logits, EncDecState(self_caches=new_caches,
                               cross_kv=state.cross_kv, pos=pos + 1)
