"""Mamba-1 selective SSM block (falcon-mamba-7b, arXiv:2410.05355).

Training uses a *chunked* scan: the sequence is split into chunks of
``ssm_chunk``; within a chunk the recurrence runs as an associative scan on
(B, Q, d_inner, n) tensors (bounded memory), and the inter-chunk carry is a
plain ``lax.scan`` over S/Q steps. This is the TPU adaptation of the paper's
CUDA selective-scan kernel: chunk-local work is dense and MXU-friendly, the
sequential dependency is reduced to S/Q carry steps. The Pallas
``mamba_scan`` kernel implements the same chunking on-device; this module is
the XLA-native reference path used by the dry-run.

Decode keeps O(1) state per token: conv tail (B, cw-1, d_inner) + SSM state
(B, d_inner, n) — why falcon-mamba runs the long_500k cell.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import MODEL_AXIS, fan_in_init, shard_act


class MambaState(NamedTuple):
    h: jax.Array           # (B, d_inner, n)
    conv: jax.Array        # (B, cw-1, d_inner)


def mamba_init(key, d: int, d_inner: int, state: int, dt_rank: int,
               conv_width: int, dtype) -> dict:
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    A = jnp.tile(jnp.arange(1, state + 1, dtype=jnp.float32), (d_inner, 1))
    return {
        "in_proj": fan_in_init(ks[0], (d, 2 * d_inner), d, dtype),
        "conv_w": fan_in_init(ks[1], (conv_width, d_inner), conv_width, dtype),
        "conv_b": jnp.zeros((d_inner,), dtype=dtype),
        "x_proj": fan_in_init(ks[2], (d_inner, dt_rank + 2 * state), d_inner, dtype),
        "dt_proj": fan_in_init(ks[3], (dt_rank, d_inner), dt_rank, dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((d_inner,), 1e-2))).astype(dtype),
        "A_log": jnp.log(A).astype(dtype),
        "D": jnp.ones((d_inner,), dtype=dtype),
        "out_proj": fan_in_init(ks[4], (d_inner, d), d_inner, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq: x (B,S,di), w (cw,di)."""
    cw = w.shape[0]
    di = x.shape[-1]
    y = jax.lax.conv_general_dilated(
        x, w[:, None, :],
        window_strides=(1,), padding=[(cw - 1, 0)],
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=di,
    )
    return y + b


def _chunked_scan(Abar: jax.Array, Bx: jax.Array, chunk: int,
                  h0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """h_t = Abar_t * h_{t-1} + Bx_t, over axis 1 (S), chunked.

    Abar/Bx: (B, S, di, n). Returns (h (B,S,di,n), h_final (B,di,n)).
    """
    B, S, di, n = Abar.shape
    Q = min(chunk, S)
    if S % Q:
        # pad with identity elements (A=1, b=0)
        pad = Q - S % Q
        Abar = jnp.pad(Abar, ((0, 0), (0, pad), (0, 0), (0, 0)),
                       constant_values=1.0)
        Bx = jnp.pad(Bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = Abar.shape[1] // Q
    Ac = Abar.reshape(B, nc, Q, di, n).swapaxes(0, 1)   # (nc, B, Q, di, n)
    Bc = Bx.reshape(B, nc, Q, di, n).swapaxes(0, 1)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def chunk_step(h_prev, xs):
        a, b = xs                                        # (B, Q, di, n)
        cumA, local = jax.lax.associative_scan(combine, (a, b), axis=1)
        h_all = local + cumA * h_prev[:, None]
        return h_all[:, -1], h_all

    h_final, h_chunks = jax.lax.scan(chunk_step, h0, (Ac, Bc))
    h = h_chunks.swapaxes(0, 1).reshape(B, nc * Q, di, n)[:, :S]
    return h, h_final


def _fused_chunk_scan(dt, Bc, Cc, xin, A, chunk: int) -> jax.Array:
    """Per-chunk discretization + scan + readout (§Perf 'fused' impl).

    The materialized path builds Abar/Bx/h as full (B, S, di, n) tensors —
    4·S/Q× the HBM traffic of this version, which discretizes and reads out
    inside the chunk scan so only (B, Q, di, n) is ever live. Per-chunk
    jax.checkpoint keeps backward memory to one chunk.
    """
    B, S, di = dt.shape
    n = A.shape[1]
    Q = min(chunk, S)
    pad = (Q - S % Q) % Q
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        xin = jnp.pad(xin, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // Q

    def to_chunks(t):
        return t.reshape(B, nc, Q, *t.shape[2:]).swapaxes(0, 1)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    @jax.checkpoint
    def chunk_step(h_prev, xs):
        dt_c, Bc_c, Cc_c, x_c = xs                       # (B, Q, ...)
        Abar = jnp.exp(dt_c[..., None] * A)              # (B, Q, di, n)
        Bx = (dt_c[..., None] * Bc_c[:, :, None, :]
              * x_c[..., None].astype(jnp.float32))
        cumA, local = jax.lax.associative_scan(combine, (Abar, Bx), axis=1)
        h_all = local + cumA * h_prev[:, None]
        y_c = jnp.einsum("bqin,bqn->bqi", h_all, Cc_c)
        return h_all[:, -1], y_c

    _, y = jax.lax.scan(
        chunk_step,
        jnp.zeros((B, di, n), jnp.float32),
        (to_chunks(dt), to_chunks(Bc.astype(jnp.float32)),
         to_chunks(Cc.astype(jnp.float32)), to_chunks(xin)),
    )
    y = y.swapaxes(0, 1).reshape(B, S + pad, di)
    return y[:, :S]


def mamba_apply(
    params: dict,
    x: jax.Array,            # (B, S, d)
    *,
    dtype,
    chunk: int = 256,
    impl: str = "materialized",
) -> jax.Array:
    B, S, d = x.shape
    di = params["A_log"].shape[0]
    n = params["A_log"].shape[1]
    r = params["dt_proj"].shape[0]

    xz = x @ params["in_proj"].astype(dtype)              # (B,S,2di)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard_act(xin, "batch", None, MODEL_AXIS)
    xin = jax.nn.silu(_causal_conv(xin, params["conv_w"].astype(dtype),
                                   params["conv_b"].astype(dtype)))

    proj = xin @ params["x_proj"].astype(dtype)           # (B,S,r+2n)
    dt_in, Bc, Cc = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        dt_in @ params["dt_proj"].astype(dtype)
        + params["dt_bias"].astype(dtype)
    ).astype(jnp.float32)                                  # (B,S,di)

    A = -jnp.exp(params["A_log"].astype(jnp.float32))      # (di, n)
    if impl == "fused":
        y = _fused_chunk_scan(dt, Bc, Cc, xin, A, chunk).astype(dtype)
    else:
        Abar = jnp.exp(dt[..., None] * A)                  # (B,S,di,n)
        Bx = (dt[..., None] * Bc[:, :, None, :].astype(jnp.float32)
              * xin[..., None].astype(jnp.float32))
        h0 = jnp.zeros((B, di, n), dtype=jnp.float32)
        h, _ = _chunked_scan(Abar, Bx, chunk, h0)
        y = jnp.einsum("bsin,bsn->bsi", h,
                       Cc.astype(jnp.float32)).astype(dtype)
    y = y + params["D"].astype(dtype) * xin
    y = y * jax.nn.silu(z)
    y = shard_act(y, "batch", None, MODEL_AXIS)
    return y @ params["out_proj"].astype(dtype)


def mamba_init_state(params: dict, batch: int, conv_width: int, dtype
                     ) -> MambaState:
    di, n = params["A_log"].shape
    return MambaState(
        h=jnp.zeros((batch, di, n), dtype=jnp.float32),
        conv=jnp.zeros((batch, conv_width - 1, di), dtype=dtype),
    )


def mamba_decode(
    params: dict,
    x: jax.Array,            # (B, 1, d)
    state: MambaState,
    *,
    dtype,
) -> Tuple[jax.Array, MambaState]:
    B = x.shape[0]
    di, n = params["A_log"].shape
    r = params["dt_proj"].shape[0]

    xz = x[:, 0] @ params["in_proj"].astype(dtype)         # (B, 2di)
    xin, z = jnp.split(xz, 2, axis=-1)
    # conv over [state, xin]
    win = jnp.concatenate([state.conv, xin[:, None, :]], axis=1)  # (B, cw, di)
    w = params["conv_w"].astype(dtype)                     # (cw, di)
    xin_c = jax.nn.silu(
        jnp.einsum("bci,ci->bi", win, w) + params["conv_b"].astype(dtype)
    )
    new_conv = win[:, 1:]

    proj = xin_c @ params["x_proj"].astype(dtype)
    dt_in, Bc, Cc = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        dt_in @ params["dt_proj"].astype(dtype)
        + params["dt_bias"].astype(dtype)
    ).astype(jnp.float32)                                   # (B, di)

    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    Abar = jnp.exp(dt[..., None] * A)                       # (B, di, n)
    Bx = (dt[..., None] * Bc[:, None, :].astype(jnp.float32)
          * xin_c[..., None].astype(jnp.float32))
    h = Abar * state.h + Bx
    y = jnp.einsum("bin,bn->bi", h, Cc.astype(jnp.float32)).astype(dtype)
    y = y + params["D"].astype(dtype) * xin_c
    y = y * jax.nn.silu(z)
    out = (y @ params["out_proj"].astype(dtype))[:, None, :]
    return out, MambaState(h=h, conv=new_conv)
