"""Model facade: one interface over decoder-only and enc-dec stacks.

Also home of ``input_specs`` / ``decode_state_specs`` — the
ShapeDtypeStruct stand-ins the multi-pod dry-run lowers against (weak-type
correct, shardable, zero allocation).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, transformer


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._m = encdec if cfg.is_encdec else transformer

    # -- params ---------------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        return self._m.init_model(key, self.cfg)

    def abstract_params(self):
        return jax.eval_shape(
            lambda k: self._m.init_model(k, self.cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )

    # -- steps ------------------------------------------------------------------
    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        return self._m.loss_fn(params, self.cfg, batch)

    def prefill_logits(self, params, batch) -> jax.Array:
        return self._m.forward_logits(params, self.cfg, batch)

    def decode(self, params, state, batch):
        return self._m.decode_step(params, self.cfg, state, batch)

    def init_decode_state(self, params, batch_size: int, seq_budget: int,
                          frames=None):
        if self.cfg.is_encdec:
            assert frames is not None, "enc-dec decode needs encoder frames"
            return encdec.init_decode_state(params, self.cfg, frames, seq_budget)
        return transformer.init_decode_state(self.cfg, batch_size, seq_budget)

    # -- dry-run specs --------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        act = jnp.dtype(cfg.dtype)
        d = cfg.d_model
        if shape.kind == "decode":
            batch: Dict[str, jax.ShapeDtypeStruct] = {
                "tokens": jax.ShapeDtypeStruct((B, 1), i32)
            }
            return batch
        if cfg.is_encdec:
            batch = {
                "embeds": jax.ShapeDtypeStruct((B, cfg.encoder_seq, d), act),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
            }
        elif cfg.input_mode == "embeddings":
            batch = {
                "embeds": jax.ShapeDtypeStruct((B, S, d), act),
            }
            if cfg.mrope_sections:
                batch["positions"] = jax.ShapeDtypeStruct((B, S, 3), i32)
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return batch

    def decode_state_specs(self, shape: ShapeConfig):
        assert shape.kind == "decode"
        cfg = self.cfg
        B, budget = shape.global_batch, shape.seq_len
        if cfg.is_encdec:
            p_specs = self.abstract_params()
            f_spec = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
            )
            return jax.eval_shape(
                lambda p, f: encdec.init_decode_state(p, cfg, f, budget),
                p_specs, f_spec,
            )
        return jax.eval_shape(
            lambda: transformer.init_decode_state(cfg, B, budget)
        )


@functools.lru_cache(maxsize=None)
def _cached_model(name: str) -> Model:
    from repro.configs.registry import get_config

    return Model(get_config(name))


def build_model(cfg_or_name) -> Model:
    if isinstance(cfg_or_name, str):
        return _cached_model(cfg_or_name)
    return Model(cfg_or_name)
