"""Griffin recurrent block with RG-LRU (recurrentgemma-2b, arXiv:2402.19427).

Block: x -> [gate branch: linear -> GeLU] ⊙ [recurrent branch: linear ->
causal conv(4) -> RG-LRU] -> output linear.

RG-LRU: r_t = σ(W_r x_t), i_t = σ(W_i x_t),
        a_t = exp(-c · softplus(Λ) · r_t)          (c = 8)
        h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training runs the diagonal recurrence as a single associative scan over S
(cheap: elementwise on (B, S, w)); decode carries (h, conv tail) — O(1) per
token, which is why recurrentgemma runs the long_500k cell. The gate
matrices are dense (w×w) rather than RecurrentGemma's block-diagonal heads —
a ≤0.5 % parameter-count deviation noted in DESIGN.md.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import MODEL_AXIS, fan_in_init, shard_act

_C = 8.0


class RGLRUState(NamedTuple):
    h: jax.Array          # (B, w) fp32
    conv: jax.Array       # (B, cw-1, w)


def rglru_init(key, d: int, w: int, conv_width: int, dtype) -> dict:
    ks = jax.random.split(key, 6)
    # Λ init so that a^c uniform-ish in [0.9, 0.999] (paper appendix)
    lam = jnp.log(jnp.expm1(-jnp.log(
        jnp.linspace(0.9, 0.999, w, dtype=jnp.float32)) / _C))
    return {
        "wx": fan_in_init(ks[0], (d, w), d, dtype),          # recurrent branch in
        "wy": fan_in_init(ks[1], (d, w), d, dtype),          # gate branch in
        "conv_w": fan_in_init(ks[2], (conv_width, w), conv_width, dtype),
        "conv_b": jnp.zeros((w,), dtype=dtype),
        "w_r": fan_in_init(ks[3], (w, w), w, dtype),         # recurrence gate
        "w_i": fan_in_init(ks[4], (w, w), w, dtype),         # input gate
        "b_r": jnp.zeros((w,), dtype=dtype),
        "b_i": jnp.zeros((w,), dtype=dtype),
        "lam": lam.astype(dtype),
        "wo": fan_in_init(ks[5], (w, d), w, dtype),
    }


def _gates(params: dict, xr: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (a, gated_input) in fp32; xr is the conv output (..., w)."""
    xf = xr.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_r"].astype(jnp.float32)
                       + params["b_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ params["w_i"].astype(jnp.float32)
                       + params["b_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * xf


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    cw = w.shape[0]
    ch = x.shape[-1]
    y = jax.lax.conv_general_dilated(
        x, w[:, None, :], window_strides=(1,), padding=[(cw - 1, 0)],
        dimension_numbers=("NHC", "HIO", "NHC"), feature_group_count=ch,
    )
    return y + b


def rglru_apply(params: dict, x: jax.Array, *, dtype) -> jax.Array:
    """Training/prefill path: x (B, S, d) -> (B, S, d)."""
    xr = x @ params["wx"].astype(dtype)
    xr = shard_act(xr, "batch", None, MODEL_AXIS)
    xr = _causal_conv(xr, params["conv_w"].astype(dtype),
                      params["conv_b"].astype(dtype))
    a, bx = _gates(params, xr)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    gate = jax.nn.gelu(x @ params["wy"].astype(dtype))
    y = (h.astype(dtype) * gate)
    y = shard_act(y, "batch", None, MODEL_AXIS)
    return y @ params["wo"].astype(dtype)


def rglru_init_state(params: dict, batch: int, conv_width: int, dtype
                     ) -> RGLRUState:
    w = params["lam"].shape[0]
    return RGLRUState(
        h=jnp.zeros((batch, w), dtype=jnp.float32),
        conv=jnp.zeros((batch, conv_width - 1, w), dtype=dtype),
    )


def rglru_decode(
    params: dict,
    x: jax.Array,          # (B, 1, d)
    state: RGLRUState,
    *,
    dtype,
) -> Tuple[jax.Array, RGLRUState]:
    xr = x[:, 0] @ params["wx"].astype(dtype)               # (B, w)
    win = jnp.concatenate([state.conv, xr[:, None]], axis=1)
    wc = params["conv_w"].astype(dtype)
    xr_c = jnp.einsum("bcw,cw->bw", win, wc) + params["conv_b"].astype(dtype)
    a, bx = _gates(params, xr_c)
    h = a * state.h + bx
    gate = jax.nn.gelu(x[:, 0] @ params["wy"].astype(dtype))
    y = h.astype(dtype) * gate
    out = (y @ params["wo"].astype(dtype))[:, None]
    return out, RGLRUState(h=h, conv=win[:, 1:])
