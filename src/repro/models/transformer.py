"""Decoder-only transformer stack assembly (all non-enc-dec architectures).

Layers are grouped into repeating blocks (``cfg.block_pattern``); the stack
``lax.scan``s over whole blocks (stacked params) and unrolls the remainder —
HLO size stays O(pattern), not O(num_layers), which keeps 62-layer models
compilable and lets remat apply per block. Heterogeneous patterns (gemma3's
5 local : 1 global, griffin's 2 recurrent : 1 attn) are python-static inside
the block function, so no lax.cond is needed.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ATTN_LOCAL, DENSE, MAMBA, MOE, NONE, RGLRU, ModelConfig
from repro.models import attention as attn_mod
from repro.models.attention import KVCache, attn_init, init_cache
from repro.models.layers import (
    embed_init,
    embed_lookup,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    rope_angles,
    shard_act,
    softmax_xent,
    unembed_logits,
)
from repro.models.moe import moe_apply, moe_init
from repro.models.rglru import (
    RGLRUState,
    rglru_apply,
    rglru_decode,
    rglru_init,
    rglru_init_state,
)
from repro.models.ssm import (
    MambaState,
    mamba_apply,
    mamba_decode,
    mamba_init,
    mamba_init_state,
)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_layer(key, cfg: ModelConfig, spec) -> Dict[str, Any]:
    pd = _pdtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    p: Dict[str, Any] = {"norm1": rmsnorm_init(d, pd)}
    if spec.mixer in (ATTN, ATTN_LOCAL):
        p["mixer"] = attn_init(
            ks[0], d, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim,
            pd, bias=cfg.attn_bias, qk_norm=cfg.qk_norm,
            phys_heads=cfg.num_heads_phys, phys_kv=cfg.num_kv_heads_phys,
        )
    elif spec.mixer == MAMBA:
        p["mixer"] = mamba_init(
            ks[0], d, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.conv_width, pd
        )
    elif spec.mixer == RGLRU:
        p["mixer"] = rglru_init(ks[0], d, cfg.lru_width, cfg.conv_width, pd)
    if spec.ffn != NONE:
        p["norm2"] = rmsnorm_init(d, pd)
        if spec.ffn == DENSE:
            p["ffn"] = mlp_init(ks[1], d, cfg.d_ff, cfg.act, pd)
        else:
            p["ffn"] = moe_init(
                ks[1], d, cfg.num_experts, cfg.moe_d_ff,
                cfg.num_shared_experts, pd, expert_pad=cfg.expert_pad,
            )
    return p


def init_block(key, cfg: ModelConfig) -> Dict[str, Any]:
    pattern = cfg.block_pattern
    ks = jax.random.split(key, len(pattern))
    return {f"l{i}": init_layer(ks[i], cfg, spec)
            for i, spec in enumerate(pattern)}


def init_model(key, cfg: ModelConfig) -> Dict[str, Any]:
    pd = _pdtype(cfg)
    pattern, nb, tail = cfg.scan_split()
    n_keys = 2 + nb + len(tail) + (0 if cfg.tie_embeddings else 1)
    ks = jax.random.split(key, n_keys)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, pd),
        "final_norm": rmsnorm_init(cfg.d_model, pd),
    }
    if nb > 0:
        params["blocks"] = jax.vmap(lambda k: init_block(k, cfg))(
            jnp.stack(ks[2 : 2 + nb])
        )
    params["tail"] = [
        init_layer(ks[2 + nb + i], cfg, spec) for i, spec in enumerate(tail)
    ]
    if not cfg.tie_embeddings:
        from repro.models.layers import fan_in_init

        params["lm_head"] = {
            "w": fan_in_init(ks[-1], (cfg.d_model, cfg.vocab_size), cfg.d_model, pd)
        }
    return params


# ---------------------------------------------------------------------------
# train / prefill forward
# ---------------------------------------------------------------------------
def apply_layer_train(params, spec, cfg: ModelConfig, x, cos, sin
                      ) -> Tuple[jax.Array, jax.Array]:
    dt = _dtype(cfg)
    eps = cfg.norm_eps
    h = rmsnorm(params["norm1"], x, eps)
    if spec.mixer in (ATTN, ATTN_LOCAL):
        m = attn_mod.attention_train(
            params["mixer"], h, cos, sin, dtype=dt, eps=eps, causal=True,
            window=spec.window, softcap=cfg.attn_logit_softcap,
            use_rope=cfg.use_rope, q_chunk=cfg.attn_q_chunk,
        )
    elif spec.mixer == MAMBA:
        m = mamba_apply(params["mixer"], h, dtype=dt, chunk=cfg.ssm_chunk,
                        impl=cfg.ssm_impl)
    elif spec.mixer == RGLRU:
        m = rglru_apply(params["mixer"], h, dtype=dt)
    else:  # pragma: no cover
        raise ValueError(spec.mixer)
    x = x + m
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn != NONE:
        h = rmsnorm(params["norm2"], x, eps)
        if spec.ffn == DENSE:
            f = mlp_apply(params["ffn"], h, cfg.act, dt)
        else:
            f, aux = moe_apply(
                params["ffn"], h, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, dtype=dt,
                num_real_experts=cfg.num_experts,
            )
        x = x + f
    x = shard_act(x, "batch", None, None)
    return x, aux


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    if policy == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    raise ValueError(f"unknown remat policy {policy!r}")


def forward_backbone(params, cfg: ModelConfig, x, cos, sin
                     ) -> Tuple[jax.Array, jax.Array]:
    pattern, nb, tail = cfg.scan_split()
    aux_total = jnp.zeros((), jnp.float32)

    if nb > 0:
        def block_fn(carry, bp):
            h, aux = carry
            for i, spec in enumerate(pattern):
                h, a = apply_layer_train(bp[f"l{i}"], spec, cfg, h, cos, sin)
                aux = aux + a
            return (h, aux), None

        block_fn = _remat(block_fn, cfg.remat_policy)
        if cfg.scan_layers:
            (x, aux_total), _ = jax.lax.scan(
                block_fn, (x, aux_total), params["blocks"]
            )
        else:
            for bi in range(nb):
                bp = jax.tree.map(lambda p: p[bi], params["blocks"])
                (x, aux_total), _ = block_fn((x, aux_total), bp)
    for i, spec in enumerate(tail):
        x, a = apply_layer_train(params["tail"][i], spec, cfg, x, cos, sin)
        aux_total = aux_total + a
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux_total


def _positions(cfg: ModelConfig, batch: Dict[str, jax.Array], S: int, B: int):
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[..., None], (B, S, 3))
    return pos


def _input_x(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    dt = _dtype(cfg)
    if cfg.input_mode == "embeddings" and "embeds" in batch:
        x = batch["embeds"].astype(dt)
        x = shard_act(x, "batch", None, None)
        B, S = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed_lookup(params["embed"], tokens, dt)
    return x, B, S


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full train forward -> (scalar loss fp32, metrics)."""
    x, B, S = _input_x(params, cfg, batch)
    pos = _positions(cfg, batch, S, B)
    cos, sin = rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta,
                           cfg.mrope_sections)
    x, aux = forward_backbone(params, cfg, x, cos, sin)
    if cfg.tie_embeddings:
        logits = unembed_logits(params["embed"], x, _dtype(cfg))
    else:
        logits = x @ params["lm_head"]["w"].astype(_dtype(cfg))
        logits = shard_act(logits, "batch", None, "model")
    xent = softmax_xent(logits, batch["labels"], mode=cfg.xent_mode)
    loss = xent + cfg.router_aux_coef * aux
    return loss, {"xent": xent, "aux": aux}


def forward_logits(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                   last_only: bool = True) -> jax.Array:
    """Prefill forward (no labels). Returns last-position logits by default."""
    x, B, S = _input_x(params, cfg, batch)
    pos = _positions(cfg, batch, S, B)
    cos, sin = rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta,
                           cfg.mrope_sections)
    x, _ = forward_backbone(params, cfg, x, cos, sin)
    if last_only:
        x = x[:, -1:]
    if cfg.tie_embeddings:
        return unembed_logits(params["embed"], x, _dtype(cfg))
    return x @ params["lm_head"]["w"].astype(_dtype(cfg))


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
class DecodeState(NamedTuple):
    blocks: Any            # per-pattern-position states, stacked over nb
    tail: Any              # list of per-layer states
    pos: jax.Array         # scalar int32: next absolute position


def _layer_capacity(cfg: ModelConfig, spec, seq_budget: int) -> int:
    if spec.mixer == ATTN_LOCAL and spec.window > 0:
        return min(spec.window, seq_budget)
    return seq_budget


def init_layer_state(cfg: ModelConfig, spec, batch: int, seq_budget: int):
    dt = _dtype(cfg)
    if spec.mixer in (ATTN, ATTN_LOCAL):
        return init_cache(
            batch, _layer_capacity(cfg, spec, seq_budget),
            cfg.num_kv_heads_phys or cfg.num_kv_heads,
            cfg.resolved_head_dim, dt,
        )
    if spec.mixer == MAMBA:
        di, n = cfg.d_inner, cfg.ssm_state
        return MambaState(
            h=jnp.zeros((batch, di, n), jnp.float32),
            conv=jnp.zeros((batch, cfg.conv_width - 1, di), dt),
        )
    if spec.mixer == RGLRU:
        return RGLRUState(
            h=jnp.zeros((batch, cfg.lru_width), jnp.float32),
            conv=jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), dt),
        )
    raise ValueError(spec.mixer)


def init_decode_state(cfg: ModelConfig, batch: int, seq_budget: int,
                      pos: int = 0) -> DecodeState:
    pattern, nb, tail = cfg.scan_split()

    def one_block(_):
        return tuple(
            init_layer_state(cfg, spec, batch, seq_budget) for spec in pattern
        )

    blocks = (
        jax.vmap(one_block)(jnp.arange(nb)) if nb > 0 else None
    )
    tail_states = [
        init_layer_state(cfg, spec, batch, seq_budget) for spec in tail
    ]
    return DecodeState(blocks=blocks, tail=tail_states,
                       pos=jnp.asarray(pos, jnp.int32))


def apply_layer_decode(params, state, spec, cfg: ModelConfig, x, pos, cos, sin):
    dt = _dtype(cfg)
    eps = cfg.norm_eps
    h = rmsnorm(params["norm1"], x, eps)
    if spec.mixer in (ATTN, ATTN_LOCAL):
        m, new_state = attn_mod.attention_decode(
            params["mixer"], h, state, pos, cos, sin, dtype=dt, eps=eps,
            window=spec.window, softcap=cfg.attn_logit_softcap,
            use_rope=cfg.use_rope,
        )
    elif spec.mixer == MAMBA:
        m, new_state = mamba_decode(params["mixer"], h, state, dtype=dt)
    elif spec.mixer == RGLRU:
        m, new_state = rglru_decode(params["mixer"], h, state, dtype=dt)
    else:  # pragma: no cover
        raise ValueError(spec.mixer)
    x = x + m
    if spec.ffn != NONE:
        h = rmsnorm(params["norm2"], x, eps)
        if spec.ffn == DENSE:
            f = mlp_apply(params["ffn"], h, cfg.act, dt)
        else:
            f, _ = moe_apply(
                params["ffn"], h, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, dtype=dt,
                num_real_experts=cfg.num_experts,
            )
        x = x + f
    return x, new_state


def decode_step(params, cfg: ModelConfig, state: DecodeState,
                batch: Dict[str, jax.Array]) -> Tuple[jax.Array, DecodeState]:
    """One token for every sequence in the batch.

    batch: {"tokens": (B, 1)} or {"embeds": (B, 1, d)}.
    Returns (logits (B, 1, V), new state).
    """
    dt = _dtype(cfg)
    x, B, _ = _input_x(params, cfg, batch)
    pos = state.pos
    pos_ids = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    if cfg.mrope_sections:
        pos_ids = jnp.broadcast_to(pos_ids[..., None], (B, 1, 3))
    cos, sin = rope_angles(pos_ids, cfg.resolved_head_dim, cfg.rope_theta,
                           cfg.mrope_sections)

    pattern, nb, tail = cfg.scan_split()
    new_blocks = None
    if nb > 0:
        # The stacked caches ride in the scan CARRY and are updated in place
        # (dynamic_update_index_in_dim on the carry) — the xs->ys formulation
        # would materialize a second full cache buffer (measured +2x HBM on
        # the 32k decode cells; see EXPERIMENTS.md §Perf).
        def apply_block(h, bp, bs):
            new_states = []
            for i, spec in enumerate(pattern):
                h, ns = apply_layer_decode(
                    bp[f"l{i}"], bs[i], spec, cfg, h, pos, cos, sin
                )
                new_states.append(ns)
            return h, tuple(new_states)

        if cfg.scan_layers:
            def block_fn(carry, xs):
                h, caches = carry
                bp, bi = xs
                bs = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(
                        c, bi, 0, keepdims=False), caches)
                h, ns = apply_block(h, bp, bs)
                caches = jax.tree.map(
                    lambda c, n: jax.lax.dynamic_update_index_in_dim(
                        c, n.astype(c.dtype), bi, 0), caches, ns)
                return (h, caches), None

            (x, new_blocks), _ = jax.lax.scan(
                block_fn, (x, state.blocks),
                (params["blocks"], jnp.arange(nb)),
            )
        else:
            caches = state.blocks
            for bi in range(nb):
                bp = jax.tree.map(lambda p: p[bi], params["blocks"])
                bs = jax.tree.map(lambda c: c[bi], caches)
                x, ns = apply_block(x, bp, bs)
                caches = jax.tree.map(
                    lambda c, n: c.at[bi].set(n.astype(c.dtype)), caches, ns)
            new_blocks = caches
    new_tail = []
    for i, spec in enumerate(tail):
        x, ns = apply_layer_decode(
            params["tail"][i], state.tail[i], spec, cfg, x, pos, cos, sin
        )
        new_tail.append(ns)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed_logits(params["embed"], x, dt)
    else:
        logits = x @ params["lm_head"]["w"].astype(dt)
    return logits, DecodeState(blocks=new_blocks, tail=new_tail, pos=pos + 1)
